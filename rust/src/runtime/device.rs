//! PJRT device backend (feature `pjrt`).
//!
//! The compiled HLO artifacts execute on a dedicated device thread that
//! is the sole owner of the PJRT client, executables and `Literal`s
//! (none of which are `Send`) — the [`PjrtBackend`] marshals each typed
//! [`Backend`] op into `HostTensor`s, issues a synchronous execute RPC
//! over an mpsc channel and unmarshals the reply, mirroring vLLM's
//! single device-worker pattern. Artifact-name strings exist only here:
//! callers everywhere else in the crate speak the typed trait.
//!
//! The offline build ships a stub `xla` crate (vendor/xla) whose client
//! constructor fails at runtime, so `--features pjrt` compile-checks the
//! whole backend while execution still requires real bindings.

#![cfg(feature = "pjrt")]

use super::backend::{Backend, Capabilities, Op, OpCounters};
use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::linalg::{Mat, Svd};
use crate::util::LockExt;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
// lint:allow(mpsc) — the device thread is the sole owner of non-Send
// PJRT state; a private channel pair per call is the marshalling
// boundary, not a client-facing receiver API.
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

enum Cmd {
    Execute {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: Sender<Result<Vec<HostTensor>>>,
    },
    Warm { artifact: String, reply: Sender<Result<()>> },
}

/// Typed backend over the PJRT device thread.
pub struct PjrtBackend {
    manifest: Manifest,
    tx: Mutex<Sender<Cmd>>,
    ops: Arc<OpCounters>,
}

impl PjrtBackend {
    /// Spawn the device thread serving the manifest's artifacts.
    pub fn spawn(manifest: Manifest) -> Result<PjrtBackend> {
        let (tx, rx) = channel::<Cmd>();
        let thread_manifest = manifest.clone();
        std::thread::Builder::new()
            .name("drrl-device".into())
            .spawn(move || device_main(thread_manifest, rx))
            .context("spawning device thread")?;
        Ok(PjrtBackend {
            manifest,
            tx: Mutex::new(tx),
            ops: Arc::new(OpCounters::default()),
        })
    }

    fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        self.tx
            .lock_unpoisoned()
            .send(Cmd::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    fn warm_artifact(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .lock_unpoisoned()
            .send(Cmd::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Derived from the manifest: an op is supported iff every artifact
    /// it dispatches to was built (serving-only artifact dirs may omit
    /// e.g. the train-step graph — `warm_all` must skip those, not
    /// abort).
    fn capabilities(&self) -> Capabilities {
        let has = |n: &str| self.manifest.artifact_files.contains_key(n);
        let buckets = &self.manifest.kernel.rank_buckets;
        let mut supported = Vec::new();
        for op in Op::ALL {
            let present = match op {
                Op::FullAttention => has("full_attn"),
                Op::LowRankAttention => {
                    !buckets.is_empty()
                        && buckets.iter().all(|b| has(&format!("lowrank_attn_r{b}")))
                }
                Op::PowerIterSigma => has("power_iter"),
                Op::PolicyLogits => has("policy_net"),
                Op::LmLogits => has("lm_logits"),
                Op::LmEvalLoss => has("lm_eval_loss"),
                Op::LmTrainStep => has("lm_train_step"),
            };
            if present {
                supported.push(op);
            }
        }
        Capabilities { supported, models_latency: false }
    }

    fn ops(&self) -> Arc<OpCounters> {
        Arc::clone(&self.ops)
    }

    /// Compile the op's artifact(s) ahead of first use.
    fn warm(&self, op: Op) -> Result<()> {
        match op {
            Op::FullAttention => self.warm_artifact("full_attn"),
            Op::LowRankAttention => {
                for b in &self.manifest.kernel.rank_buckets {
                    self.warm_artifact(&format!("lowrank_attn_r{b}"))?;
                }
                Ok(())
            }
            Op::PowerIterSigma => self.warm_artifact("power_iter"),
            Op::PolicyLogits => self.warm_artifact("policy_net"),
            Op::LmLogits => self.warm_artifact("lm_logits"),
            Op::LmEvalLoss => self.warm_artifact("lm_eval_loss"),
            Op::LmTrainStep => self.warm_artifact("lm_train_step"),
        }
    }

    fn full_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        self.ops.record(Op::FullAttention);
        let (n, d) = q.shape();
        let out = self.execute(
            "full_attn",
            vec![HostTensor::from_mat(q), HostTensor::from_mat(k), HostTensor::from_mat(v)],
        )?;
        Ok(out[0].to_mat(n, d))
    }

    fn lowrank_attention(&self, svd: &Svd, bucket: usize, rank: usize, v_val: &Mat) -> Result<Mat> {
        self.ops.record(Op::LowRankAttention);
        anyhow::ensure!(svd.s.len() >= bucket, "need ≥{bucket} factors, have {}", svd.s.len());
        let (n, d) = v_val.shape();
        let u = svd.u.take_cols(bucket);
        let vt = svd.v.take_cols(bucket).transpose();
        let s: Vec<f64> = svd.s[..bucket].to_vec();
        let mask: Vec<f32> = (0..bucket).map(|i| if i < rank { 1.0 } else { 0.0 }).collect();
        let out = self.execute(
            &format!("lowrank_attn_r{bucket}"),
            vec![
                HostTensor::from_mat(&u),
                HostTensor::from_f64s(&s),
                HostTensor::from_mat(&vt),
                HostTensor::from_mat(v_val),
                HostTensor::f32(mask, &[bucket as i64]),
            ],
        )?;
        Ok(out[0].to_mat(n, d))
    }

    fn power_iter_sigma(&self, m: &Mat, v0: &[f64]) -> Result<f64> {
        self.ops.record(Op::PowerIterSigma);
        let out = self
            .execute("power_iter", vec![HostTensor::from_mat(m), HostTensor::from_f64s(v0)])?;
        Ok(out[0].scalar())
    }

    fn policy_logits(&self, weights: &[f32], state: &[f64]) -> Result<Vec<f64>> {
        self.ops.record(Op::PolicyLogits);
        let wlen = weights.len() as i64;
        let out = self.execute(
            "policy_net",
            vec![
                HostTensor::f32(weights.to_vec(), &[wlen]),
                HostTensor::from_f64s(state),
            ],
        )?;
        Ok(out[0]
            .as_f32()
            .ok_or_else(|| anyhow!("policy_net returned non-f32"))?
            .iter()
            .map(|&x| x as f64)
            .collect())
    }

    fn lm_logits(&self, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.ops.record(Op::LmLogits);
        let lm = &self.manifest.lm;
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let out = self.execute(
            "lm_logits",
            vec![
                HostTensor::f32(params.to_vec(), &[lm.param_count as i64]),
                HostTensor::i32(tokens.to_vec(), &bl),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().expect_f32())
    }

    fn lm_eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f64> {
        self.ops.record(Op::LmEvalLoss);
        let lm = &self.manifest.lm;
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let out = self.execute(
            "lm_eval_loss",
            vec![
                HostTensor::f32(params.to_vec(), &[lm.param_count as i64]),
                HostTensor::i32(tokens.to_vec(), &bl),
                HostTensor::i32(targets.to_vec(), &bl),
            ],
        )?;
        Ok(out[0].scalar())
    }

    fn lm_train_step(
        &self,
        params: &mut Vec<f32>,
        adam_m: &mut Vec<f32>,
        adam_v: &mut Vec<f32>,
        step: f32,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f64> {
        self.ops.record(Op::LmTrainStep);
        let lm = &self.manifest.lm;
        let p = lm.param_count as i64;
        let bl = [lm.batch as i64, lm.seq_len as i64];
        // Clone rather than mem::take: a failed execute must leave the
        // caller's training state intact (the state is only replaced
        // below, once the device returned all four outputs).
        let out = self.execute(
            "lm_train_step",
            vec![
                HostTensor::f32(params.clone(), &[p]),
                HostTensor::f32(adam_m.clone(), &[p]),
                HostTensor::f32(adam_v.clone(), &[p]),
                HostTensor::scalar_f32(step),
                HostTensor::i32(tokens.to_vec(), &bl),
                HostTensor::i32(targets.to_vec(), &bl),
            ],
        )?;
        anyhow::ensure!(out.len() == 4, "train_step returns 4 outputs, got {}", out.len());
        let mut it = out.into_iter();
        *params = it.next().unwrap().expect_f32();
        *adam_m = it.next().unwrap().expect_f32();
        *adam_v = it.next().unwrap().expect_f32();
        Ok(it.next().unwrap().scalar())
    }
}

// lint:allow(mpsc) — receiving end of the device thread's private
// marshalling channel (see the module header).
fn device_main(manifest: Manifest, rx: std::sync::mpsc::Receiver<Cmd>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            crate::log_warn!("PJRT CPU client unavailable: {e}");
            // Drain commands with errors so callers fail fast.
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Cmd::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                    Cmd::Warm { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                    }
                }
            }
            return;
        }
    };
    // Per-op execute counts live in the backend's `OpCounters`; the
    // device thread caches only the compiled executables.
    let mut cache: BTreeMap<String, xla::PjRtLoadedExecutable> = BTreeMap::new();

    let load = |client: &xla::PjRtClient,
                cache: &mut BTreeMap<String, xla::PjRtLoadedExecutable>,
                manifest: &Manifest,
                name: &str|
     -> Result<()> {
        if cache.contains_key(name) {
            return Ok(());
        }
        let path = manifest.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    };

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Warm { artifact, reply } => {
                let _ = reply.send(load(&client, &mut cache, &manifest, &artifact));
            }
            Cmd::Execute { artifact, inputs, reply } => {
                let result = (|| -> Result<Vec<HostTensor>> {
                    load(&client, &mut cache, &manifest, &artifact)?;
                    // load() just inserted (or found) the entry.
                    // lint:allow(panic-in-worker)
                    let exe = cache.get(&artifact).unwrap();
                    let lits: Vec<xla::Literal> =
                        inputs.iter().map(to_literal).collect::<Result<_>>()?;
                    let bufs = exe.execute::<xla::Literal>(&lits)?;
                    let out = bufs[0][0].to_literal_sync()?;
                    let parts = out.to_tuple()?;
                    parts.iter().map(from_literal).collect()
                })();
                let _ = reply.send(result);
            }
        }
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    match t {
        HostTensor::F32 { data, dims } => Ok(xla::Literal::vec1(data).reshape(dims)?),
        HostTensor::I32 { data, dims } => Ok(xla::Literal::vec1(data).reshape(dims)?),
    }
}

fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l.array_shape()?;
    let dims = shape.dims().to_vec();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::F32 { data: l.to_vec::<f32>()?, dims }),
        xla::ElementType::S32 => Ok(HostTensor::I32 { data: l.to_vec::<i32>()?, dims }),
        other => {
            // Convert anything else (f64/bf16/…) through F32.
            let conv = l.convert(xla::PrimitiveType::F32)?;
            let _ = other;
            Ok(HostTensor::F32 { data: conv.to_vec::<f32>()?, dims })
        }
    }
}
