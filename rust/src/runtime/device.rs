//! Device dispatch layer.
//!
//! Two backends behind one cheap `DeviceHandle` (Clone + Send + Sync):
//!
//! * **PJRT** (feature `pjrt`): the compiled HLO artifacts execute on a
//!   dedicated device thread that is the sole owner of the PJRT client,
//!   executables and `Literal`s (none of which are `Send`) — callers
//!   issue synchronous `execute` RPCs over an mpsc channel, mirroring
//!   vLLM's single device-worker pattern.
//! * **Host** (default): the pure-Rust [`HostBackend`] interprets the
//!   artifact entry points with the crate's own kernels. It is
//!   `Send + Sync` and runs on the calling thread, so concurrent engine
//!   workers execute kernels genuinely in parallel.
//!
//! The offline build ships without the `xla` bindings crate, so the
//! `pjrt` feature is off by default and everything — tests, examples,
//! the serving engine — runs against the host backend.

use super::host::HostBackend;
use super::manifest::Manifest;
use super::tensor::HostTensor;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Cloneable, Send + Sync handle to a backend.
#[derive(Clone)]
pub struct DeviceHandle {
    inner: Inner,
}

#[derive(Clone)]
enum Inner {
    Host(Arc<HostBackend>),
    #[cfg(feature = "pjrt")]
    Pjrt(std::sync::mpsc::Sender<pjrt::Cmd>),
}

impl DeviceHandle {
    /// Spawn a backend serving artifacts from `dir`. With the `pjrt`
    /// feature this compiles and runs the HLO artifacts on a device
    /// thread; otherwise the manifest's shapes drive the host backend.
    pub fn spawn(dir: &std::path::Path) -> Result<DeviceHandle> {
        let manifest = Manifest::load(dir)?;
        Self::spawn_backend(manifest)
    }

    #[cfg(feature = "pjrt")]
    fn spawn_backend(manifest: Manifest) -> Result<DeviceHandle> {
        pjrt::spawn(manifest)
    }

    #[cfg(not(feature = "pjrt"))]
    fn spawn_backend(manifest: Manifest) -> Result<DeviceHandle> {
        Ok(Self::host(manifest))
    }

    /// Host backend over an in-memory manifest (no files needed).
    pub fn host(manifest: Manifest) -> DeviceHandle {
        DeviceHandle { inner: Inner::Host(Arc::new(HostBackend::new(manifest))) }
    }

    /// Global handle over the default artifact dir (lazy).
    pub fn global() -> Result<&'static DeviceHandle> {
        static HANDLE: OnceLock<std::result::Result<DeviceHandle, String>> = OnceLock::new();
        static INIT: Mutex<()> = Mutex::new(());
        let _g = INIT.lock().unwrap();
        let r = HANDLE.get_or_init(|| {
            DeviceHandle::spawn(&Manifest::default_dir()).map_err(|e| format!("{e:#}"))
        });
        r.as_ref().map_err(|e| anyhow::anyhow!("device init failed: {e}"))
    }

    /// Synchronous execute.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        match &self.inner {
            Inner::Host(h) => h.execute(artifact, &inputs),
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(tx) => pjrt::execute(tx, artifact, inputs),
        }
    }

    /// Compile (PJRT) or validate (host) an artifact ahead of first use.
    pub fn warm(&self, artifact: &str) -> Result<()> {
        match &self.inner {
            Inner::Host(h) => h.warm(artifact),
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(tx) => pjrt::warm(tx, artifact),
        }
    }

    /// Per-artifact execute counts.
    pub fn stats(&self) -> Result<BTreeMap<String, u64>> {
        match &self.inner {
            Inner::Host(h) => Ok(h.stats()),
            #[cfg(feature = "pjrt")]
            Inner::Pjrt(tx) => pjrt::stats(tx),
        }
    }
}

/// The PJRT device thread. Requires the external `xla` bindings crate;
/// the module only compiles with `--features pjrt`.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use anyhow::{anyhow, Context};
    use std::sync::mpsc::{channel, Sender};

    pub(super) enum Cmd {
        Execute {
            artifact: String,
            inputs: Vec<HostTensor>,
            reply: Sender<Result<Vec<HostTensor>>>,
        },
        Warm { artifact: String, reply: Sender<Result<()>> },
        Stats { reply: Sender<BTreeMap<String, u64>> },
    }

    pub(super) fn spawn(manifest: Manifest) -> Result<DeviceHandle> {
        let (tx, rx) = channel::<Cmd>();
        std::thread::Builder::new()
            .name("drrl-device".into())
            .spawn(move || device_main(manifest, rx))
            .context("spawning device thread")?;
        Ok(DeviceHandle { inner: Inner::Pjrt(tx) })
    }

    pub(super) fn execute(
        tx: &Sender<Cmd>,
        artifact: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        tx.send(Cmd::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    pub(super) fn warm(tx: &Sender<Cmd>, artifact: &str) -> Result<()> {
        let (reply, rx) = channel();
        tx.send(Cmd::Warm { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))?
    }

    pub(super) fn stats(tx: &Sender<Cmd>) -> Result<BTreeMap<String, u64>> {
        let (reply, rx) = channel();
        tx.send(Cmd::Stats { reply }).map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped reply"))
    }

    struct LoadedExe {
        exe: xla::PjRtLoadedExecutable,
        calls: u64,
    }

    fn device_main(manifest: Manifest, rx: std::sync::mpsc::Receiver<Cmd>) {
        let client = match xla::PjRtClient::cpu() {
            Ok(c) => c,
            Err(e) => {
                eprintln!("FATAL: PJRT CPU client: {e}");
                // Drain commands with errors so callers fail fast.
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Execute { reply, .. } => {
                            let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                        }
                        Cmd::Warm { reply, .. } => {
                            let _ = reply.send(Err(anyhow!("PJRT client unavailable")));
                        }
                        Cmd::Stats { reply } => {
                            let _ = reply.send(BTreeMap::new());
                        }
                    }
                }
                return;
            }
        };
        let mut cache: BTreeMap<String, LoadedExe> = BTreeMap::new();

        let load = |client: &xla::PjRtClient,
                    cache: &mut BTreeMap<String, LoadedExe>,
                    manifest: &Manifest,
                    name: &str|
         -> Result<()> {
            if cache.contains_key(name) {
                return Ok(());
            }
            let path = manifest.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            cache.insert(name.to_string(), LoadedExe { exe, calls: 0 });
            Ok(())
        };

        while let Ok(cmd) = rx.recv() {
            match cmd {
                Cmd::Warm { artifact, reply } => {
                    let _ = reply.send(load(&client, &mut cache, &manifest, &artifact));
                }
                Cmd::Stats { reply } => {
                    let _ =
                        reply.send(cache.iter().map(|(k, v)| (k.clone(), v.calls)).collect());
                }
                Cmd::Execute { artifact, inputs, reply } => {
                    let result = (|| -> Result<Vec<HostTensor>> {
                        load(&client, &mut cache, &manifest, &artifact)?;
                        let entry = cache.get_mut(&artifact).unwrap();
                        entry.calls += 1;
                        let lits: Vec<xla::Literal> =
                            inputs.iter().map(to_literal).collect::<Result<_>>()?;
                        let bufs = entry.exe.execute::<xla::Literal>(&lits)?;
                        let out = bufs[0][0].to_literal_sync()?;
                        let parts = out.to_tuple()?;
                        parts.iter().map(from_literal).collect()
                    })();
                    let _ = reply.send(result);
                }
            }
        }
    }

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        match t {
            HostTensor::F32 { data, dims } => Ok(xla::Literal::vec1(data).reshape(dims)?),
            HostTensor::I32 { data, dims } => Ok(xla::Literal::vec1(data).reshape(dims)?),
        }
    }

    fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
        let shape = l.array_shape()?;
        let dims = shape.dims().to_vec();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { data: l.to_vec::<f32>()?, dims }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { data: l.to_vec::<i32>()?, dims }),
            other => {
                // Convert anything else (f64/bf16/…) through F32.
                let conv = l.convert(xla::PrimitiveType::F32)?;
                let _ = other;
                Ok(HostTensor::F32 { data: conv.to_vec::<f32>()?, dims })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn handle() -> Option<&'static DeviceHandle> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        DeviceHandle::global().ok()
    }

    #[test]
    fn executes_full_attn_artifact() {
        let Some(h) = handle() else { return };
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let (n, d) = (m.kernel.seq_len, m.kernel.head_dim);
        let q: Vec<f32> = (0..n * d).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let t = |v: &[f32]| HostTensor::f32(v.to_vec(), &[n as i64, d as i64]);
        let out = h.execute("full_attn", vec![t(&q), t(&q), t(&q)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims(), &[n as i64, d as i64]);
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stats_count_executions() {
        let Some(h) = handle() else { return };
        let before = h.stats().unwrap().get("power_iter").copied().unwrap_or(0);
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let n = m.kernel.seq_len;
        let mat: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32 * 0.1).collect();
        let v0: Vec<f32> = (0..n).map(|i| 1.0 + (i % 3) as f32).collect();
        h.execute(
            "power_iter",
            vec![
                HostTensor::f32(mat, &[n as i64, n as i64]),
                HostTensor::f32(v0, &[n as i64]),
            ],
        )
        .unwrap();
        let after = h.stats().unwrap()["power_iter"];
        assert_eq!(after, before + 1);
    }

    #[test]
    fn unknown_artifact_errors_cleanly() {
        let Some(h) = handle() else { return };
        let err = h.execute("nonexistent", vec![]).unwrap_err();
        assert!(format!("{err:#}").contains("nonexistent"));
    }

    #[test]
    fn handle_is_send_and_clonable() {
        let Some(h) = handle() else { return };
        let h2 = h.clone();
        let t = std::thread::spawn(move || h2.stats().map(|s| s.len()));
        t.join().unwrap().unwrap();
    }

    #[test]
    fn host_handle_works_without_artifacts() {
        // The host backend needs no files: synthetic manifest end-to-end.
        let h = DeviceHandle::host(Manifest::synthetic(16, 4));
        let q: Vec<f32> = (0..16 * 4).map(|i| (i % 5) as f32 * 0.1).collect();
        let t = |v: &[f32]| HostTensor::f32(v.to_vec(), &[16, 4]);
        let out = h.execute("full_attn", vec![t(&q), t(&q), t(&q)]).unwrap();
        assert_eq!(out[0].dims(), &[16, 4]);
        assert_eq!(h.stats().unwrap()["full_attn"], 1);
    }
}
