//! Host-side tensors that cross the device-thread boundary.
//!
//! The `xla` crate's `PjRtClient` / `Literal` wrap `Rc`/raw handles and
//! are not `Send`, so all PJRT objects live on one dedicated device
//! thread (runtime::device). Everything that crosses the channel is a
//! plain `HostTensor`.

use crate::linalg::Mat;

/// A host tensor (row-major) with shape.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, dims: &[i64]) -> Self {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        HostTensor::F32 { data, dims: dims.to_vec() }
    }

    pub fn i32(data: Vec<i32>, dims: &[i64]) -> Self {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        HostTensor::I32 { data, dims: dims.to_vec() }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], dims: vec![] }
    }

    pub fn from_mat(m: &Mat) -> Self {
        HostTensor::f32(m.to_f32(), &[m.rows() as i64, m.cols() as i64])
    }

    pub fn from_f64s(v: &[f64]) -> Self {
        HostTensor::f32(v.iter().map(|&x| x as f32).collect(), &[v.len() as i64])
    }

    pub fn dims(&self) -> &[i64] {
        match self {
            HostTensor::F32 { dims, .. } | HostTensor::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Expect an f32 tensor, returning its data.
    pub fn expect_f32(self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } => data,
            HostTensor::I32 { .. } => panic!("expected f32 tensor, got i32"),
        }
    }

    pub fn to_mat(&self, rows: usize, cols: usize) -> Mat {
        let data = self.as_f32().expect("f32 tensor");
        assert_eq!(data.len(), rows * cols);
        Mat::from_f32(rows, cols, data)
    }

    /// First element as f64 (scalar outputs like losses).
    pub fn scalar(&self) -> f64 {
        match self {
            HostTensor::F32 { data, .. } => data[0] as f64,
            HostTensor::I32 { data, .. } => data[0] as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn mat_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let m = Mat::randn(3, 4, 1.0, &mut rng);
        let t = HostTensor::from_mat(&m);
        assert_eq!(t.dims(), &[3, 4]);
        assert!(t.to_mat(3, 4).allclose(&m, 1e-6));
    }

    #[test]
    fn scalar_and_accessors() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.scalar(), 2.5);
        assert!(t.as_i32().is_none());
        let ti = HostTensor::i32(vec![1, 2], &[2]);
        assert_eq!(ti.as_i32().unwrap(), &[1, 2]);
        assert_eq!(ti.scalar(), 1.0);
    }

    #[test]
    #[should_panic]
    fn expect_f32_panics_on_i32() {
        HostTensor::i32(vec![1], &[1]).expect_f32();
    }
}
