//! Typed high-level entry points over the device thread: rank-bucket
//! dispatch for the masked factor-attention kernel, full attention,
//! power iteration, the transformer policy and the LM train/eval/logits
//! graphs.

use super::device::DeviceHandle;
use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::linalg::{Mat, Svd};
use anyhow::Result;

/// High-level artifact API used by the coordinator and trainers.
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    pub device: DeviceHandle,
    /// Lazily loaded transformer-policy weights (runtime argument to the
    /// policy artifact — see DESIGN.md §9 on constant elision).
    policy_weights: std::sync::OnceLock<Vec<f32>>,
}

impl ArtifactRegistry {
    pub fn open_default() -> Result<Self> {
        Self::open(&Manifest::default_dir())
    }

    pub fn open(dir: &std::path::Path) -> Result<Self> {
        Ok(ArtifactRegistry {
            manifest: Manifest::load(dir)?,
            device: DeviceHandle::spawn(dir)?,
            policy_weights: std::sync::OnceLock::new(),
        })
    }

    /// Registry over the pure-Rust host backend with a synthetic manifest
    /// (no artifacts on disk). `kernel_seq_len`/`head_dim` size the
    /// attention kernels; the LM uses a small fixed shape. The AOT-only
    /// entry points (`policy_net`, `lm_train_step`) return errors — use
    /// non-Hlo policy sources with host registries.
    pub fn open_host(kernel_seq_len: usize, head_dim: usize) -> Self {
        let manifest = Manifest::synthetic(kernel_seq_len, head_dim);
        ArtifactRegistry {
            device: DeviceHandle::host(manifest.clone()),
            manifest,
            policy_weights: std::sync::OnceLock::new(),
        }
    }

    /// Load (once) the flat policy weight vector from its sidecar file.
    fn policy_weights(&self) -> Result<&[f32]> {
        if let Some(w) = self.policy_weights.get() {
            return Ok(w);
        }
        let path = self.manifest.dir.join(&self.manifest.policy.params_file);
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading policy weights {path:?}: {e}"))?;
        anyhow::ensure!(
            bytes.len() == self.manifest.policy.param_count * 4,
            "policy weight file size {} vs manifest count {}",
            bytes.len(),
            self.manifest.policy.param_count
        );
        let w: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let _ = self.policy_weights.set(w);
        Ok(self.policy_weights.get().unwrap())
    }

    /// Smallest compiled rank bucket ≥ the requested rank (DESIGN.md §9);
    /// falls back to the largest bucket.
    pub fn rank_bucket(&self, rank: usize) -> usize {
        let buckets = &self.manifest.kernel.rank_buckets;
        buckets
            .iter()
            .copied()
            .filter(|&b| b >= rank)
            .min()
            .unwrap_or_else(|| *buckets.iter().max().expect("non-empty buckets"))
    }

    /// Masked factor attention on the device: Y = U·diag(s⊙mask)·(Vᵀ·V).
    pub fn lowrank_attention(&self, svd: &Svd, rank: usize, v_val: &Mat) -> Result<Mat> {
        let bucket = self.rank_bucket(rank);
        let n = self.manifest.kernel.seq_len;
        let d = self.manifest.kernel.head_dim;
        anyhow::ensure!(
            svd.u.rows() == n && v_val.rows() == n && v_val.cols() == d,
            "artifact shape mismatch: svd {}x{}, v {:?} vs kernel {n}x{d}",
            svd.u.rows(),
            svd.u.cols(),
            v_val.shape()
        );
        anyhow::ensure!(svd.s.len() >= bucket, "need ≥{bucket} factors, have {}", svd.s.len());
        let u = svd.u.take_cols(bucket);
        let vt = svd.v.take_cols(bucket).transpose();
        let s: Vec<f64> = svd.s[..bucket].to_vec();
        let rank = rank.min(bucket);
        let mask: Vec<f32> = (0..bucket).map(|i| if i < rank { 1.0 } else { 0.0 }).collect();
        let out = self.device.execute(
            &format!("lowrank_attn_r{bucket}"),
            vec![
                HostTensor::from_mat(&u),
                HostTensor::from_f64s(&s),
                HostTensor::from_mat(&vt),
                HostTensor::from_mat(v_val),
                HostTensor::f32(mask, &[bucket as i64]),
            ],
        )?;
        Ok(out[0].to_mat(n, d))
    }

    /// Full attention kernel on the device.
    pub fn full_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        let n = self.manifest.kernel.seq_len;
        let d = self.manifest.kernel.head_dim;
        anyhow::ensure!(q.shape() == (n, d), "q shape {:?} vs kernel {n}x{d}", q.shape());
        let out = self.device.execute(
            "full_attn",
            vec![HostTensor::from_mat(q), HostTensor::from_mat(k), HostTensor::from_mat(v)],
        )?;
        Ok(out[0].to_mat(n, d))
    }

    /// Device-side power-iteration spectral norm.
    pub fn power_iter_sigma(&self, m: &Mat, v0: &[f64]) -> Result<f64> {
        let out = self
            .device
            .execute("power_iter", vec![HostTensor::from_mat(m), HostTensor::from_f64s(v0)])?;
        Ok(out[0].scalar())
    }

    /// Transformer-policy logits (baked weights).
    pub fn policy_logits(&self, state: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(
            state.len() == self.manifest.policy.state_dim,
            "state dim {} vs manifest {}",
            state.len(),
            self.manifest.policy.state_dim
        );
        let weights = self.policy_weights()?.to_vec();
        let wlen = weights.len() as i64;
        let out = self.device.execute(
            "policy_net",
            vec![HostTensor::f32(weights, &[wlen]), HostTensor::from_f64s(state)],
        )?;
        Ok(out[0].as_f32().unwrap().iter().map(|&x| x as f64).collect())
    }

    // ---- LM graphs (e2e training / eval / serving) ----

    /// One fused AdamW train step. State tensors are (P,)-vectors.
    #[allow(clippy::too_many_arguments)]
    pub fn lm_train_step(
        &self,
        params: &mut Vec<f32>,
        adam_m: &mut Vec<f32>,
        adam_v: &mut Vec<f32>,
        step: f32,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f64> {
        let lm = &self.manifest.lm;
        let p = lm.param_count as i64;
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let out = self.device.execute(
            "lm_train_step",
            vec![
                HostTensor::f32(std::mem::take(params), &[p]),
                HostTensor::f32(std::mem::take(adam_m), &[p]),
                HostTensor::f32(std::mem::take(adam_v), &[p]),
                HostTensor::scalar_f32(step),
                HostTensor::i32(tokens.to_vec(), &bl),
                HostTensor::i32(targets.to_vec(), &bl),
            ],
        )?;
        anyhow::ensure!(out.len() == 4, "train_step returns 4 outputs, got {}", out.len());
        let mut it = out.into_iter();
        *params = it.next().unwrap().expect_f32();
        *adam_m = it.next().unwrap().expect_f32();
        *adam_v = it.next().unwrap().expect_f32();
        Ok(it.next().unwrap().scalar())
    }

    /// Evaluation loss on one batch.
    pub fn lm_eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f64> {
        let lm = &self.manifest.lm;
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let out = self.device.execute(
            "lm_eval_loss",
            vec![
                HostTensor::f32(params.to_vec(), &[lm.param_count as i64]),
                HostTensor::i32(tokens.to_vec(), &bl),
                HostTensor::i32(targets.to_vec(), &bl),
            ],
        )?;
        Ok(out[0].scalar())
    }

    /// Inference logits (Pallas-kernel trunk): (B·L·V) flattened.
    pub fn lm_logits(&self, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        let lm = &self.manifest.lm;
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let out = self.device.execute(
            "lm_logits",
            vec![
                HostTensor::f32(params.to_vec(), &[lm.param_count as i64]),
                HostTensor::i32(tokens.to_vec(), &bl),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().expect_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{attention_matrix, AttnInputs};
    use crate::linalg::top_k_svd;
    use crate::util::Pcg32;

    fn registry() -> Option<ArtifactRegistry> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ArtifactRegistry::open(&dir).unwrap())
    }

    #[test]
    fn bucket_selection() {
        let Some(reg) = registry() else { return };
        assert_eq!(reg.rank_bucket(16), 16);
        assert_eq!(reg.rank_bucket(20), 32);
        assert_eq!(reg.rank_bucket(64), 64);
        assert_eq!(reg.rank_bucket(100), 64);
    }

    #[test]
    fn lowrank_kernel_matches_rust_reference() {
        let Some(reg) = registry() else { return };
        let n = reg.manifest.kernel.seq_len;
        let d = reg.manifest.kernel.head_dim;
        let mut rng = Pcg32::seeded(7);
        let inp = AttnInputs {
            q: Mat::randn(n, d, 0.7, &mut rng),
            k: Mat::randn(n, d, 0.7, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: true,
        };
        let a = attention_matrix(&inp);
        let rank = 20; // → bucket 32
        let svd = top_k_svd(&a, reg.rank_bucket(rank), 3);
        let via_device = reg.lowrank_attention(&svd, rank, &inp.v).unwrap();
        let on_host = crate::attention::lowrank_attention_output(&svd, rank, &inp.v);
        let diff = via_device.max_abs_diff(&on_host);
        assert!(diff < 1e-4, "device vs host diff {diff}");
    }

    #[test]
    fn full_attention_kernel_matches_rust_reference() {
        let Some(reg) = registry() else { return };
        let n = reg.manifest.kernel.seq_len;
        let d = reg.manifest.kernel.head_dim;
        let mut rng = Pcg32::seeded(8);
        let inp = AttnInputs {
            q: Mat::randn(n, d, 0.5, &mut rng),
            k: Mat::randn(n, d, 0.5, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: true,
        };
        let dev = reg.full_attention(&inp.q, &inp.k, &inp.v).unwrap();
        let host = crate::attention::full_attention(&inp);
        assert!(dev.max_abs_diff(&host) < 1e-4);
    }

    #[test]
    fn policy_artifact_emits_grid_logits() {
        let Some(reg) = registry() else { return };
        let state = vec![0.1; reg.manifest.policy.state_dim];
        let logits = reg.policy_logits(&state).unwrap();
        assert_eq!(logits.len(), reg.manifest.policy.n_actions);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lm_train_step_reduces_loss_on_repeated_batch() {
        let Some(reg) = registry() else { return };
        let lm = &reg.manifest.lm;
        let p = lm.param_count;
        let mut rng = Pcg32::seeded(10);
        // GPT-style init on the Rust side (artifact owns no state).
        let mut params: Vec<f32> = (0..p).map(|_| (rng.normal() * 0.02) as f32).collect();
        let mut m = vec![0f32; p];
        let mut v = vec![0f32; p];
        let bl = lm.batch * lm.seq_len;
        let tokens: Vec<i32> = (0..bl).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let first = reg.lm_train_step(&mut params, &mut m, &mut v, 0.0, &tokens, &targets).unwrap();
        let mut last = first;
        for s in 1..8 {
            last = reg
                .lm_train_step(&mut params, &mut m, &mut v, s as f32, &tokens, &targets)
                .unwrap();
        }
        assert!(last < first, "loss did not drop: {first} → {last}");
        // Eval loss agrees with the train-path loss on identical data.
        let eval = reg.lm_eval_loss(&params, &tokens, &targets).unwrap();
        assert!((eval - last).abs() / last < 0.5, "eval {eval} vs train {last}");
    }

    #[test]
    fn lm_logits_shape() {
        let Some(reg) = registry() else { return };
        let lm = &reg.manifest.lm;
        let mut rng = Pcg32::seeded(11);
        let params: Vec<f32> =
            (0..lm.param_count).map(|_| (rng.normal() * 0.02) as f32).collect();
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let logits = reg.lm_logits(&params, &tokens).unwrap();
        assert_eq!(logits.len(), lm.batch * lm.seq_len * lm.vocab);
    }
}
