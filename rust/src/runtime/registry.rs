//! Typed high-level entry points over a pluggable [`Backend`]: shape and
//! rank-bucket validation for the masked factor-attention op, full
//! attention, power iteration, the transformer policy and the LM
//! train/eval/logits graphs.
//!
//! The registry is a *thin adapter*: it owns the manifest (the single
//! source of truth for shapes), validates every call against it, rounds
//! requested ranks to compiled buckets, resolves policy weights, and
//! then dispatches to the typed trait methods of the backend it owns.
//! No artifact-name strings cross this boundary in either direction.

use super::backend::{Backend, Capabilities, Op, OpCounters};
use super::host::HostBackend;
use super::manifest::Manifest;
use super::sim::SimBackend;
use crate::linalg::{Mat, Svd};
use crate::sim::DeviceProfile;
use anyhow::Result;
use std::sync::Arc;

/// High-level execution API used by the coordinator and trainers: a
/// manifest plus the backend instance it validates calls for. Engines
/// own one registry each (`Arc`-shared across their workers).
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    /// Lazily resolved transformer-policy weights (runtime argument to
    /// the policy op — see DESIGN.md §9 on constant elision). Loaded
    /// from the sidecar file for artifact manifests; synthesized
    /// deterministically for synthetic ones.
    policy_weights: std::sync::OnceLock<Vec<f32>>,
}

impl ArtifactRegistry {
    pub fn open_default() -> Result<Self> {
        Self::open(&Manifest::default_dir())
    }

    /// Registry over the artifacts in `dir`. With the `pjrt` feature the
    /// backend is the PJRT device thread; otherwise the manifest's
    /// shapes drive the host backend.
    pub fn open(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        #[cfg(feature = "pjrt")]
        let backend: Box<dyn Backend> =
            Box::new(super::device::PjrtBackend::spawn(manifest.clone())?);
        #[cfg(not(feature = "pjrt"))]
        let backend: Box<dyn Backend> = Box::new(HostBackend::new(manifest.clone()));
        Ok(Self::with_backend(manifest, backend))
    }

    /// Registry over the pure-Rust host backend with a synthetic
    /// manifest (no artifacts on disk). `kernel_seq_len`/`head_dim` size
    /// the attention kernels; the LM and policy use small fixed shapes.
    /// Every op is available — `PolicySource::Hlo` and `LmTrainer` run
    /// fully offline.
    pub fn open_host(kernel_seq_len: usize, head_dim: usize) -> Self {
        let manifest = Manifest::synthetic(kernel_seq_len, head_dim);
        let backend = Box::new(HostBackend::new(manifest.clone()));
        Self::with_backend(manifest, backend)
    }

    /// Registry over the hardware-simulating backend: host kernels plus
    /// a roofline latency model for `profile` (see
    /// [`ArtifactRegistry::projected_ms`]).
    pub fn open_sim(kernel_seq_len: usize, head_dim: usize, profile: DeviceProfile) -> Self {
        let manifest = Manifest::synthetic(kernel_seq_len, head_dim);
        let backend = Box::new(SimBackend::new(manifest.clone(), profile));
        Self::with_backend(manifest, backend)
    }

    /// Registry from a `--backend` spec string — the single parser every
    /// CLI/example shares:
    ///
    /// * `auto` — artifacts if present, else the host backend;
    /// * `host` — pure-Rust host backend, synthetic manifest;
    /// * `sim[:a100|apple-m|cpu]` — host kernels + roofline latency
    ///   projection (default profile `a100`);
    /// * `pjrt` — the device backend; errors unless built with
    ///   `--features pjrt`.
    ///
    /// Unknown kinds and profiles are rejected, never silently remapped.
    pub fn open_spec(spec: &str) -> Result<Self> {
        let (kind, profile) = match spec.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (spec, None),
        };
        anyhow::ensure!(
            profile.is_none() || kind == "sim",
            "backend '{kind}' takes no ':profile' suffix"
        );
        match kind {
            "auto" => Ok(match Self::open_default() {
                Ok(r) => r,
                Err(e) => {
                    crate::log_warn!(
                        "artifacts unavailable ({e:#}); using the pure-Rust host backend"
                    );
                    Self::open_host(128, 32)
                }
            }),
            "host" => Ok(Self::open_host(128, 32)),
            "sim" => {
                let key = profile.unwrap_or("a100");
                let profile = DeviceProfile::by_name(key).ok_or_else(|| {
                    anyhow::anyhow!("unknown sim profile '{key}' (expected a100|apple-m|cpu)")
                })?;
                Ok(Self::open_sim(128, 32, profile))
            }
            "pjrt" => {
                #[cfg(feature = "pjrt")]
                {
                    Self::open_default()
                }
                #[cfg(not(feature = "pjrt"))]
                {
                    anyhow::bail!(
                        "backend 'pjrt' requires building with `--features pjrt` \
                         (this binary only has the host and sim backends)"
                    )
                }
            }
            other => anyhow::bail!("unknown backend '{other}' (auto|host|sim[:profile]|pjrt)"),
        }
    }

    /// Registry over an explicit backend instance (tests, custom
    /// deployments).
    pub fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Self {
        ArtifactRegistry {
            manifest,
            backend,
            policy_weights: std::sync::OnceLock::new(),
        }
    }

    /// The backend executing this registry's ops.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn capabilities(&self) -> Capabilities {
        self.backend.capabilities()
    }

    /// Shared per-op execute counters (folded into
    /// `coordinator::Metrics::report()` by the serving engine).
    pub fn ops(&self) -> Arc<OpCounters> {
        self.backend.ops()
    }

    /// Cumulative projected device latency, when the backend models one.
    pub fn projected_ms(&self) -> Option<f64> {
        self.backend.projected_ms()
    }

    /// The backend's projected-latency ledger, for scoped (delta) reads.
    pub fn latency_ledger(&self) -> Option<&crate::runtime::backend::LatencyLedger> {
        self.backend.latency_ledger()
    }

    /// The device profile the backend's latency model projects onto
    /// (`Some` for the sim backend). Serving attributes per-request
    /// `projected_ms` with this profile so its ledger matches the
    /// backend's charge-for-charge.
    pub fn device_profile(&self) -> Option<DeviceProfile> {
        self.backend.device_profile()
    }

    /// THE precedence rule for latency projection, shared by the serving
    /// engine, the rank controller and the CLIs: a backend that models
    /// latency always wins (its ledger is the ground truth projected
    /// figures must match), else the caller's configured reward profile.
    pub fn projection_profile(
        &self,
        reward_profile: Option<DeviceProfile>,
    ) -> Option<DeviceProfile> {
        self.device_profile().or(reward_profile)
    }

    /// Warm every supported op (compile artifacts ahead of first use on
    /// PJRT; validation elsewhere).
    pub fn warm_all(&self) -> Result<()> {
        self.warm_ops(&Op::ALL)
    }

    /// Warm a subset of ops, silently skipping ones the backend does not
    /// support (serving demos warm only the kernels they exercise).
    pub fn warm_ops(&self, ops: &[Op]) -> Result<()> {
        let caps = self.backend.capabilities();
        for &op in ops {
            if caps.supports(op) {
                self.backend.warm(op)?;
            }
        }
        Ok(())
    }

    /// Load (or synthesize) the flat policy weight vector once.
    fn policy_weights(&self) -> Result<&[f32]> {
        if let Some(w) = self.policy_weights.get() {
            return Ok(w);
        }
        let w = if self.manifest.is_synthetic() {
            super::host_policy::synthesize_weights(&self.manifest.policy, 0x9011C7)
        } else {
            let path = self.manifest.dir.join(&self.manifest.policy.params_file);
            let bytes = std::fs::read(&path)
                .map_err(|e| anyhow::anyhow!("reading policy weights {path:?}: {e}"))?;
            anyhow::ensure!(
                bytes.len() == self.manifest.policy.param_count * 4,
                "policy weight file size {} vs manifest count {}",
                bytes.len(),
                self.manifest.policy.param_count
            );
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        };
        let _ = self.policy_weights.set(w);
        Ok(self.policy_weights.get().unwrap())
    }

    /// Smallest compiled rank bucket ≥ the requested rank; falls back to
    /// the largest bucket. Delegates to the single hoisted definition on
    /// [`super::KernelShape`].
    pub fn rank_bucket(&self, rank: usize) -> usize {
        self.manifest.kernel.rank_bucket(rank)
    }

    /// Masked factor attention: Y = U·diag(s⊙mask)·(Vᵀ·V).
    pub fn lowrank_attention(&self, svd: &Svd, rank: usize, v_val: &Mat) -> Result<Mat> {
        let bucket = self.rank_bucket(rank);
        let n = self.manifest.kernel.seq_len;
        let d = self.manifest.kernel.head_dim;
        anyhow::ensure!(
            svd.u.rows() == n && v_val.rows() == n && v_val.cols() == d,
            "kernel shape mismatch: svd {}x{}, v {:?} vs kernel {n}x{d}",
            svd.u.rows(),
            svd.u.cols(),
            v_val.shape()
        );
        anyhow::ensure!(svd.s.len() >= bucket, "need ≥{bucket} factors, have {}", svd.s.len());
        self.backend.lowrank_attention(svd, bucket, rank.min(bucket), v_val)
    }

    /// Full attention kernel.
    pub fn full_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        let n = self.manifest.kernel.seq_len;
        let d = self.manifest.kernel.head_dim;
        anyhow::ensure!(q.shape() == (n, d), "q shape {:?} vs kernel {n}x{d}", q.shape());
        self.backend.full_attention(q, k, v)
    }

    /// Power-iteration spectral norm.
    pub fn power_iter_sigma(&self, m: &Mat, v0: &[f64]) -> Result<f64> {
        anyhow::ensure!(v0.len() == m.cols(), "v0 length {} vs {} cols", v0.len(), m.cols());
        self.backend.power_iter_sigma(m, v0)
    }

    /// Transformer-policy logits over the rank grid.
    pub fn policy_logits(&self, state: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(
            state.len() == self.manifest.policy.state_dim,
            "state dim {} vs manifest {}",
            state.len(),
            self.manifest.policy.state_dim
        );
        let weights = self.policy_weights()?;
        self.backend.policy_logits(weights, state)
    }

    // ---- LM graphs (e2e training / eval / serving) ----

    fn check_lm_batch(&self, what: &str, t: &[i32]) -> Result<()> {
        let lm = &self.manifest.lm;
        anyhow::ensure!(
            t.len() == lm.batch * lm.seq_len,
            "{what}: got {} tokens, want {}x{}",
            t.len(),
            lm.batch,
            lm.seq_len
        );
        Ok(())
    }

    fn check_lm_params(&self, p: &[f32]) -> Result<()> {
        anyhow::ensure!(
            p.len() == self.manifest.lm.param_count,
            "param vector len {} vs manifest {}",
            p.len(),
            self.manifest.lm.param_count
        );
        Ok(())
    }

    /// One fused AdamW train step. State vectors are (P,)-shaped.
    #[allow(clippy::too_many_arguments)]
    pub fn lm_train_step(
        &self,
        params: &mut Vec<f32>,
        adam_m: &mut Vec<f32>,
        adam_v: &mut Vec<f32>,
        step: f32,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f64> {
        self.check_lm_params(params)?;
        self.check_lm_batch("tokens", tokens)?;
        self.check_lm_batch("targets", targets)?;
        self.backend.lm_train_step(params, adam_m, adam_v, step, tokens, targets)
    }

    /// Evaluation loss on one batch.
    pub fn lm_eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f64> {
        self.check_lm_params(params)?;
        self.check_lm_batch("tokens", tokens)?;
        self.check_lm_batch("targets", targets)?;
        self.backend.lm_eval_loss(params, tokens, targets)
    }

    /// Inference logits: (B·L·V) flattened.
    pub fn lm_logits(&self, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.check_lm_params(params)?;
        self.check_lm_batch("tokens", tokens)?;
        self.backend.lm_logits(params, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{attention_matrix, AttnInputs};
    use crate::linalg::top_k_svd;
    use crate::util::Pcg32;

    fn registry() -> Option<ArtifactRegistry> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(ArtifactRegistry::open(&dir).unwrap())
    }

    #[test]
    fn bucket_selection_on_host_registry() {
        let reg = ArtifactRegistry::open_host(64, 16);
        assert_eq!(reg.rank_bucket(16), 16);
        assert_eq!(reg.rank_bucket(20), 32);
        assert_eq!(reg.rank_bucket(64), 64);
        assert_eq!(reg.rank_bucket(100), 64);
    }

    #[test]
    fn registry_validates_shapes_before_dispatch() {
        let reg = ArtifactRegistry::open_host(32, 8);
        let mut rng = Pcg32::seeded(1);
        let wrong = Mat::randn(16, 8, 1.0, &mut rng);
        assert!(reg.full_attention(&wrong, &wrong, &wrong).is_err());
        assert!(reg.policy_logits(&[0.0; 4]).is_err());
        assert!(reg.lm_logits(&[0.0f32; 4], &[0i32; 4]).is_err());
        // Dispatch never happened: the backend op counters stay zero.
        assert_eq!(reg.ops().total(), 0);
    }

    #[test]
    fn host_registry_reports_backend_and_capabilities() {
        let reg = ArtifactRegistry::open_host(32, 8);
        assert_eq!(reg.backend_name(), "host");
        assert!(reg.capabilities().supports(Op::LmTrainStep));
        assert!(reg.projected_ms().is_none());
        assert!(reg.warm_all().is_ok());
        let sim = ArtifactRegistry::open_sim(32, 8, DeviceProfile::A100);
        assert_eq!(sim.backend_name(), "sim");
        assert_eq!(sim.projected_ms(), Some(0.0));
    }

    #[test]
    fn open_spec_parses_backends_and_rejects_typos() {
        assert_eq!(ArtifactRegistry::open_spec("host").unwrap().backend_name(), "host");
        assert_eq!(ArtifactRegistry::open_spec("sim").unwrap().backend_name(), "sim");
        assert_eq!(
            ArtifactRegistry::open_spec("sim:apple-m").unwrap().backend_name(),
            "sim"
        );
        assert!(ArtifactRegistry::open_spec("hots").is_err(), "typo must be rejected");
        assert!(ArtifactRegistry::open_spec("sim:foo").is_err(), "unknown profile rejected");
        assert!(ArtifactRegistry::open_spec("host:a100").is_err(), "profile on non-sim");
        #[cfg(not(feature = "pjrt"))]
        assert!(
            ArtifactRegistry::open_spec("pjrt").is_err(),
            "pjrt without the feature must error, not silently degrade"
        );
    }

    #[test]
    fn synthetic_policy_weights_resolve_once() {
        let reg = ArtifactRegistry::open_host(32, 8);
        let state = vec![0.2f64; reg.manifest.policy.state_dim];
        let a = reg.policy_logits(&state).unwrap();
        let b = reg.policy_logits(&state).unwrap();
        assert_eq!(a, b, "cached weights must be deterministic");
        assert_eq!(a.len(), reg.manifest.policy.n_actions);
    }

    #[test]
    fn lowrank_kernel_matches_rust_reference() {
        let Some(reg) = registry() else { return };
        let n = reg.manifest.kernel.seq_len;
        let d = reg.manifest.kernel.head_dim;
        let mut rng = Pcg32::seeded(7);
        let inp = AttnInputs {
            q: Mat::randn(n, d, 0.7, &mut rng),
            k: Mat::randn(n, d, 0.7, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: true,
        };
        let a = attention_matrix(&inp);
        let rank = 20; // → bucket 32
        let svd = top_k_svd(&a, reg.rank_bucket(rank), 3);
        let via_backend = reg.lowrank_attention(&svd, rank, &inp.v).unwrap();
        let on_host = crate::attention::lowrank_attention_output(&svd, rank, &inp.v);
        let diff = via_backend.max_abs_diff(&on_host);
        assert!(diff < 1e-4, "backend vs host diff {diff}");
    }

    #[test]
    fn full_attention_kernel_matches_rust_reference() {
        let Some(reg) = registry() else { return };
        let n = reg.manifest.kernel.seq_len;
        let d = reg.manifest.kernel.head_dim;
        let mut rng = Pcg32::seeded(8);
        let inp = AttnInputs {
            q: Mat::randn(n, d, 0.5, &mut rng),
            k: Mat::randn(n, d, 0.5, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: true,
        };
        let dev = reg.full_attention(&inp.q, &inp.k, &inp.v).unwrap();
        let host = crate::attention::full_attention(&inp);
        assert!(dev.max_abs_diff(&host) < 1e-4);
    }

    #[test]
    fn policy_artifact_emits_grid_logits() {
        let Some(reg) = registry() else { return };
        let state = vec![0.1; reg.manifest.policy.state_dim];
        let logits = reg.policy_logits(&state).unwrap();
        assert_eq!(logits.len(), reg.manifest.policy.n_actions);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lm_train_step_reduces_loss_on_repeated_batch() {
        let Some(reg) = registry() else { return };
        let lm = &reg.manifest.lm;
        let p = lm.param_count;
        let mut rng = Pcg32::seeded(10);
        // GPT-style init on the Rust side (artifact owns no state).
        let mut params: Vec<f32> = (0..p).map(|_| (rng.normal() * 0.02) as f32).collect();
        let mut m = vec![0f32; p];
        let mut v = vec![0f32; p];
        let bl = lm.batch * lm.seq_len;
        let tokens: Vec<i32> = (0..bl).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let first = reg.lm_train_step(&mut params, &mut m, &mut v, 0.0, &tokens, &targets).unwrap();
        let mut last = first;
        for s in 1..8 {
            last = reg
                .lm_train_step(&mut params, &mut m, &mut v, s as f32, &tokens, &targets)
                .unwrap();
        }
        assert!(last < first, "loss did not drop: {first} → {last}");
        // Eval loss agrees with the train-path loss on identical data.
        let eval = reg.lm_eval_loss(&params, &tokens, &targets).unwrap();
        assert!((eval - last).abs() / last < 0.5, "eval {eval} vs train {last}");
    }

    #[test]
    fn lm_logits_shape() {
        let Some(reg) = registry() else { return };
        let lm = &reg.manifest.lm;
        let mut rng = Pcg32::seeded(11);
        let params: Vec<f32> =
            (0..lm.param_count).map(|_| (rng.normal() * 0.02) as f32).collect();
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let logits = reg.lm_logits(&params, &tokens).unwrap();
        assert_eq!(logits.len(), lm.batch * lm.seq_len * lm.vocab);
    }
}
