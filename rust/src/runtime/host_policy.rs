//! Host forward of the transformer policy network (paper Eq. 7, §4.5.1).
//!
//! π_θ(a|s) = Softmax(MLP(TransformerEncoder(s))) — the same computation
//! `python/compile/policy_net.py` lowers into the `policy_net` artifact:
//! the 33-dim state splits into three semantic tokens (conv features,
//! weight statistics, spectral/positional scalars), projects to
//! `d_model`, runs `n_blocks` pre-LN encoder blocks and pools into a
//! tanh-MLP head over the rank grid. Weights arrive as one flat f32
//! vector in the deterministic `param_order` layout.
//!
//! This closes the host backend's `policy_net` gap: `PolicySource::Hlo`
//! now runs fully offline (synthetic registries generate deterministic
//! weights via [`synthesize_weights`]; artifact-backed registries load
//! the trained sidecar file as before).

use super::manifest::PolicyShape;
use crate::linalg::{matmul, Mat};
use crate::util::Pcg32;
use anyhow::Result;

/// State-token split (must mirror policy_net.py / drrl::rl::state):
/// conv features, weight statistics, and the spectral/positional tail.
const CONV_FEATS: usize = 16;
const WSTAT_FEATS: usize = 9;

struct BlockParams {
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    w1: Mat,
    b1: Vec<f64>,
    w2: Mat,
    b2: Vec<f64>,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
}

struct PolicyParams {
    tok0: Mat, // CONV_FEATS × d
    tok1: Mat, // WSTAT_FEATS × d
    tok2: Mat, // tail × d
    pos: Mat,  // 3 × d
    blocks: Vec<BlockParams>,
    head_w1: Mat,
    head_b1: Vec<f64>,
    head_w2: Mat,
    head_b2: Vec<f64>,
}

fn parse(weights: &[f32], shape: &PolicyShape) -> Result<PolicyParams> {
    anyhow::ensure!(
        weights.len() == shape.flat_param_count(),
        "policy weight vector len {} vs layout {}",
        weights.len(),
        shape.flat_param_count()
    );
    anyhow::ensure!(
        shape.state_dim > CONV_FEATS + WSTAT_FEATS,
        "state dim {} too small for the 16/9/tail token split",
        shape.state_dim
    );
    anyhow::ensure!(
        shape.d_model % shape.n_heads.max(1) == 0,
        "policy d_model {} not divisible by n_heads {}",
        shape.d_model,
        shape.n_heads
    );
    let d = shape.d_model;
    let tail = shape.state_dim - CONV_FEATS - WSTAT_FEATS;
    let mut off = 0usize;
    let mut take_mat = |rows: usize, cols: usize| -> Mat {
        let n = rows * cols;
        let m = Mat::from_f32(rows, cols, &weights[off..off + n]);
        off += n;
        m
    };
    // Order MUST mirror policy_net.py::param_order.
    let tok0 = take_mat(CONV_FEATS, d);
    let tok1 = take_mat(WSTAT_FEATS, d);
    let tok2 = take_mat(tail, d);
    let pos = take_mat(3, d);
    let mut blocks = Vec::with_capacity(shape.n_blocks);
    for _ in 0..shape.n_blocks {
        let wq = take_mat(d, d);
        let wk = take_mat(d, d);
        let wv = take_mat(d, d);
        let wo = take_mat(d, d);
        let ln1_g = take_mat(1, d).into_vec();
        let ln1_b = take_mat(1, d).into_vec();
        let w1 = take_mat(d, 4 * d);
        let b1 = take_mat(1, 4 * d).into_vec();
        let w2 = take_mat(4 * d, d);
        let b2 = take_mat(1, d).into_vec();
        let ln2_g = take_mat(1, d).into_vec();
        let ln2_b = take_mat(1, d).into_vec();
        blocks.push(BlockParams {
            wq, wk, wv, wo, ln1_g, ln1_b, w1, b1, w2, b2, ln2_g, ln2_b,
        });
    }
    let head_w1 = take_mat(d, d);
    let head_b1 = take_mat(1, d).into_vec();
    let head_w2 = take_mat(d, shape.n_actions);
    let head_b2 = take_mat(1, shape.n_actions).into_vec();
    Ok(PolicyParams { tok0, tok1, tok2, pos, blocks, head_w1, head_b1, head_w2, head_b2 })
}

fn layernorm_rows(x: &mut Mat, g: &[f64], b: &[f64]) {
    for i in 0..x.rows() {
        let row = x.row_mut(i);
        let mu = row.iter().sum::<f64>() / row.len() as f64;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / row.len() as f64;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g[j] + b[j];
        }
    }
}

fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + ((2.0 / std::f64::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

/// Non-causal softmax attention over the 3-token sequence for one head
/// slice `[lo, hi)` of q/k/v.
fn head_attention(q: &Mat, k: &Mat, v: &Mat, lo: usize, hi: usize) -> Mat {
    let n = q.rows();
    let hd = hi - lo;
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = Mat::zeros(n, hd);
    for i in 0..n {
        let qi = &q.row(i)[lo..hi];
        let mut scores = vec![0.0f64; n];
        for (j, s) in scores.iter_mut().enumerate() {
            let kj = &k.row(j)[lo..hi];
            *s = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f64>() * scale;
        }
        let max = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0.0;
        for s in scores.iter_mut() {
            *s = (*s - max).exp();
            denom += *s;
        }
        let row = out.row_mut(i);
        for (j, &w) in scores.iter().enumerate() {
            let vj = &v.row(j)[lo..hi];
            let w = w / denom;
            for (o, &x) in row.iter_mut().zip(vj) {
                *o += w * x;
            }
        }
    }
    out
}

/// A parsed policy network, reusable across forwards. The serving hot
/// path runs one forward per segment decision, so the host backend
/// caches this (keyed by a weights fingerprint) instead of re-parsing
/// the flat vector every call.
pub struct PolicyNet {
    shape: PolicyShape,
    p: PolicyParams,
}

impl PolicyNet {
    /// Parse the flat weight vector once.
    pub fn parse(weights: &[f32], shape: &PolicyShape) -> Result<PolicyNet> {
        Ok(PolicyNet { shape: shape.clone(), p: parse(weights, shape)? })
    }

    /// 33-dim state → logits over the rank grid.
    pub fn forward(&self, state: &[f64]) -> Result<Vec<f64>> {
        forward_parsed(&self.p, state, &self.shape)
    }
}

/// Flat weights + 33-dim state → logits over the rank grid (one-shot
/// parse + forward; the host backend uses [`PolicyNet`] to amortize the
/// parse).
pub fn policy_forward(weights: &[f32], state: &[f64], shape: &PolicyShape) -> Result<Vec<f64>> {
    forward_parsed(&parse(weights, shape)?, state, shape)
}

fn forward_parsed(p: &PolicyParams, state: &[f64], shape: &PolicyShape) -> Result<Vec<f64>> {
    anyhow::ensure!(
        state.len() == shape.state_dim,
        "state dim {} vs policy {}",
        state.len(),
        shape.state_dim
    );
    let d = shape.d_model;
    let hd = d / shape.n_heads.max(1);

    // Token embedding: x = stack(s0·tok0, s1·tok1, s2·tok2) + pos.
    let s0 = Mat::from_vec(1, CONV_FEATS, state[..CONV_FEATS].to_vec());
    let s1 = Mat::from_vec(
        1,
        WSTAT_FEATS,
        state[CONV_FEATS..CONV_FEATS + WSTAT_FEATS].to_vec(),
    );
    let s2 = Mat::from_vec(
        1,
        shape.state_dim - CONV_FEATS - WSTAT_FEATS,
        state[CONV_FEATS + WSTAT_FEATS..].to_vec(),
    );
    let t0 = matmul(&s0, &p.tok0);
    let t1 = matmul(&s1, &p.tok1);
    let t2 = matmul(&s2, &p.tok2);
    let mut x = t0.vcat(&t1).vcat(&t2);
    x.add_inplace(&p.pos);

    for blk in &p.blocks {
        // Pre-LN attention sublayer.
        let mut h = x.clone();
        layernorm_rows(&mut h, &blk.ln1_g, &blk.ln1_b);
        let q = matmul(&h, &blk.wq);
        let k = matmul(&h, &blk.wk);
        let v = matmul(&h, &blk.wv);
        let mut cat = Mat::zeros(0, 0);
        for head in 0..shape.n_heads.max(1) {
            let o = head_attention(&q, &k, &v, head * hd, (head + 1) * hd);
            cat = if head == 0 { o } else { cat.hcat(&o) };
        }
        x.add_inplace(&matmul(&cat, &blk.wo));
        // Pre-LN FFN sublayer: x + gelu(h2·w1 + b1)·w2 + b2 (b2 added to
        // the residual stream, mirroring the python expression).
        let mut h2 = x.clone();
        layernorm_rows(&mut h2, &blk.ln2_g, &blk.ln2_b);
        let mut ff = matmul(&h2, &blk.w1);
        for i in 0..ff.rows() {
            for (j, v) in ff.row_mut(i).iter_mut().enumerate() {
                *v = gelu(*v + blk.b1[j]);
            }
        }
        let mut ff2 = matmul(&ff, &blk.w2);
        for i in 0..ff2.rows() {
            for (j, v) in ff2.row_mut(i).iter_mut().enumerate() {
                *v += blk.b2[j];
            }
        }
        x.add_inplace(&ff2);
    }

    // Mean-pool the 3 tokens, tanh MLP head.
    let mut pooled = vec![0.0f64; d];
    for i in 0..x.rows() {
        for (p, &v) in pooled.iter_mut().zip(x.row(i)) {
            *p += v / x.rows() as f64;
        }
    }
    let pooled = Mat::from_vec(1, d, pooled);
    let mut hid = matmul(&pooled, &p.head_w1);
    for (j, v) in hid.row_mut(0).iter_mut().enumerate() {
        *v = (*v + p.head_b1[j]).tanh();
    }
    let mut logits = matmul(&hid, &p.head_w2).into_vec();
    for (l, b) in logits.iter_mut().zip(&p.head_b2) {
        *l += b;
    }
    Ok(logits)
}

/// Deterministic policy weights for synthetic (artifact-free) manifests,
/// in the flat `param_order` layout: Xavier-style dense init, 0.02·N(0,1)
/// positions, unit layernorm gains, zero biases — the same scheme as
/// `policy_net.init_policy_params`, driven by the crate's own PRNG.
pub fn synthesize_weights(shape: &PolicyShape, seed: u64) -> Vec<f32> {
    let d = shape.d_model;
    let tail = shape.state_dim.saturating_sub(CONV_FEATS + WSTAT_FEATS);
    let mut rng = Pcg32::seeded(seed);
    let mut out: Vec<f32> = Vec::with_capacity(shape.flat_param_count());
    let mut dense = |rng: &mut Pcg32, out: &mut Vec<f32>, i: usize, o: usize| {
        let std = (2.0 / (i + o) as f64).sqrt();
        for _ in 0..i * o {
            out.push((rng.normal() * std) as f32);
        }
    };
    dense(&mut rng, &mut out, CONV_FEATS, d);
    dense(&mut rng, &mut out, WSTAT_FEATS, d);
    dense(&mut rng, &mut out, tail, d);
    for _ in 0..3 * d {
        out.push((rng.normal() * 0.02) as f32); // pos
    }
    for _ in 0..shape.n_blocks {
        for _ in 0..4 {
            dense(&mut rng, &mut out, d, d); // wq wk wv wo
        }
        out.extend(vec![1.0f32; d]); // ln1_g
        out.extend(vec![0.0f32; d]); // ln1_b
        dense(&mut rng, &mut out, d, 4 * d); // w1
        out.extend(vec![0.0f32; 4 * d]); // b1
        dense(&mut rng, &mut out, 4 * d, d); // w2
        out.extend(vec![0.0f32; d]); // b2
        out.extend(vec![1.0f32; d]); // ln2_g
        out.extend(vec![0.0f32; d]); // ln2_b
    }
    dense(&mut rng, &mut out, d, d); // head_w1
    out.extend(vec![0.0f32; d]); // head_b1
    dense(&mut rng, &mut out, d, shape.n_actions); // head_w2
    out.extend(vec![0.0f32; shape.n_actions]); // head_b2
    debug_assert_eq!(out.len(), shape.flat_param_count());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn shape() -> PolicyShape {
        Manifest::synthetic(32, 8).policy
    }

    #[test]
    fn synthesized_weights_match_layout_and_are_deterministic() {
        let s = shape();
        let a = synthesize_weights(&s, 7);
        let b = synthesize_weights(&s, 7);
        assert_eq!(a.len(), s.flat_param_count());
        assert_eq!(a, b, "same seed → same weights");
        assert_ne!(a, synthesize_weights(&s, 8), "different seed → different weights");
    }

    #[test]
    fn forward_emits_finite_grid_logits() {
        let s = shape();
        let w = synthesize_weights(&s, 1);
        let state: Vec<f64> = (0..s.state_dim).map(|i| (i as f64 * 0.1).sin()).collect();
        let logits = policy_forward(&w, &state, &s).unwrap();
        assert_eq!(logits.len(), s.n_actions);
        assert!(logits.iter().all(|v| v.is_finite()));
        // The state must matter: a different state moves the logits.
        let state2: Vec<f64> = state.iter().map(|v| v + 0.5).collect();
        let logits2 = policy_forward(&w, &state2, &s).unwrap();
        assert_ne!(logits, logits2);
    }

    #[test]
    fn forward_validates_dims() {
        let s = shape();
        let w = synthesize_weights(&s, 1);
        let long_state = vec![0.0; s.state_dim + 1];
        assert!(policy_forward(&w, &long_state, &s).is_err());
        let state = vec![0.0; s.state_dim];
        assert!(policy_forward(&w[..w.len() - 1], &state, &s).is_err());
    }
}
