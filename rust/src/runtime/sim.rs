//! Hardware-simulating backend: host kernels + a roofline latency model.
//!
//! [`SimBackend`] computes every op with the same pure-Rust kernels as
//! [`super::HostBackend`] (results are bit-identical), but additionally
//! charges each kernel call to a [`DeviceProfile`]'s roofline model
//! (`crate::sim::hw`), accumulating *projected* device latency in a
//! ledger. That injects the paper's hardware-constraint axis into the
//! serving loop without a device: latency-aware rewards, per-deployment
//! A/B runs (`--backend sim`), and Fig-4-style projections all read the
//! ledger through [`super::Backend::projected_ms`].

use super::backend::{Backend, Capabilities, LatencyLedger, Op, OpCounters};
use super::host::HostBackend;
use super::manifest::Manifest;
use crate::flops;
use crate::linalg::{Mat, Svd};
use crate::sim::{project_latency_ms, DeviceProfile};
use anyhow::Result;
use std::sync::Arc;

/// Host execution + projected device timing.
pub struct SimBackend {
    inner: HostBackend,
    profile: DeviceProfile,
    manifest: Manifest,
    ops: Arc<OpCounters>,
    ledger: LatencyLedger,
}

impl SimBackend {
    pub fn new(manifest: Manifest, profile: DeviceProfile) -> Self {
        // One shared counter ledger: the inner host executor records
        // every op (and LM-cache hits/misses); SimBackend only adds the
        // latency projection on top — no double counting.
        let ops = Arc::new(OpCounters::default());
        SimBackend {
            inner: HostBackend::with_counters(manifest.clone(), Arc::clone(&ops)),
            profile,
            manifest,
            ops,
            ledger: LatencyLedger::default(),
        }
    }

    /// The device profile this backend projects onto.
    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    fn charge(&self, flops: u64) {
        self.ledger.add_ms(project_latency_ms(flops, &self.profile));
    }

    /// Whole-LM forward FLOPs for one (B, L) batch — the hoisted
    /// definition shared with the engine's per-request attribution.
    fn lm_forward_flops(&self) -> u64 {
        self.manifest.lm.batch_forward_flops()
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities { supported: Op::ALL.to_vec(), models_latency: true }
    }

    fn ops(&self) -> Arc<OpCounters> {
        Arc::clone(&self.ops)
    }

    fn full_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        self.charge(flops::full_attention_flops(q.rows(), q.cols()));
        self.inner.full_attention(q, k, v)
    }

    fn lowrank_attention(&self, svd: &Svd, bucket: usize, rank: usize, v_val: &Mat) -> Result<Mat> {
        // Charge the *bucket*, not the live rank: the compiled kernel
        // always runs full bucket-width matmuls with masked factors, so
        // a device could not deliver sub-bucket latency differences.
        self.charge(flops::lowrank_attention_flops(v_val.rows(), v_val.cols(), bucket, false));
        self.inner.lowrank_attention(svd, bucket, rank, v_val)
    }

    fn power_iter_sigma(&self, m: &Mat, v0: &[f64]) -> Result<f64> {
        self.charge(flops::power_iteration_flops(
            m.rows(),
            m.cols(),
            self.manifest.kernel.power_iters.max(1),
        ));
        self.inner.power_iter_sigma(m, v0)
    }

    fn policy_logits(&self, weights: &[f32], state: &[f64]) -> Result<Vec<f64>> {
        let p = &self.manifest.policy;
        self.charge(flops::policy_overhead_flops(p.state_dim, p.d_model, p.n_actions));
        self.inner.policy_logits(weights, state)
    }

    fn lm_logits(&self, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.charge(self.lm_forward_flops());
        self.inner.lm_logits(params, tokens)
    }

    fn lm_eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f64> {
        self.charge(self.lm_forward_flops());
        self.inner.lm_eval_loss(params, tokens, targets)
    }

    fn lm_train_step(
        &self,
        params: &mut Vec<f32>,
        adam_m: &mut Vec<f32>,
        adam_v: &mut Vec<f32>,
        step: f32,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f64> {
        self.charge(self.manifest.lm.train_step_flops());
        self.inner.lm_train_step(params, adam_m, adam_v, step, tokens, targets)
    }

    fn projected_ms(&self) -> Option<f64> {
        Some(self.ledger.total_ms())
    }

    fn latency_ledger(&self) -> Option<&LatencyLedger> {
        Some(&self.ledger)
    }

    fn device_profile(&self) -> Option<DeviceProfile> {
        Some(self.profile)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn backends(n: usize, d: usize) -> (HostBackend, SimBackend) {
        let m = Manifest::synthetic(n, d);
        (HostBackend::new(m.clone()), SimBackend::new(m, DeviceProfile::A100))
    }

    #[test]
    fn sim_results_are_bit_identical_to_host() {
        let (n, d) = (32, 8);
        let (host, sim) = backends(n, d);
        let mut rng = Pcg32::seeded(1);
        let q = Mat::randn(n, d, 0.7, &mut rng);
        let k = Mat::randn(n, d, 0.7, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let a = host.full_attention(&q, &k, &v).unwrap();
        let b = sim.full_attention(&q, &k, &v).unwrap();
        assert_eq!(a.data(), b.data(), "sim must delegate compute to host kernels");
    }

    #[test]
    fn sim_accumulates_projected_latency() {
        let (n, d) = (32, 8);
        let (_, sim) = backends(n, d);
        assert_eq!(sim.projected_ms(), Some(0.0));
        let mut rng = Pcg32::seeded(2);
        let q = Mat::randn(n, d, 0.7, &mut rng);
        sim.full_attention(&q, &q, &q).unwrap();
        let after_one = sim.projected_ms().unwrap();
        assert!(after_one > 0.0);
        sim.full_attention(&q, &q, &q).unwrap();
        let after_two = sim.projected_ms().unwrap();
        assert!((after_two - 2.0 * after_one).abs() < 1e-9, "latency accumulates per call");
        assert!(sim.capabilities().models_latency);
        assert_eq!(sim.ops().get(Op::FullAttention), 2);
    }

    #[test]
    fn slower_profiles_project_more_latency() {
        let m = Manifest::synthetic(32, 8);
        let fast = SimBackend::new(m.clone(), DeviceProfile::A100);
        let slow = SimBackend::new(m, DeviceProfile::CPU_DEFAULT);
        let mut rng = Pcg32::seeded(3);
        let q = Mat::randn(32, 8, 0.7, &mut rng);
        fast.full_attention(&q, &q, &q).unwrap();
        slow.full_attention(&q, &q, &q).unwrap();
        assert!(slow.projected_ms().unwrap() > fast.projected_ms().unwrap());
    }
}
