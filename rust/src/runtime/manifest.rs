//! Artifact manifest parsing (artifacts/manifest.json, emitted by
//! python/compile/aot.py). The manifest is the single source of truth
//! for shapes baked into the HLO — the Rust side never hard-codes them.

use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// LM static shapes.
#[derive(Debug, Clone)]
pub struct LmShape {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub batch: usize,
    pub param_count: usize,
    pub lr: f64,
    /// AdamW decoupled weight decay (python configs.LmConfig).
    pub weight_decay: f64,
}

impl LmShape {
    /// Analytic forward FLOPs of one whole (batch × seq_len) LM call —
    /// the single definition both the `SimBackend` roofline charges and
    /// the serving engine's per-request `projected_ms` attribution use,
    /// so the two ledgers cannot drift.
    pub fn batch_forward_flops(&self) -> u64 {
        let dims = crate::flops::ModelDims {
            block: crate::flops::BlockDims {
                n: self.seq_len,
                d_model: self.d_model,
                n_heads: self.n_heads,
                d_ff: self.d_ff,
            },
            n_layers: self.n_layers,
            vocab: self.vocab,
        };
        dims.full_model_flops() * self.batch as u64
    }

    /// Analytic FLOPs of one fused AdamW train step on the same batch:
    /// forward plus the standard backward ≈ 2× forward rule of thumb.
    /// Single definition shared by the `SimBackend` charge and the
    /// CLIs' projected train-cost summaries, so they cannot drift.
    pub fn train_step_flops(&self) -> u64 {
        3 * self.batch_forward_flops()
    }
}

/// Kernel artifact shapes.
#[derive(Debug, Clone)]
pub struct KernelShape {
    pub seq_len: usize,
    pub head_dim: usize,
    pub rank_buckets: Vec<usize>,
    pub block_n: usize,
    pub power_iters: usize,
}

impl KernelShape {
    /// Smallest compiled rank bucket ≥ the requested rank (DESIGN.md §9);
    /// falls back to the largest bucket. The single definition of the
    /// bucket rounding — the registry, the engine pipeline's probe
    /// planning and the rank controller all route through it.
    pub fn rank_bucket(&self, rank: usize) -> usize {
        self.rank_buckets
            .iter()
            .copied()
            .filter(|&b| b >= rank)
            .min()
            .unwrap_or_else(|| *self.rank_buckets.iter().max().expect("non-empty buckets"))
    }
}

/// Policy artifact shapes.
#[derive(Debug, Clone)]
pub struct PolicyShape {
    pub state_dim: usize,
    pub n_actions: usize,
    pub rank_grid: Vec<usize>,
    pub bc_accuracy: f64,
    /// Flat weight vector length + sidecar file (weights are a runtime
    /// argument — HLO text elides large constants).
    pub param_count: usize,
    pub params_file: String,
    /// Encoder architecture (python configs.PolicyConfig) — needed by the
    /// host backend to run the transformer policy without an artifact.
    pub d_model: usize,
    pub n_blocks: usize,
    pub n_heads: usize,
}

impl PolicyShape {
    /// Parameter count of the flat policy layout (must mirror
    /// python/compile/policy_net.py::param_order): the three token
    /// projections + positional rows, `n_blocks` pre-LN encoder blocks,
    /// and the two-layer MLP head.
    pub fn flat_param_count(&self) -> usize {
        let d = self.d_model;
        // tok0 (16×d) + tok1 (9×d) + tok2 ((state_dim−25)×d) + pos (3×d).
        let toks = (self.state_dim + 3) * d;
        // wq..wo 4d² + ln1 2d + w1 d·4d + b1 4d + w2 4d·d + b2 d + ln2 2d.
        let per_block = 12 * d * d + 9 * d;
        let head = d * d + d + d * self.n_actions + self.n_actions;
        toks + self.n_blocks * per_block + head
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub lm: LmShape,
    pub kernel: KernelShape,
    pub policy: PolicyShape,
    pub artifact_files: BTreeMap<String, String>,
}

impl LmShape {
    /// Parameter count of the flat AOT layout (must mirror
    /// python/compile/model.py::param_slices and HostLm::from_flat).
    pub fn flat_param_count(&self) -> usize {
        let (d, dff) = (self.d_model, self.d_ff);
        let per_layer = 4 * d * d + 2 * d * dff + dff + 5 * d;
        self.vocab * d + self.seq_len * d + self.n_layers * per_layer + 2 * d + d * self.vocab
    }
}

impl Manifest {
    /// Synthetic manifest for the pure-Rust host backend: no files on
    /// disk, shapes chosen by the caller for the attention kernels and a
    /// small fixed LM. Lets the serving stack (engine, batcher, rank
    /// controller, generation) run without `make artifacts`.
    pub fn synthetic(kernel_seq_len: usize, head_dim: usize) -> Manifest {
        let rank_buckets = vec![16, 32, 48, 64];
        let mut lm = LmShape {
            vocab: 256,
            seq_len: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            batch: 4,
            param_count: 0,
            lr: 5e-4,
            weight_decay: 0.01,
        };
        lm.param_count = lm.flat_param_count();
        let kernel = KernelShape {
            seq_len: kernel_seq_len,
            head_dim,
            rank_buckets: rank_buckets.clone(),
            block_n: 64,
            power_iters: 8,
        };
        let rank_grid = vec![16, 24, 32, 40, 48, 56, 64];
        let mut policy = PolicyShape {
            state_dim: crate::rl::state_dim(),
            n_actions: rank_grid.len(),
            rank_grid,
            bc_accuracy: 0.0,
            param_count: 0,
            params_file: "<synthetic>".to_string(),
            // Smaller encoder than the AOT artifact's (d=64): the host
            // forward runs per decision, and a d=32 policy keeps it cheap.
            d_model: 32,
            n_blocks: 2,
            n_heads: 4,
        };
        policy.param_count = policy.flat_param_count();
        let mut artifact_files = BTreeMap::new();
        for name in ["full_attn", "power_iter", "lm_logits", "lm_eval_loss", "policy_net",
            "lm_train_step"]
        {
            artifact_files.insert(name.to_string(), format!("<host:{name}>"));
        }
        for b in &rank_buckets {
            artifact_files
                .insert(format!("lowrank_attn_r{b}"), format!("<host:lowrank_attn_r{b}>"));
        }
        Manifest {
            dir: PathBuf::from("<host>"),
            lm,
            kernel,
            policy,
            artifact_files,
        }
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let u = |v: Option<&Json>, what: &str| -> Result<usize> {
            v.and_then(|x| x.as_usize()).with_context(|| format!("manifest missing {what}"))
        };
        let lmj = j.get("lm").context("manifest: lm")?;
        let lm = LmShape {
            vocab: u(lmj.get("vocab"), "lm.vocab")?,
            seq_len: u(lmj.get("seq_len"), "lm.seq_len")?,
            d_model: u(lmj.get("d_model"), "lm.d_model")?,
            n_layers: u(lmj.get("n_layers"), "lm.n_layers")?,
            n_heads: u(lmj.get("n_heads"), "lm.n_heads")?,
            d_ff: u(lmj.get("d_ff"), "lm.d_ff")?,
            batch: u(lmj.get("batch"), "lm.batch")?,
            param_count: u(j.get("lm_param_count"), "lm_param_count")?,
            lr: lmj.get("lr").and_then(|x| x.as_f64()).unwrap_or(5e-4),
            weight_decay: lmj.get("weight_decay").and_then(|x| x.as_f64()).unwrap_or(0.01),
        };
        let kj = j.get("kernel").context("manifest: kernel")?;
        let kernel = KernelShape {
            seq_len: u(kj.get("seq_len"), "kernel.seq_len")?,
            head_dim: u(kj.get("head_dim"), "kernel.head_dim")?,
            rank_buckets: kj
                .get("rank_buckets")
                .and_then(|a| a.as_arr())
                .context("kernel.rank_buckets")?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            block_n: u(kj.get("block_n"), "kernel.block_n")?,
            power_iters: u(kj.get("power_iters"), "kernel.power_iters")?,
        };
        let pj = j.get("policy").context("manifest: policy")?;
        let arts = j.get("artifacts").and_then(|a| a.as_obj()).context("artifacts")?;
        let rank_grid = arts
            .get("policy_net")
            .and_then(|p| p.get("rank_grid"))
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let pol_art = arts.get("policy_net");
        let policy = PolicyShape {
            state_dim: u(pj.get("state_dim"), "policy.state_dim")?,
            n_actions: u(pj.get("n_actions"), "policy.n_actions")?,
            rank_grid,
            bc_accuracy: j.get("policy_bc_accuracy").and_then(|x| x.as_f64()).unwrap_or(0.0),
            param_count: pol_art
                .and_then(|p| p.get("param_count"))
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            params_file: pol_art
                .and_then(|p| p.get("params_file"))
                .and_then(|x| x.as_str())
                .unwrap_or("policy_params.bin")
                .to_string(),
            // Defaults mirror python configs.PolicyConfig.
            d_model: pj.get("d_model").and_then(|x| x.as_usize()).unwrap_or(64),
            n_blocks: pj.get("n_blocks").and_then(|x| x.as_usize()).unwrap_or(2),
            n_heads: pj.get("n_heads").and_then(|x| x.as_usize()).unwrap_or(4),
        };
        let mut artifact_files = BTreeMap::new();
        for (name, spec) in arts {
            if let Some(f) = spec.get("file").and_then(|x| x.as_str()) {
                artifact_files.insert(name.clone(), f.to_string());
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), lm, kernel, policy, artifact_files })
    }

    /// True for in-memory manifests built by [`Manifest::synthetic`]
    /// (no files on disk; policy weights are synthesized, not loaded).
    pub fn is_synthetic(&self) -> bool {
        self.dir.as_os_str() == "<host>"
    }

    /// Absolute path of a named artifact.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .artifact_files
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        Ok(self.dir.join(f))
    }

    /// Default artifact dir: $DRRL_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("DRRL_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // Walk up from cwd until an artifacts/ directory is found
            // (tests run from target subdirs).
            let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                let cand = cur.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !cur.pop() {
                    return PathBuf::from("artifacts");
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_bucket_boundaries() {
        // Regression for the single hoisted definition: exact bucket →
        // itself, one past a bucket → the next, above the top → clamp.
        let k = Manifest::synthetic(64, 16).kernel;
        assert_eq!(k.rank_buckets, vec![16, 32, 48, 64]);
        assert_eq!(k.rank_bucket(1), 16);
        assert_eq!(k.rank_bucket(16), 16);
        assert_eq!(k.rank_bucket(17), 32);
        assert_eq!(k.rank_bucket(32), 32);
        assert_eq!(k.rank_bucket(33), 48);
        assert_eq!(k.rank_bucket(48), 48);
        assert_eq!(k.rank_bucket(49), 64);
        assert_eq!(k.rank_bucket(64), 64);
        assert_eq!(k.rank_bucket(65), 64, "above the top bucket clamps");
        assert_eq!(k.rank_bucket(0), 16);
    }

    #[test]
    fn synthetic_manifest_is_complete_and_synthetic() {
        let m = Manifest::synthetic(32, 8);
        assert!(m.is_synthetic());
        assert_eq!(m.lm.param_count, m.lm.flat_param_count());
        assert_eq!(m.policy.param_count, m.policy.flat_param_count());
        assert!(m.policy.param_count > 0);
        assert_eq!(m.policy.state_dim, crate::rl::state_dim());
        assert!(m.artifact_files.contains_key("policy_net"));
        assert!(m.artifact_files.contains_key("lm_train_step"));
    }

    #[test]
    fn policy_flat_count_matches_aot_layout_at_artifact_shape() {
        // The AOT PolicyConfig (d=64, 2 blocks, 33-dim state, 7 actions)
        // flattens to 106375 f32s (python policy_net.flat_param_count).
        let p = PolicyShape {
            state_dim: 33,
            n_actions: 7,
            rank_grid: vec![16, 24, 32, 40, 48, 56, 64],
            bc_accuracy: 0.0,
            param_count: 0,
            params_file: String::new(),
            d_model: 64,
            n_blocks: 2,
            n_heads: 4,
        };
        assert_eq!(p.flat_param_count(), 106_375);
    }

    #[test]
    fn parses_generated_manifest_if_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).expect("manifest loads");
        assert!(m.lm.param_count > 0);
        assert_eq!(m.lm.d_model % m.lm.n_heads, 0);
        assert!(!m.kernel.rank_buckets.is_empty());
        assert!(m.artifact_files.contains_key("lm_train_step"));
        assert!(m.artifact_path("policy_net").unwrap().exists());
        assert_eq!(m.policy.rank_grid.len(), m.policy.n_actions);
    }
}
