//! Artifact manifest parsing (artifacts/manifest.json, emitted by
//! python/compile/aot.py). The manifest is the single source of truth
//! for shapes baked into the HLO — the Rust side never hard-codes them.

use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// LM static shapes.
#[derive(Debug, Clone)]
pub struct LmShape {
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub batch: usize,
    pub param_count: usize,
    pub lr: f64,
}

/// Kernel artifact shapes.
#[derive(Debug, Clone)]
pub struct KernelShape {
    pub seq_len: usize,
    pub head_dim: usize,
    pub rank_buckets: Vec<usize>,
    pub block_n: usize,
    pub power_iters: usize,
}

/// Policy artifact shapes.
#[derive(Debug, Clone)]
pub struct PolicyShape {
    pub state_dim: usize,
    pub n_actions: usize,
    pub rank_grid: Vec<usize>,
    pub bc_accuracy: f64,
    /// Flat weight vector length + sidecar file (weights are a runtime
    /// argument — HLO text elides large constants).
    pub param_count: usize,
    pub params_file: String,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub lm: LmShape,
    pub kernel: KernelShape,
    pub policy: PolicyShape,
    pub artifact_files: BTreeMap<String, String>,
}

impl LmShape {
    /// Parameter count of the flat AOT layout (must mirror
    /// python/compile/model.py::param_slices and HostLm::from_flat).
    pub fn flat_param_count(&self) -> usize {
        let (d, dff) = (self.d_model, self.d_ff);
        let per_layer = 4 * d * d + 2 * d * dff + dff + 5 * d;
        self.vocab * d + self.seq_len * d + self.n_layers * per_layer + 2 * d + d * self.vocab
    }
}

impl Manifest {
    /// Synthetic manifest for the pure-Rust host backend: no files on
    /// disk, shapes chosen by the caller for the attention kernels and a
    /// small fixed LM. Lets the serving stack (engine, batcher, rank
    /// controller, generation) run without `make artifacts`.
    pub fn synthetic(kernel_seq_len: usize, head_dim: usize) -> Manifest {
        let rank_buckets = vec![16, 32, 48, 64];
        let mut lm = LmShape {
            vocab: 256,
            seq_len: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            batch: 4,
            param_count: 0,
            lr: 5e-4,
        };
        lm.param_count = lm.flat_param_count();
        let kernel = KernelShape {
            seq_len: kernel_seq_len,
            head_dim,
            rank_buckets: rank_buckets.clone(),
            block_n: 64,
            power_iters: 8,
        };
        let rank_grid = vec![16, 24, 32, 40, 48, 56, 64];
        let policy = PolicyShape {
            state_dim: crate::rl::state_dim(),
            n_actions: rank_grid.len(),
            rank_grid,
            bc_accuracy: 0.0,
            param_count: 0,
            params_file: "policy_params.bin".to_string(),
        };
        let mut artifact_files = BTreeMap::new();
        for name in ["full_attn", "power_iter", "lm_logits", "lm_eval_loss"] {
            artifact_files.insert(name.to_string(), format!("<host:{name}>"));
        }
        for b in &rank_buckets {
            artifact_files
                .insert(format!("lowrank_attn_r{b}"), format!("<host:lowrank_attn_r{b}>"));
        }
        Manifest {
            dir: PathBuf::from("<host>"),
            lm,
            kernel,
            policy,
            artifact_files,
        }
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let u = |v: Option<&Json>, what: &str| -> Result<usize> {
            v.and_then(|x| x.as_usize()).with_context(|| format!("manifest missing {what}"))
        };
        let lmj = j.get("lm").context("manifest: lm")?;
        let lm = LmShape {
            vocab: u(lmj.get("vocab"), "lm.vocab")?,
            seq_len: u(lmj.get("seq_len"), "lm.seq_len")?,
            d_model: u(lmj.get("d_model"), "lm.d_model")?,
            n_layers: u(lmj.get("n_layers"), "lm.n_layers")?,
            n_heads: u(lmj.get("n_heads"), "lm.n_heads")?,
            d_ff: u(lmj.get("d_ff"), "lm.d_ff")?,
            batch: u(lmj.get("batch"), "lm.batch")?,
            param_count: u(j.get("lm_param_count"), "lm_param_count")?,
            lr: lmj.get("lr").and_then(|x| x.as_f64()).unwrap_or(5e-4),
        };
        let kj = j.get("kernel").context("manifest: kernel")?;
        let kernel = KernelShape {
            seq_len: u(kj.get("seq_len"), "kernel.seq_len")?,
            head_dim: u(kj.get("head_dim"), "kernel.head_dim")?,
            rank_buckets: kj
                .get("rank_buckets")
                .and_then(|a| a.as_arr())
                .context("kernel.rank_buckets")?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            block_n: u(kj.get("block_n"), "kernel.block_n")?,
            power_iters: u(kj.get("power_iters"), "kernel.power_iters")?,
        };
        let pj = j.get("policy").context("manifest: policy")?;
        let arts = j.get("artifacts").and_then(|a| a.as_obj()).context("artifacts")?;
        let rank_grid = arts
            .get("policy_net")
            .and_then(|p| p.get("rank_grid"))
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        let pol_art = arts.get("policy_net");
        let policy = PolicyShape {
            state_dim: u(pj.get("state_dim"), "policy.state_dim")?,
            n_actions: u(pj.get("n_actions"), "policy.n_actions")?,
            rank_grid,
            bc_accuracy: j.get("policy_bc_accuracy").and_then(|x| x.as_f64()).unwrap_or(0.0),
            param_count: pol_art
                .and_then(|p| p.get("param_count"))
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            params_file: pol_art
                .and_then(|p| p.get("params_file"))
                .and_then(|x| x.as_str())
                .unwrap_or("policy_params.bin")
                .to_string(),
        };
        let mut artifact_files = BTreeMap::new();
        for (name, spec) in arts {
            if let Some(f) = spec.get("file").and_then(|x| x.as_str()) {
                artifact_files.insert(name.clone(), f.to_string());
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), lm, kernel, policy, artifact_files })
    }

    /// Absolute path of a named artifact.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .artifact_files
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        Ok(self.dir.join(f))
    }

    /// Default artifact dir: $DRRL_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("DRRL_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // Walk up from cwd until an artifacts/ directory is found
            // (tests run from target subdirs).
            let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                let cand = cur.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !cur.pop() {
                    return PathBuf::from("artifacts");
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_manifest_if_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).expect("manifest loads");
        assert!(m.lm.param_count > 0);
        assert_eq!(m.lm.d_model % m.lm.n_heads, 0);
        assert!(!m.kernel.rank_buckets.is_empty());
        assert!(m.artifact_files.contains_key("lm_train_step"));
        assert!(m.artifact_path("policy_net").unwrap().exists());
        assert_eq!(m.policy.rank_grid.len(), m.policy.n_actions);
    }
}
