//! Runtime (L3 ↔ artifacts boundary): the typed, pluggable [`Backend`]
//! API plus the [`ArtifactRegistry`] validation adapter over it.
//!
//! ## Backends
//!
//! | backend | construction | execution | completeness |
//! |---------|--------------|-----------|--------------|
//! | [`HostBackend`] | [`ArtifactRegistry::open_host`] | pure-Rust kernels on the calling thread | every [`Op`] |
//! | `PjrtBackend` (feature `pjrt`) | [`ArtifactRegistry::open`] | compiled HLO artifacts on a dedicated device thread | every [`Op`] |
//! | [`SimBackend`] | [`ArtifactRegistry::open_sim`] | host kernels + roofline latency projection | every [`Op`], `models_latency` |
//!
//! Support is declared through [`backend::Capabilities`] — an op a
//! backend cannot run returns a typed "unsupported" error, never a
//! panic — and per-op execute counts flow through [`backend::OpCounters`]
//! into the serving engine's `Metrics::report()`.
//!
//! ## Projected-latency surfaces (`models_latency` backends)
//!
//! | surface | what it reads |
//! |---------|---------------|
//! | [`ArtifactRegistry::projected_ms`] | cumulative backend ledger total (ms) |
//! | [`ArtifactRegistry::latency_ledger`] | the [`LatencyLedger`] itself — scoped `mark()`/`since()` delta reads attribute charges per op wave |
//! | [`ArtifactRegistry::device_profile`] | the roofline [`crate::sim::DeviceProfile`] charges are priced on |
//! | `AttentionResponse::projected_ms` | *per-request* attribution: that request's kernel charges (sums across a co-batched wave to the backend ledger, 1e-9) |
//! | `GenerateResponse::projected_ms` | per-chunk attribution of the LM decode dispatches |
//! | `Metrics::report()` | live `projected[profile]` ledger: spent vs full-rank counterfactual |
//!
//! The serving engine also accepts a `reward_profile` in its controller
//! config: a backend with no latency model then still projects (same
//! roofline formulas), while a `models_latency` backend's own profile
//! always wins so the metrics ledger matches the backend's.
//!
//! ## Migration from the stringly-typed runtime
//!
//! The old API dispatched kernels by artifact-name string through a
//! process-global device handle. Artifact names now exist *only inside*
//! backend implementations in this module; everything else calls typed
//! methods:
//!
//! | old (string dispatch)                                   | new (typed)                              |
//! |---------------------------------------------------------|------------------------------------------|
//! | process-global device-handle singleton                  | backend owned per registry/engine        |
//! | `device.execute("full_attn", vec![q, k, v])`            | `reg.full_attention(&q, &k, &v)`         |
//! | `device.execute("lowrank_attn_r{b}", vec![u, s, vt, …])`| `reg.lowrank_attention(&svd, rank, &v)`  |
//! | `device.execute("power_iter", vec![m, v0])`             | `reg.power_iter_sigma(&m, &v0)`          |
//! | `device.execute("policy_net", vec![w, state])`          | `reg.policy_logits(&state)`              |
//! | `device.execute("lm_logits", vec![p, toks])`            | `reg.lm_logits(&params, &tokens)`        |
//! | `device.execute("lm_eval_loss", …)`                     | `reg.lm_eval_loss(&params, &t, &g)`      |
//! | `device.execute("lm_train_step", …)`                    | `reg.lm_train_step(&mut p, &mut m, …)`   |
//! | `device.warm("full_attn")` per name                     | `reg.warm_all()` / `Backend::warm(Op)`   |
//! | `device.stats()` → `BTreeMap<String, u64>`              | `reg.ops()` → typed [`backend::OpCounters`] |

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod device;
pub mod host;
pub mod host_policy;
pub mod manifest;
pub mod registry;
pub mod sim;
pub mod tensor;

pub use backend::{Backend, Capabilities, LatencyLedger, LedgerMark, Op, OpCounters};
#[cfg(feature = "pjrt")]
pub use device::PjrtBackend;
pub use host::HostBackend;
pub use manifest::{KernelShape, LmShape, Manifest, PolicyShape};
pub use registry::ArtifactRegistry;
pub use sim::SimBackend;
pub use tensor::HostTensor;
