//! PJRT runtime (L3 ↔ artifacts boundary): a dedicated device thread
//! owns the non-Send PJRT client and compiled executables; callers use
//! the Send `DeviceHandle` RPC and the typed `ArtifactRegistry` API.

pub mod device;
pub mod host;
pub mod manifest;
pub mod registry;
pub mod tensor;

pub use device::DeviceHandle;
pub use host::HostBackend;
pub use manifest::{KernelShape, LmShape, Manifest, PolicyShape};
pub use registry::ArtifactRegistry;
pub use tensor::HostTensor;
