//! Pure-Rust host backend: executes the artifact entry points with the
//! crate's own numeric kernels when PJRT (feature `pjrt`) is unavailable
//! or the HLO artifacts have not been built.
//!
//! Semantics mirror the L1/L2 artifacts: `full_attn` is causal blocked
//! attention, `lowrank_attn_r{B}` is the masked factor apply
//! Y = U·diag(s⊙mask)·(Vᵀ·V_val), `power_iter` is K iterations of
//! v ← MᵀMv/‖·‖, and `lm_logits` / `lm_eval_loss` evaluate the decoder
//! LM through `HostLm` on the same flat parameter layout. Inputs and
//! outputs cross the boundary as f32 `HostTensor`s, matching the device
//! path's precision.
//!
//! Unlike the PJRT device thread (whose `Literal`s are not `Send`), the
//! host backend is `Send + Sync` and executes on the *calling* thread —
//! concurrent engine workers and per-head fan-out run kernels genuinely
//! in parallel instead of serializing through one device thread.

use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::attention::{full_attention, AttnInputs};
use crate::linalg::{matmul, Mat};
use crate::train::HostLm;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Thread-safe host executor keyed by artifact name.
pub struct HostBackend {
    manifest: Manifest,
    calls: Mutex<BTreeMap<String, u64>>,
    /// Parsed-LM cache keyed by a fingerprint of the flat param vector:
    /// the generation hot path sends identical params on every decode
    /// step, so re-parsing (and re-allocating) the whole model per
    /// `lm_logits` call was pure overhead. Capacity 1 — serving uses one
    /// frozen parameter set at a time.
    lm_cache: Mutex<Option<(u64, Arc<HostLm>)>>,
}

impl HostBackend {
    pub fn new(manifest: Manifest) -> Self {
        HostBackend {
            manifest,
            calls: Mutex::new(BTreeMap::new()),
            lm_cache: Mutex::new(None),
        }
    }

    /// Per-artifact execute counts (mirrors the device thread's stats),
    /// plus `lm_cache_hit` / `lm_cache_miss` counters for the parsed-LM
    /// cache.
    pub fn stats(&self) -> BTreeMap<String, u64> {
        self.calls.lock().unwrap().clone()
    }

    fn bump(&self, key: &str) {
        *self.calls.lock().unwrap().entry(key.to_string()).or_insert(0) += 1;
    }

    /// Availability check; compilation is a no-op on the host.
    pub fn warm(&self, artifact: &str) -> Result<()> {
        anyhow::ensure!(
            self.manifest.artifact_files.contains_key(artifact),
            "artifact '{artifact}' not in manifest"
        );
        Ok(())
    }

    pub fn execute(&self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let out = self.dispatch(artifact, inputs)?;
        *self.calls.lock().unwrap().entry(artifact.to_string()).or_insert(0) += 1;
        Ok(out)
    }

    fn dispatch(&self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match artifact {
            "full_attn" => self.full_attn(inputs),
            "power_iter" => self.power_iter(inputs),
            "lm_logits" => self.lm_logits(inputs),
            "lm_eval_loss" => self.lm_eval_loss(inputs),
            name if name.starts_with("lowrank_attn_r") => {
                let bucket: usize = name["lowrank_attn_r".len()..]
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad rank bucket in '{name}'"))?;
                self.lowrank_attn(bucket, inputs)
            }
            "policy_net" => Err(anyhow::anyhow!(
                "artifact 'policy_net' needs the AOT transformer policy; the host \
                 backend cannot execute it — use PolicySource::Actor/Fixed/\
                 AdaptiveEnergy, or build artifacts and enable the `pjrt` feature"
            )),
            "lm_train_step" => Err(anyhow::anyhow!(
                "artifact 'lm_train_step' (fused AdamW backward) is only available \
                 with the `pjrt` feature and built artifacts"
            )),
            other => Err(anyhow::anyhow!("artifact '{other}' not available on host backend")),
        }
    }

    fn mat_input(t: &HostTensor, rows: usize, cols: usize, what: &str) -> Result<Mat> {
        let data = t
            .as_f32()
            .ok_or_else(|| anyhow::anyhow!("{what}: expected f32 tensor"))?;
        anyhow::ensure!(
            data.len() == rows * cols,
            "{what}: got {} elements, want {rows}x{cols}",
            data.len()
        );
        Ok(Mat::from_f32(rows, cols, data))
    }

    fn full_attn(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (n, d) = (self.manifest.kernel.seq_len, self.manifest.kernel.head_dim);
        anyhow::ensure!(inputs.len() == 3, "full_attn takes q,k,v");
        let inp = AttnInputs {
            q: Self::mat_input(&inputs[0], n, d, "q")?,
            k: Self::mat_input(&inputs[1], n, d, "k")?,
            v: Self::mat_input(&inputs[2], n, d, "v")?,
            causal: true,
        };
        Ok(vec![HostTensor::from_mat(&full_attention(&inp))])
    }

    /// Y = U·diag(s⊙mask)·(Vᵀ·V_val) — the masked factor apply.
    fn lowrank_attn(&self, bucket: usize, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (n, d) = (self.manifest.kernel.seq_len, self.manifest.kernel.head_dim);
        anyhow::ensure!(inputs.len() == 5, "lowrank_attn takes u,s,vt,v,mask");
        let u = Self::mat_input(&inputs[0], n, bucket, "u")?;
        let s = inputs[1].as_f32().ok_or_else(|| anyhow::anyhow!("s: expected f32"))?;
        let vt = Self::mat_input(&inputs[2], bucket, n, "vt")?;
        let v_val = Self::mat_input(&inputs[3], n, d, "v_val")?;
        let mask = inputs[4].as_f32().ok_or_else(|| anyhow::anyhow!("mask: expected f32"))?;
        anyhow::ensure!(s.len() == bucket && mask.len() == bucket, "s/mask length");
        let mut w = matmul(&vt, &v_val); // bucket × d
        for i in 0..bucket {
            let scale = (s[i] * mask[i]) as f64;
            for x in w.row_mut(i).iter_mut() {
                *x *= scale;
            }
        }
        Ok(vec![HostTensor::from_mat(&matmul(&u, &w))])
    }

    /// K iterations of v ← MᵀMv/‖·‖ from the given v0, then σ = ‖Mv‖
    /// (mirrors python/compile/kernels/power_iter.py).
    fn power_iter(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        anyhow::ensure!(inputs.len() == 2, "power_iter takes m, v0");
        let dims = inputs[0].dims();
        anyhow::ensure!(dims.len() == 2, "m must be 2-D");
        let (r, c) = (dims[0] as usize, dims[1] as usize);
        let m = Self::mat_input(&inputs[0], r, c, "m")?;
        let v0 = inputs[1].as_f32().ok_or_else(|| anyhow::anyhow!("v0: expected f32"))?;
        anyhow::ensure!(v0.len() == c, "v0 length {} vs {c}", v0.len());
        let mut v: Vec<f64> = v0.iter().map(|&x| x as f64).collect();
        let norm = |x: &[f64]| x.iter().map(|a| a * a).sum::<f64>().sqrt();
        let scale = norm(&v).max(1e-30);
        v.iter_mut().for_each(|x| *x /= scale);
        for _ in 0..self.manifest.kernel.power_iters.max(1) {
            let w = crate::linalg::matvec(&m, &v);
            let mut next = crate::linalg::matvec_t(&m, &w);
            let nrm = norm(&next).max(1e-30);
            next.iter_mut().for_each(|x| *x /= nrm);
            v = next;
        }
        let sigma = norm(&crate::linalg::matvec(&m, &v));
        Ok(vec![
            HostTensor::f32(vec![sigma as f32], &[1]),
            HostTensor::from_f64s(&v),
        ])
    }

    fn lm_tokens(t: &HostTensor, batch: usize, seq_len: usize, what: &str) -> Result<Vec<i32>> {
        let data = t
            .as_i32()
            .ok_or_else(|| anyhow::anyhow!("{what}: expected i32 tensor"))?;
        anyhow::ensure!(
            data.len() == batch * seq_len,
            "{what}: got {} tokens, want {batch}x{seq_len}",
            data.len()
        );
        Ok(data.to_vec())
    }

    /// Parsed host LM for the given flat params, served from the
    /// fingerprint-keyed cache. The forward runs outside the cache lock
    /// (`HostLm` evaluation is `&self`), so concurrent callers share one
    /// parsed model without serializing on each other.
    fn host_lm(&self, params: &HostTensor) -> Result<Arc<HostLm>> {
        let lm = &self.manifest.lm;
        let p = params
            .as_f32()
            .ok_or_else(|| anyhow::anyhow!("params: expected f32 tensor"))?;
        anyhow::ensure!(
            p.len() == lm.param_count,
            "param vector len {} vs manifest {}",
            p.len(),
            lm.param_count
        );
        let fp = params_fingerprint(p);
        {
            let g = self.lm_cache.lock().unwrap();
            if let Some((cached_fp, host)) = g.as_ref() {
                if *cached_fp == fp {
                    let host = Arc::clone(host);
                    drop(g);
                    self.bump("lm_cache_hit");
                    return Ok(host);
                }
            }
        }
        // Parse outside the lock; a racing miss just parses twice and
        // the last writer wins.
        let parsed = Arc::new(HostLm::from_flat(p, lm));
        *self.lm_cache.lock().unwrap() = Some((fp, Arc::clone(&parsed)));
        self.bump("lm_cache_miss");
        Ok(parsed)
    }

    fn lm_logits(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lm = self.manifest.lm.clone();
        anyhow::ensure!(inputs.len() == 2, "lm_logits takes params, tokens");
        let host = self.host_lm(&inputs[0])?;
        let tokens = Self::lm_tokens(&inputs[1], lm.batch, lm.seq_len, "tokens")?;
        let mut out = Vec::with_capacity(lm.batch * lm.seq_len * lm.vocab);
        for b in 0..lm.batch {
            let row = &tokens[b * lm.seq_len..(b + 1) * lm.seq_len];
            let logits = host.forward(row, &crate::train::AttnMethod::Full, 1);
            out.extend(logits.data().iter().map(|&x| x as f32));
        }
        Ok(vec![HostTensor::f32(
            out,
            &[lm.batch as i64, lm.seq_len as i64, lm.vocab as i64],
        )])
    }

    fn lm_eval_loss(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lm = self.manifest.lm.clone();
        anyhow::ensure!(inputs.len() == 3, "lm_eval_loss takes params, tokens, targets");
        let host = self.host_lm(&inputs[0])?;
        let tokens = Self::lm_tokens(&inputs[1], lm.batch, lm.seq_len, "tokens")?;
        let targets = Self::lm_tokens(&inputs[2], lm.batch, lm.seq_len, "targets")?;
        let mut total = 0.0;
        for b in 0..lm.batch {
            let t = &tokens[b * lm.seq_len..(b + 1) * lm.seq_len];
            let g = &targets[b * lm.seq_len..(b + 1) * lm.seq_len];
            total += host.loss(t, g, &crate::train::AttnMethod::Full, 1);
        }
        let mean = (total / lm.batch as f64) as f32;
        Ok(vec![HostTensor::f32(vec![mean], &[1])])
    }
}

/// FNV-1a over the raw f32 bits (plus the length). One linear pass —
/// far cheaper than re-parsing the model it guards. A colliding pair of
/// distinct parameter vectors would silently share a cache slot, but at
/// 64 bits that risk is negligible against the serving workload.
fn params_fingerprint(p: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in p {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ p.len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_matrix;
    use crate::linalg::top_k_svd;
    use crate::util::Pcg32;

    fn backend(n: usize, d: usize) -> HostBackend {
        HostBackend::new(Manifest::synthetic(n, d))
    }

    fn attn_inputs(n: usize, d: usize, seed: u64) -> AttnInputs {
        let mut rng = Pcg32::seeded(seed);
        AttnInputs {
            q: Mat::randn(n, d, 0.7, &mut rng),
            k: Mat::randn(n, d, 0.7, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: true,
        }
    }

    #[test]
    fn full_attn_matches_host_reference() {
        let (n, d) = (64, 16);
        let be = backend(n, d);
        let inp = attn_inputs(n, d, 1);
        let out = be
            .execute(
                "full_attn",
                &[
                    HostTensor::from_mat(&inp.q),
                    HostTensor::from_mat(&inp.k),
                    HostTensor::from_mat(&inp.v),
                ],
            )
            .unwrap();
        let y = out[0].to_mat(n, d);
        // f32 boundary conversion on inputs, so compare against the
        // reference on the same rounded inputs.
        let rounded = AttnInputs {
            q: Mat::from_f32(n, d, &inp.q.to_f32()),
            k: Mat::from_f32(n, d, &inp.k.to_f32()),
            v: Mat::from_f32(n, d, &inp.v.to_f32()),
            causal: true,
        };
        assert!(y.allclose(&full_attention(&rounded), 1e-4));
    }

    #[test]
    fn lowrank_attn_matches_factor_apply() {
        let (n, d) = (64, 16);
        let be = backend(n, d);
        let inp = attn_inputs(n, d, 2);
        let a = attention_matrix(&inp);
        let bucket = 32;
        let svd = top_k_svd(&a, bucket, 3);
        let rank = 20;
        let mask: Vec<f32> = (0..bucket).map(|i| if i < rank { 1.0 } else { 0.0 }).collect();
        let out = be
            .execute(
                "lowrank_attn_r32",
                &[
                    HostTensor::from_mat(&svd.u.take_cols(bucket)),
                    HostTensor::from_f64s(&svd.s[..bucket]),
                    HostTensor::from_mat(&svd.v.take_cols(bucket).transpose()),
                    HostTensor::from_mat(&inp.v),
                    HostTensor::f32(mask, &[bucket as i64]),
                ],
            )
            .unwrap();
        let host = crate::attention::lowrank_attention_output(&svd, rank, &inp.v);
        assert!(out[0].to_mat(n, d).allclose(&host, 1e-3));
    }

    #[test]
    fn power_iter_estimates_sigma() {
        let (n, d) = (32, 8);
        let be = backend(n, d);
        let mut rng = Pcg32::seeded(4);
        // Spiked spectrum (σ₁ ≫ σ₂) so K=8 power iterations converge to
        // well under the tolerance regardless of the random tail.
        let mut m = Mat::randn(n, n, 0.1, &mut rng);
        let u = Mat::randn(n, 1, 1.0, &mut rng);
        let v = Mat::randn(n, 1, 1.0, &mut rng);
        m.axpy(5.0, &crate::linalg::matmul(&u, &v.transpose()));
        let v0: Vec<f32> = (0..n).map(|i| 1.0 + (i % 3) as f32).collect();
        let out = be
            .execute(
                "power_iter",
                &[
                    HostTensor::from_mat(&m),
                    HostTensor::f32(v0, &[n as i64]),
                ],
            )
            .unwrap();
        let sigma = out[0].scalar();
        let exact = crate::linalg::svd(&m).s[0];
        assert!((sigma - exact).abs() / exact < 0.05, "sigma {sigma} vs {exact}");
    }

    #[test]
    fn lm_logits_and_loss_shapes() {
        let be = backend(32, 8);
        let lm = Manifest::synthetic(32, 8).lm;
        let mut rng = Pcg32::seeded(5);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let p = HostTensor::f32(params, &[lm.param_count as i64]);
        let logits = be
            .execute("lm_logits", &[p.clone(), HostTensor::i32(tokens.clone(), &bl)])
            .unwrap();
        assert_eq!(logits[0].len(), lm.batch * lm.seq_len * lm.vocab);
        let loss = be
            .execute(
                "lm_eval_loss",
                &[p, HostTensor::i32(tokens, &bl), HostTensor::i32(targets, &bl)],
            )
            .unwrap();
        let l = loss[0].scalar();
        assert!(l.is_finite() && l > 0.0, "loss {l}");
    }

    #[test]
    fn lm_cache_hits_on_identical_params_and_misses_on_change() {
        let be = backend(32, 8);
        let lm = Manifest::synthetic(32, 8).lm;
        let mut rng = Pcg32::seeded(6);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let bl = [lm.batch as i64, lm.seq_len as i64];
        let t = HostTensor::i32(tokens, &bl);
        let p = HostTensor::f32(params.clone(), &[lm.param_count as i64]);
        let a = be.execute("lm_logits", &[p.clone(), t.clone()]).unwrap();
        let b = be.execute("lm_logits", &[p, t.clone()]).unwrap();
        // Cached parse must not change results.
        assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
        let mut stats = be.stats();
        assert_eq!(stats.remove("lm_cache_miss"), Some(1));
        assert_eq!(stats.remove("lm_cache_hit"), Some(1));
        // A different parameter vector must invalidate the cache.
        params[0] += 1.0;
        let p2 = HostTensor::f32(params, &[lm.param_count as i64]);
        be.execute("lm_logits", &[p2, t]).unwrap();
        assert_eq!(be.stats().get("lm_cache_miss"), Some(&2));
    }

    #[test]
    fn unknown_and_unsupported_artifacts_error() {
        let be = backend(16, 4);
        assert!(be.execute("nonexistent", &[]).is_err());
        assert!(be.execute("policy_net", &[]).is_err());
        assert!(be.warm("full_attn").is_ok());
        assert!(be.warm("nonexistent").is_err());
    }
}
