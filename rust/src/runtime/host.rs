//! Pure-Rust host backend: the *complete* [`Backend`] implementation,
//! executing every typed op with the crate's own numeric kernels.
//!
//! Semantics mirror the L1/L2 artifacts: full attention is causal
//! blocked attention, the low-rank op is the masked factor apply
//! Y = U·diag(s⊙mask)·(Vᵀ·V_val), power iteration runs K rounds of
//! v ← MᵀMv/‖·‖, the LM ops evaluate/train the decoder LM on the same
//! flat parameter layout (the train step is a hand-written backward +
//! fused AdamW — see [`crate::train::lm_loss_and_grad`]), and
//! `policy_logits` runs the transformer policy encoder on the host
//! ([`super::host_policy`]). Matrix inputs and outputs are rounded
//! through f32 at the op boundary, matching the device path's precision,
//! so swapping backends does not change numerics beyond kernel-level
//! float noise.
//!
//! Unlike the PJRT device thread (whose `Literal`s are not `Send`), the
//! host backend is `Send + Sync` and executes on the *calling* thread —
//! concurrent engine workers and per-head fan-out run kernels genuinely
//! in parallel instead of serializing through one device thread.

use super::backend::{Backend, Capabilities, Op, OpCounters};
use super::manifest::Manifest;
use crate::attention::{full_attention, AttnInputs};
use crate::linalg::{matmul, Mat, Svd};
use crate::train::HostLm;
use crate::util::LockExt;
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Round a matrix through f32, mirroring the artifact boundary.
fn f32_boundary(m: &Mat) -> Mat {
    Mat::from_f32(m.rows(), m.cols(), &m.to_f32())
}

/// Thread-safe host executor over a manifest's shapes.
pub struct HostBackend {
    manifest: Manifest,
    ops: Arc<OpCounters>,
    /// Parsed-LM cache keyed by a fingerprint of the flat param vector:
    /// the generation hot path sends identical params on every decode
    /// step, so re-parsing (and re-allocating) the whole model per
    /// `lm_logits` call was pure overhead. Capacity 1 — serving uses one
    /// frozen parameter set at a time.
    lm_cache: Mutex<Option<(u64, Arc<HostLm>)>>,
    /// Parsed policy cache, same scheme: one forward runs per segment
    /// decision and the weights are frozen for the registry's lifetime.
    policy_cache: Mutex<Option<(u64, Arc<super::host_policy::PolicyNet>)>>,
}

impl HostBackend {
    pub fn new(manifest: Manifest) -> Self {
        Self::with_counters(manifest, Arc::new(OpCounters::default()))
    }

    /// Host backend recording into caller-owned counters (the
    /// [`super::SimBackend`] shares one ledger with its inner host
    /// executor this way, so op and LM-cache counts surface once).
    pub(crate) fn with_counters(manifest: Manifest, ops: Arc<OpCounters>) -> Self {
        HostBackend {
            manifest,
            ops,
            lm_cache: Mutex::new(None),
            policy_cache: Mutex::new(None),
        }
    }

    /// Parsed host LM for the given flat params, served from the
    /// fingerprint-keyed cache. The forward runs outside the cache lock
    /// (`HostLm` evaluation is `&self`), so concurrent callers share one
    /// parsed model without serializing on each other.
    fn host_lm(&self, params: &[f32]) -> Result<Arc<HostLm>> {
        let lm = &self.manifest.lm;
        anyhow::ensure!(
            params.len() == lm.param_count,
            "param vector len {} vs manifest {}",
            params.len(),
            lm.param_count
        );
        let fp = params_fingerprint(params);
        {
            let g = self.lm_cache.lock_unpoisoned();
            if let Some((cached_fp, host)) = g.as_ref() {
                if *cached_fp == fp {
                    let host = Arc::clone(host);
                    drop(g);
                    self.ops.record_lm_cache(true);
                    return Ok(host);
                }
            }
        }
        // Parse outside the lock; a racing miss just parses twice and
        // the last writer wins.
        let parsed = Arc::new(HostLm::from_flat(params, lm));
        *self.lm_cache.lock_unpoisoned() = Some((fp, Arc::clone(&parsed)));
        self.ops.record_lm_cache(false);
        Ok(parsed)
    }

    fn check_tokens(&self, what: &str, t: &[i32]) -> Result<()> {
        let lm = &self.manifest.lm;
        anyhow::ensure!(
            t.len() == lm.batch * lm.seq_len,
            "{what}: got {} tokens, want {}x{}",
            t.len(),
            lm.batch,
            lm.seq_len
        );
        Ok(())
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::complete()
    }

    fn ops(&self) -> Arc<OpCounters> {
        Arc::clone(&self.ops)
    }

    fn full_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        self.ops.record(Op::FullAttention);
        let inp = AttnInputs {
            q: f32_boundary(q),
            k: f32_boundary(k),
            v: f32_boundary(v),
            causal: true,
        };
        Ok(f32_boundary(&full_attention(&inp)))
    }

    /// Y = U·diag(s⊙mask)·(Vᵀ·V_val) — the masked factor apply, with the
    /// first `rank` of `bucket` factors live.
    fn lowrank_attention(&self, svd: &Svd, bucket: usize, rank: usize, v_val: &Mat) -> Result<Mat> {
        self.ops.record(Op::LowRankAttention);
        anyhow::ensure!(svd.s.len() >= bucket, "need ≥{bucket} factors, have {}", svd.s.len());
        let u = f32_boundary(&svd.u.take_cols(bucket));
        let vt = f32_boundary(&svd.v.take_cols(bucket).transpose());
        let s32: Vec<f32> = svd.s[..bucket].iter().map(|&x| x as f32).collect();
        let v_val = f32_boundary(v_val);
        let mut w = matmul(&vt, &v_val); // bucket × d
        for i in 0..bucket {
            let scale = if i < rank { s32[i] as f64 } else { 0.0 };
            for x in w.row_mut(i).iter_mut() {
                *x *= scale;
            }
        }
        Ok(f32_boundary(&matmul(&u, &w)))
    }

    /// K iterations of v ← MᵀMv/‖·‖ from the given v0, then σ = ‖Mv‖
    /// (mirrors python/compile/kernels/power_iter.py).
    fn power_iter_sigma(&self, m: &Mat, v0: &[f64]) -> Result<f64> {
        self.ops.record(Op::PowerIterSigma);
        anyhow::ensure!(v0.len() == m.cols(), "v0 length {} vs {}", v0.len(), m.cols());
        let m = f32_boundary(m);
        let mut v: Vec<f64> = v0.iter().map(|&x| (x as f32) as f64).collect();
        let norm = |x: &[f64]| x.iter().map(|a| a * a).sum::<f64>().sqrt();
        let scale = norm(&v).max(1e-30);
        v.iter_mut().for_each(|x| *x /= scale);
        for _ in 0..self.manifest.kernel.power_iters.max(1) {
            let w = crate::linalg::matvec(&m, &v);
            let mut next = crate::linalg::matvec_t(&m, &w);
            let nrm = norm(&next).max(1e-30);
            next.iter_mut().for_each(|x| *x /= nrm);
            v = next;
        }
        let sigma = norm(&crate::linalg::matvec(&m, &v));
        Ok((sigma as f32) as f64)
    }

    fn policy_logits(&self, weights: &[f32], state: &[f64]) -> Result<Vec<f64>> {
        self.ops.record(Op::PolicyLogits);
        let fp = params_fingerprint(weights);
        {
            let g = self.policy_cache.lock_unpoisoned();
            if let Some((cached_fp, net)) = g.as_ref() {
                if *cached_fp == fp {
                    let net = Arc::clone(net);
                    drop(g);
                    return net.forward(state);
                }
            }
        }
        let net = Arc::new(super::host_policy::PolicyNet::parse(
            weights,
            &self.manifest.policy,
        )?);
        *self.policy_cache.lock_unpoisoned() = Some((fp, Arc::clone(&net)));
        net.forward(state)
    }

    fn lm_logits(&self, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        self.ops.record(Op::LmLogits);
        let lm = self.manifest.lm.clone();
        self.check_tokens("tokens", tokens)?;
        let host = self.host_lm(params)?;
        let mut out = Vec::with_capacity(lm.batch * lm.seq_len * lm.vocab);
        for b in 0..lm.batch {
            let row = &tokens[b * lm.seq_len..(b + 1) * lm.seq_len];
            let logits = host.forward(row, &crate::train::AttnMethod::Full, 1);
            out.extend(logits.data().iter().map(|&x| x as f32));
        }
        Ok(out)
    }

    fn lm_eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f64> {
        self.ops.record(Op::LmEvalLoss);
        let lm = self.manifest.lm.clone();
        self.check_tokens("tokens", tokens)?;
        self.check_tokens("targets", targets)?;
        let host = self.host_lm(params)?;
        let mut total = 0.0;
        for b in 0..lm.batch {
            let t = &tokens[b * lm.seq_len..(b + 1) * lm.seq_len];
            let g = &targets[b * lm.seq_len..(b + 1) * lm.seq_len];
            total += host.loss(t, g, &crate::train::AttnMethod::Full, 1);
        }
        Ok(((total / lm.batch as f64) as f32) as f64)
    }

    /// Forward + hand-written backward + fused AdamW on the host — the
    /// previously PJRT-only train step, now offline.
    fn lm_train_step(
        &self,
        params: &mut Vec<f32>,
        adam_m: &mut Vec<f32>,
        adam_v: &mut Vec<f32>,
        step: f32,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f64> {
        self.ops.record(Op::LmTrainStep);
        let lm = &self.manifest.lm;
        self.check_tokens("tokens", tokens)?;
        self.check_tokens("targets", targets)?;
        anyhow::ensure!(
            adam_m.len() == params.len() && adam_v.len() == params.len(),
            "Adam moment vectors must match the param vector"
        );
        let (loss, grad) = crate::train::lm_loss_and_grad(params, lm, tokens, targets)?;
        crate::train::adamw_step(params, adam_m, adam_v, &grad, step, lm.lr, lm.weight_decay);
        Ok((loss as f32) as f64)
    }
}

/// FNV-1a over the raw f32 bits (plus the length). One linear pass —
/// far cheaper than re-parsing the model it guards. A colliding pair of
/// distinct parameter vectors would silently share a cache slot, but at
/// 64 bits that risk is negligible against the serving workload.
fn params_fingerprint(p: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in p {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ p.len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_matrix;
    use crate::linalg::top_k_svd;
    use crate::util::Pcg32;

    fn backend(n: usize, d: usize) -> HostBackend {
        HostBackend::new(Manifest::synthetic(n, d))
    }

    fn attn_inputs(n: usize, d: usize, seed: u64) -> AttnInputs {
        let mut rng = Pcg32::seeded(seed);
        AttnInputs {
            q: Mat::randn(n, d, 0.7, &mut rng),
            k: Mat::randn(n, d, 0.7, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: true,
        }
    }

    #[test]
    fn full_attn_matches_host_reference() {
        let (n, d) = (64, 16);
        let be = backend(n, d);
        let inp = attn_inputs(n, d, 1);
        let y = be.full_attention(&inp.q, &inp.k, &inp.v).unwrap();
        // f32 boundary conversion on inputs, so compare against the
        // reference on the same rounded inputs.
        let rounded = AttnInputs {
            q: Mat::from_f32(n, d, &inp.q.to_f32()),
            k: Mat::from_f32(n, d, &inp.k.to_f32()),
            v: Mat::from_f32(n, d, &inp.v.to_f32()),
            causal: true,
        };
        assert!(y.allclose(&full_attention(&rounded), 1e-4));
        assert_eq!(be.ops().get(Op::FullAttention), 1);
    }

    #[test]
    fn lowrank_attn_matches_factor_apply() {
        let (n, d) = (64, 16);
        let be = backend(n, d);
        let inp = attn_inputs(n, d, 2);
        let a = attention_matrix(&inp);
        let bucket = 32;
        let svd = top_k_svd(&a, bucket, 3);
        let rank = 20;
        let y = be.lowrank_attention(&svd, bucket, rank, &inp.v).unwrap();
        let host = crate::attention::lowrank_attention_output(&svd, rank, &inp.v);
        assert!(y.allclose(&host, 1e-3));
    }

    #[test]
    fn lowrank_attn_rejects_short_spectrum() {
        let be = backend(16, 4);
        let mut rng = Pcg32::seeded(3);
        let a = Mat::randn(16, 16, 1.0, &mut rng);
        let svd = top_k_svd(&a, 8, 3);
        let v = Mat::randn(16, 4, 1.0, &mut rng);
        assert!(be.lowrank_attention(&svd, 16, 8, &v).is_err());
    }

    #[test]
    fn power_iter_estimates_sigma() {
        let (n, d) = (32, 8);
        let be = backend(n, d);
        let mut rng = Pcg32::seeded(4);
        // Spiked spectrum (σ₁ ≫ σ₂) so K=8 power iterations converge to
        // well under the tolerance regardless of the random tail.
        let mut m = Mat::randn(n, n, 0.1, &mut rng);
        let u = Mat::randn(n, 1, 1.0, &mut rng);
        let v = Mat::randn(n, 1, 1.0, &mut rng);
        m.axpy(5.0, &crate::linalg::matmul(&u, &v.transpose()));
        let v0: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let sigma = be.power_iter_sigma(&m, &v0).unwrap();
        let exact = crate::linalg::svd(&m).s[0];
        assert!((sigma - exact).abs() / exact < 0.05, "sigma {sigma} vs {exact}");
    }

    #[test]
    fn lm_logits_and_loss_shapes() {
        let be = backend(32, 8);
        let lm = Manifest::synthetic(32, 8).lm;
        let mut rng = Pcg32::seeded(5);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let logits = be.lm_logits(&params, &tokens).unwrap();
        assert_eq!(logits.len(), lm.batch * lm.seq_len * lm.vocab);
        let l = be.lm_eval_loss(&params, &tokens, &targets).unwrap();
        assert!(l.is_finite() && l > 0.0, "loss {l}");
    }

    #[test]
    fn lm_cache_hits_on_identical_params_and_misses_on_change() {
        let be = backend(32, 8);
        let lm = Manifest::synthetic(32, 8).lm;
        let mut rng = Pcg32::seeded(6);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let a = be.lm_logits(&params, &tokens).unwrap();
        let b = be.lm_logits(&params, &tokens).unwrap();
        // Cached parse must not change results.
        assert_eq!(a, b);
        assert_eq!(be.ops().lm_cache_misses(), 1);
        assert_eq!(be.ops().lm_cache_hits(), 1);
        // A different parameter vector must invalidate the cache.
        params[0] += 1.0;
        be.lm_logits(&params, &tokens).unwrap();
        assert_eq!(be.ops().lm_cache_misses(), 2);
    }

    #[test]
    fn host_backend_is_complete() {
        let be = backend(16, 4);
        for op in Op::ALL {
            assert!(be.capabilities().supports(op), "host must support {op}");
            assert!(be.warm(op).is_ok());
        }
        assert!(be.projected_ms().is_none());
    }

    #[test]
    fn policy_logits_run_on_host() {
        let be = backend(16, 4);
        let shape = Manifest::synthetic(16, 4).policy;
        let weights = super::super::host_policy::synthesize_weights(&shape, 42);
        let state = vec![0.1f64; shape.state_dim];
        let logits = be.policy_logits(&weights, &state).unwrap();
        assert_eq!(logits.len(), shape.n_actions);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lm_train_step_reduces_loss_on_repeated_batch() {
        let be = backend(16, 4);
        let lm = Manifest::synthetic(16, 4).lm;
        let mut rng = Pcg32::seeded(10);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let mut m = vec![0f32; lm.param_count];
        let mut v = vec![0f32; lm.param_count];
        let bl = lm.batch * lm.seq_len;
        let tokens: Vec<i32> = (0..bl).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let first = be.lm_train_step(&mut params, &mut m, &mut v, 0.0, &tokens, &targets).unwrap();
        let mut last = first;
        for s in 1..8 {
            last = be
                .lm_train_step(&mut params, &mut m, &mut v, s as f32, &tokens, &targets)
                .unwrap();
        }
        assert!(last < first, "loss did not drop: {first} → {last}");
        // Eval loss agrees with the train-path loss on identical data.
        let eval = be.lm_eval_loss(&params, &tokens, &targets).unwrap();
        assert!((eval - last).abs() / last < 0.5, "eval {eval} vs train {last}");
        assert_eq!(be.ops().get(Op::LmTrainStep), 8);
    }
}
