//! The typed, pluggable execution backend API.
//!
//! A [`Backend`] executes the runtime's kernel operations through *typed*
//! methods — no artifact-name strings cross this boundary. Callers go
//! through the [`super::ArtifactRegistry`] adapter, which owns shape and
//! rank-bucket validation; backends receive pre-validated inputs and are
//! free to marshal them however their execution substrate requires
//! (in-process kernels, a PJRT device thread, a hardware cost model).
//!
//! Three implementations ship with the crate:
//!
//! * [`super::HostBackend`] — pure-Rust kernels, complete (every [`Op`]
//!   including the transformer policy and the fused-AdamW train step).
//! * `PjrtBackend` (feature `pjrt`) — the compiled HLO artifacts on a
//!   dedicated device thread.
//! * [`super::SimBackend`] — host kernels plus a roofline latency model
//!   ([`crate::sim::DeviceProfile`]), so latency-aware experiments run
//!   without a device.
//!
//! Support is *declared*, not discovered by panicking: an op a backend
//! cannot run is absent from [`Capabilities`] and its method returns a
//! typed "unsupported" error (the default body). The conformance suite
//! (`rust/tests/backend_conformance.rs`) holds every compiled-in backend
//! to this contract.

use crate::linalg::{Mat, Svd};
use crate::util::LockExt;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The typed kernel operations a backend may implement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Op {
    /// Dense causal attention for one head.
    FullAttention,
    /// Masked factor apply Y = U·diag(s⊙mask)·(Vᵀ·V_val) at a rank bucket.
    LowRankAttention,
    /// Power-iteration spectral-norm estimate.
    PowerIterSigma,
    /// Transformer-policy logits over the rank grid.
    PolicyLogits,
    /// Decoder-LM inference logits for one (B, L) batch.
    LmLogits,
    /// Decoder-LM evaluation loss for one batch.
    LmEvalLoss,
    /// One fused AdamW train step (forward + backward + update).
    LmTrainStep,
}

/// Number of distinct ops (array sizing for [`OpCounters`]).
const N_OPS: usize = 7;

impl Op {
    /// Every operation, in a stable order.
    pub const ALL: [Op; N_OPS] = [
        Op::FullAttention,
        Op::LowRankAttention,
        Op::PowerIterSigma,
        Op::PolicyLogits,
        Op::LmLogits,
        Op::LmEvalLoss,
        Op::LmTrainStep,
    ];

    /// Stable snake_case name (metrics keys, error messages).
    pub fn name(self) -> &'static str {
        match self {
            Op::FullAttention => "full_attention",
            Op::LowRankAttention => "lowrank_attention",
            Op::PowerIterSigma => "power_iter_sigma",
            Op::PolicyLogits => "policy_logits",
            Op::LmLogits => "lm_logits",
            Op::LmEvalLoss => "lm_eval_loss",
            Op::LmTrainStep => "lm_train_step",
        }
    }

    fn index(self) -> usize {
        Op::ALL.iter().position(|&o| o == self).expect("op in ALL")
    }
}

impl std::fmt::Display for Op {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a backend can do, reported up front so callers never have to
/// probe by catching panics or errors.
#[derive(Debug, Clone)]
pub struct Capabilities {
    /// Operations the backend executes.
    pub supported: Vec<Op>,
    /// The backend models execution latency (see
    /// [`Backend::projected_ms`]).
    pub models_latency: bool,
}

impl Capabilities {
    /// Every op, no latency model (the complete compute backends).
    pub fn complete() -> Capabilities {
        Capabilities { supported: Op::ALL.to_vec(), models_latency: false }
    }

    pub fn supports(&self, op: Op) -> bool {
        self.supported.contains(&op)
    }
}

/// The typed error every backend returns for an op outside its
/// [`Capabilities`].
pub fn unsupported(backend: &str, op: Op) -> anyhow::Error {
    anyhow::anyhow!(
        "op '{op}' is not supported by the '{backend}' backend \
         (check Backend::capabilities() before dispatching)"
    )
}

/// Per-op execute counters plus the host LM parse-cache counters —
/// the typed replacement for the old per-artifact `stats()` BTreeMap.
/// Shared (`Arc`) between a backend and [`crate::coordinator::Metrics`],
/// which folds the counts into its `report()`.
#[derive(Default)]
pub struct OpCounters {
    counts: [AtomicU64; N_OPS],
    lm_cache_hits: AtomicU64,
    lm_cache_misses: AtomicU64,
}

impl OpCounters {
    pub fn record(&self, op: Op) {
        self.counts[op.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, op: Op) -> u64 {
        self.counts[op.index()].load(Ordering::Relaxed)
    }

    /// Total executes across every op.
    pub fn total(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn record_lm_cache(&self, hit: bool) {
        if hit {
            self.lm_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.lm_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn lm_cache_hits(&self) -> u64 {
        self.lm_cache_hits.load(Ordering::Relaxed)
    }

    pub fn lm_cache_misses(&self) -> u64 {
        self.lm_cache_misses.load(Ordering::Relaxed)
    }

    /// One-line summary of the non-zero counters, e.g.
    /// `lowrank_attention=42 lm_logits=7 lm_cache=6/1`.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> = Op::ALL
            .iter()
            .filter(|&&op| self.get(op) > 0)
            .map(|&op| format!("{op}={}", self.get(op)))
            .collect();
        let (hits, misses) = (self.lm_cache_hits(), self.lm_cache_misses());
        if hits + misses > 0 {
            parts.push(format!("lm_cache={hits}/{misses}"));
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(" ")
        }
    }
}

/// Cumulative projected-latency ledger for backends that model hardware
/// timing (the [`super::SimBackend`]).
///
/// Besides the running total, the ledger supports *scoped* reads: take a
/// [`LedgerMark`] before an op wave and read [`LatencyLedger::since`]
/// after it to attribute the wave's charges — that is how per-request
/// `projected_ms` attribution is pinned against the backend's own
/// accounting (see `rust/tests/backend_conformance.rs`).
#[derive(Default)]
pub struct LatencyLedger {
    total_ms: Mutex<f64>,
}

/// A point-in-time ledger position, for delta (scoped) reads.
#[derive(Debug, Clone, Copy)]
pub struct LedgerMark(f64);

impl LatencyLedger {
    pub fn add_ms(&self, ms: f64) {
        *self.total_ms.lock_unpoisoned() += ms;
    }

    pub fn total_ms(&self) -> f64 {
        *self.total_ms.lock_unpoisoned()
    }

    /// The current ledger position, for a later scoped read.
    pub fn mark(&self) -> LedgerMark {
        LedgerMark(self.total_ms())
    }

    /// Milliseconds charged since `mark` was taken. Only attributable to
    /// one op wave when no other backend traffic interleaves — callers
    /// scope marks to exclusive sections (single-worker runs, tests).
    pub fn since(&self, mark: LedgerMark) -> f64 {
        self.total_ms() - mark.0
    }
}

/// A typed, pluggable execution backend.
///
/// Methods default to a typed "unsupported" error; implementations
/// override exactly the set their [`Capabilities`] declare. The
/// [`super::ArtifactRegistry`] adapter validates shapes and rank
/// buckets against the manifest before dispatching; backends are also
/// usable directly (the conformance suite does), so they keep their own
/// cheap guards on sizes they would otherwise index out of bounds with —
/// a deliberate second line of defense, not the primary validation
/// surface.
#[allow(unused_variables)]
pub trait Backend: Send + Sync {
    /// Stable backend name (`host`, `pjrt`, `sim`).
    fn name(&self) -> &'static str;

    /// What this backend can execute.
    fn capabilities(&self) -> Capabilities;

    /// Shared per-op execute counters.
    fn ops(&self) -> Arc<OpCounters>;

    /// Prepare an op ahead of first use (compile for PJRT, no-op on the
    /// host). Unsupported ops error.
    fn warm(&self, op: Op) -> Result<()> {
        if self.capabilities().supports(op) {
            Ok(())
        } else {
            Err(unsupported(self.name(), op))
        }
    }

    /// Dense causal attention: q, k, v are n×d.
    fn full_attention(&self, q: &Mat, k: &Mat, v: &Mat) -> Result<Mat> {
        Err(unsupported(self.name(), Op::FullAttention))
    }

    /// Masked factor apply at `bucket` columns of `svd` with the first
    /// `rank` factors live: Y = U·diag(s⊙mask)·(Vᵀ·V_val).
    fn lowrank_attention(&self, svd: &Svd, bucket: usize, rank: usize, v_val: &Mat) -> Result<Mat> {
        Err(unsupported(self.name(), Op::LowRankAttention))
    }

    /// Spectral-norm estimate of `m` from start vector `v0`.
    fn power_iter_sigma(&self, m: &Mat, v0: &[f64]) -> Result<f64> {
        Err(unsupported(self.name(), Op::PowerIterSigma))
    }

    /// Transformer-policy logits for one state. `weights` is the flat
    /// parameter vector in the `policy_net` layout.
    fn policy_logits(&self, weights: &[f32], state: &[f64]) -> Result<Vec<f64>> {
        Err(unsupported(self.name(), Op::PolicyLogits))
    }

    /// LM inference logits, (B·L·V) flattened.
    fn lm_logits(&self, params: &[f32], tokens: &[i32]) -> Result<Vec<f32>> {
        Err(unsupported(self.name(), Op::LmLogits))
    }

    /// LM evaluation loss on one batch.
    fn lm_eval_loss(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> Result<f64> {
        Err(unsupported(self.name(), Op::LmEvalLoss))
    }

    /// One fused AdamW train step; updates params and moments in place
    /// and returns the batch loss.
    fn lm_train_step(
        &self,
        params: &mut Vec<f32>,
        adam_m: &mut Vec<f32>,
        adam_v: &mut Vec<f32>,
        step: f32,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<f64> {
        Err(unsupported(self.name(), Op::LmTrainStep))
    }

    /// Cumulative *projected* execution latency in milliseconds, for
    /// backends whose [`Capabilities::models_latency`] is true.
    fn projected_ms(&self) -> Option<f64> {
        None
    }

    /// The [`LatencyLedger`] behind [`Backend::projected_ms`], for scoped
    /// (delta) reads. `Some` exactly when `models_latency` is true.
    fn latency_ledger(&self) -> Option<&LatencyLedger> {
        None
    }

    /// The device profile this backend's latency model projects onto.
    /// `Some` exactly when [`Capabilities::models_latency`] is true; the
    /// serving stack uses it to attribute per-request `projected_ms`
    /// with the same roofline formulas the backend charges with.
    fn device_profile(&self) -> Option<crate::sim::DeviceProfile> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Inert(Arc<OpCounters>);

    impl Backend for Inert {
        fn name(&self) -> &'static str {
            "inert"
        }

        fn capabilities(&self) -> Capabilities {
            Capabilities { supported: vec![], models_latency: false }
        }

        fn ops(&self) -> Arc<OpCounters> {
            Arc::clone(&self.0)
        }
    }

    #[test]
    fn default_methods_report_unsupported_instead_of_panicking() {
        let be = Inert(Arc::new(OpCounters::default()));
        let m = Mat::zeros(2, 2);
        let err = be.full_attention(&m, &m, &m).unwrap_err();
        assert!(format!("{err:#}").contains("full_attention"), "{err:#}");
        assert!(format!("{err:#}").contains("inert"));
        for op in Op::ALL {
            assert!(!be.capabilities().supports(op));
            assert!(be.warm(op).is_err());
        }
        assert!(be.projected_ms().is_none());
        assert!(be.latency_ledger().is_none());
        assert!(be.device_profile().is_none());
    }

    #[test]
    fn ledger_scoped_reads_attribute_deltas() {
        let l = LatencyLedger::default();
        l.add_ms(1.5);
        let mark = l.mark();
        assert_eq!(l.since(mark), 0.0);
        l.add_ms(2.25);
        l.add_ms(0.25);
        assert!((l.since(mark) - 2.5).abs() < 1e-12);
        assert!((l.total_ms() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn op_counters_record_and_summarize() {
        let c = OpCounters::default();
        c.record(Op::LowRankAttention);
        c.record(Op::LowRankAttention);
        c.record(Op::LmLogits);
        c.record_lm_cache(true);
        c.record_lm_cache(false);
        assert_eq!(c.get(Op::LowRankAttention), 2);
        assert_eq!(c.get(Op::FullAttention), 0);
        assert_eq!(c.total(), 3);
        let s = c.summary();
        assert!(s.contains("lowrank_attention=2"), "{s}");
        assert!(s.contains("lm_cache=1/1"), "{s}");
        assert!(!s.contains("full_attention"), "{s}");
    }

    #[test]
    fn empty_counters_summarize_as_none() {
        assert_eq!(OpCounters::default().summary(), "none");
    }

    #[test]
    fn capabilities_complete_covers_all_ops() {
        let caps = Capabilities::complete();
        for op in Op::ALL {
            assert!(caps.supports(op));
        }
        assert!(!caps.models_latency);
    }
}
