//! Rank-selection policies: the learned DR-RL agent plus every baseline
//! the paper compares against (Table 1: Fixed Low-Rank, Adaptive SVD,
//! Random Rank; Table 3 adds Performer- and Nyströmformer-style static
//! attention approximators).

pub mod drrl;
pub mod static_attention;
pub mod static_baselines;

pub use drrl::DrRlPolicy;
pub use static_attention::{nystrom_attention, performer_attention, StaticAttnKind};
pub use static_baselines::{
    AdaptiveSvdPolicy, FixedRankPolicy, OraclePolicy, RandomRankPolicy, SoftThresholdPolicy,
};

use crate::rl::RankState;

/// A policy maps the observed state (plus the trust-region mask) to an
/// index into the environment's rank grid.
pub trait RankPolicy {
    /// Choose an action index. `spectrum` is the current attention
    /// spectrum (some baselines decide on it directly rather than on the
    /// featurized state).
    fn choose(&mut self, state: &RankState, spectrum: &[f64], mask: &[bool]) -> usize;

    /// Human-readable name for experiment tables.
    fn name(&self) -> &'static str;
}
