//! The deployable DR-RL policy: a trained actor network behind the
//! `RankPolicy` interface, with greedy (argmax) or stochastic action
//! selection and the safety mask applied at the distribution level.

use super::RankPolicy;
use crate::rl::{ActorCritic, RankState};
use crate::util::Pcg32;

/// Learned policy wrapper.
pub struct DrRlPolicy {
    pub ac: ActorCritic,
    /// Greedy at deployment (paper inference mode); stochastic during
    /// evaluation studies of exploration.
    pub greedy: bool,
    rng: Pcg32,
    /// Decision counter (drives ε annealing upstream; kept for metrics).
    pub decisions: u64,
}

impl DrRlPolicy {
    pub fn new(ac: ActorCritic, greedy: bool, seed: u64) -> Self {
        DrRlPolicy { ac, greedy, rng: Pcg32::seeded(seed), decisions: 0 }
    }
}

impl RankPolicy for DrRlPolicy {
    fn choose(&mut self, state: &RankState, _spectrum: &[f64], mask: &[bool]) -> usize {
        self.decisions += 1;
        let dist = self.ac.distribution(&state.features, Some(mask));
        if self.greedy {
            dist.argmax()
        } else {
            dist.sample(&mut self.rng)
        }
    }

    fn name(&self) -> &'static str {
        "dr-rl"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_deterministic() {
        let ac = ActorCritic::new(6, 16, 4, 1e-3, 1);
        let mut p = DrRlPolicy::new(ac, true, 2);
        let st = RankState { features: vec![0.3; 6] };
        let a1 = p.choose(&st, &[], &[true; 4]);
        let a2 = p.choose(&st, &[], &[true; 4]);
        assert_eq!(a1, a2);
        assert_eq!(p.decisions, 2);
    }

    #[test]
    fn masked_actions_never_chosen() {
        let ac = ActorCritic::new(6, 16, 4, 1e-3, 3);
        let mut p = DrRlPolicy::new(ac, false, 4);
        let st = RankState { features: vec![-0.5; 6] };
        let mask = [false, true, false, true];
        for _ in 0..50 {
            let a = p.choose(&st, &[], &mask);
            assert!(mask[a]);
        }
    }
}
