//! Static linear-attention approximators for Table 3: Performer (FAVOR+
//! positive random features) and Nyströmformer (landmark-based Nyström
//! approximation of softmax attention). These replace the attention
//! *mechanism* (not just the rank), so they live here rather than in the
//! rank-policy hierarchy.

use crate::attention::AttnInputs;
use crate::linalg::{matmul, matmul_at, matmul_bt, Mat};
use crate::util::Pcg32;

/// Which static approximator a baseline model uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StaticAttnKind {
    Performer,
    Nystromformer,
}

/// Performer / FAVOR+ attention with positive orthogonal-ish random
/// features: φ(x) = exp(ωᵀx − ‖x‖²/2)/√m, attention ≈ φ(Q)(φ(K)ᵀV)
/// row-normalized. Complexity O(n·m·d).
pub fn performer_attention(inp: &AttnInputs, n_features: usize, seed: u64) -> Mat {
    let d = inp.head_dim();
    let scale = 1.0 / (d as f64).sqrt();
    let mut rng = Pcg32::seeded(seed);
    // Random projection ω ~ N(0, I) (orthogonality improves variance but
    // plain Gaussian features suffice at our scales).
    let omega = Mat::randn(d, n_features, 1.0, &mut rng);

    let phi = |x: &Mat| -> Mat {
        // x is n×d, pre-scaled by 1/√√d on both sides ⇒ use x·√scale.
        let xs = x.scale(scale.sqrt());
        let proj = matmul(&xs, &omega); // n×m
        let mut out = Mat::zeros(proj.rows(), proj.cols());
        for i in 0..proj.rows() {
            let sq = xs.row(i).iter().map(|v| v * v).sum::<f64>() / 2.0;
            for j in 0..proj.cols() {
                out[(i, j)] = (proj[(i, j)] - sq).exp() / (n_features as f64).sqrt();
            }
        }
        out
    };

    let qf = phi(&inp.q); // n×m
    let kf = phi(&inp.k); // n×m
    // KV = φ(K)ᵀ·V : m×d ; normalizer z = φ(K)ᵀ·1 : m
    let kv = matmul_at(&kf, &inp.v);
    let ones = Mat::filled(inp.k.rows(), 1, 1.0);
    let z = matmul_at(&kf, &ones); // m×1
    let num = matmul(&qf, &kv); // n×d
    let den = matmul(&qf, &z); // n×1
    let mut out = num;
    for i in 0..out.rows() {
        let d_i = den[(i, 0)].max(1e-9);
        for v in out.row_mut(i).iter_mut() {
            *v /= d_i;
        }
    }
    out
}

/// Nyströmformer attention with `m` landmarks: segment-mean landmarks,
/// Ã = softmax(Q·K̃ᵀ/√d) · pinv(softmax(Q̃·K̃ᵀ/√d)) · softmax(Q̃·Kᵀ/√d) · V.
pub fn nystrom_attention(inp: &AttnInputs, n_landmarks: usize, _seed: u64) -> Mat {
    let n = inp.q.rows();
    let d = inp.head_dim() as f64;
    let m = n_landmarks.min(n).max(1);
    let q_l = segment_means(&inp.q, m);
    let k_l = segment_means(&inp.k, m);

    let sm = |mut s: Mat| -> Mat {
        s.scale_inplace(1.0 / d.sqrt());
        crate::attention::softmax_rows_inplace(&mut s);
        s
    };
    let f = sm(matmul_bt(&inp.q, &k_l)); // n×m
    let a = sm(matmul_bt(&q_l, &k_l)); // m×m
    let b = sm(matmul_bt(&q_l, &inp.k)); // m×n
    let a_pinv = pinv_iterative(&a, 12);
    let bv = matmul(&b, &inp.v); // m×d
    let fbv = matmul(&a_pinv, &bv); // m×d
    matmul(&f, &fbv) // n×d
}

/// Landmark construction: means of contiguous segments.
fn segment_means(x: &Mat, m: usize) -> Mat {
    let n = x.rows();
    let mut out = Mat::zeros(m, x.cols());
    for s in 0..m {
        let lo = s * n / m;
        let hi = ((s + 1) * n / m).max(lo + 1).min(n);
        for i in lo..hi {
            for (j, v) in x.row(i).iter().enumerate() {
                out[(s, j)] += v;
            }
        }
        let cnt = (hi - lo) as f64;
        for v in out.row_mut(s).iter_mut() {
            *v /= cnt;
        }
    }
    out
}

/// Newton–Schulz iterative pseudo-inverse (as in the Nyströmformer paper,
/// avoiding an explicit SVD on the hot path).
fn pinv_iterative(a: &Mat, iters: usize) -> Mat {
    let n = a.rows();
    // Initialization: Aᵀ / (‖A‖₁‖A‖∞) guarantees convergence.
    let norm1 = (0..a.cols())
        .map(|j| (0..n).map(|i| a[(i, j)].abs()).sum::<f64>())
        .fold(0.0f64, f64::max);
    let norm_inf = (0..n)
        .map(|i| a.row(i).iter().map(|v| v.abs()).sum::<f64>())
        .fold(0.0f64, f64::max);
    let mut z = a.transpose().scale(1.0 / (norm1 * norm_inf).max(1e-12));
    let eye = Mat::eye(n);
    for _ in 0..iters {
        let az = matmul(a, &z); // n×n
        // Z ← Z(13I − AZ(15I − AZ(7I − AZ)))/4  — 3rd-order NS (Nyströmformer).
        let t1 = &eye.scale(7.0) - &az;
        let t2 = &eye.scale(15.0) - &matmul(&az, &t1);
        let t3 = &eye.scale(13.0) - &matmul(&az, &t2);
        z = matmul(&z, &t3).scale(0.25);
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full_attention;

    fn inputs(n: usize, d: usize, seed: u64) -> AttnInputs {
        let mut rng = Pcg32::seeded(seed);
        AttnInputs {
            q: Mat::randn(n, d, 0.5, &mut rng),
            k: Mat::randn(n, d, 0.5, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: false,
        }
    }

    #[test]
    fn performer_approximates_softmax_attention() {
        let inp = inputs(24, 8, 1);
        let exact = full_attention(&inp);
        let approx = performer_attention(&inp, 256, 2);
        let rel = (&exact - &approx).fro_norm() / exact.fro_norm();
        assert!(rel < 0.35, "performer rel err {rel}");
        // More features → better approximation (variance shrinks).
        let worse = performer_attention(&inp, 8, 2);
        let rel_worse = (&exact - &worse).fro_norm() / exact.fro_norm();
        assert!(rel < rel_worse, "{rel} !< {rel_worse}");
    }

    #[test]
    fn nystrom_with_all_landmarks_is_close() {
        let inp = inputs(16, 8, 3);
        let exact = full_attention(&inp);
        let approx = nystrom_attention(&inp, 16, 0);
        let rel = (&exact - &approx).fro_norm() / exact.fro_norm();
        assert!(rel < 0.15, "nystrom full-landmark rel err {rel}");
    }

    #[test]
    fn nystrom_improves_with_landmarks() {
        let inp = inputs(32, 8, 4);
        let exact = full_attention(&inp);
        let few = nystrom_attention(&inp, 2, 0);
        let many = nystrom_attention(&inp, 16, 0);
        let e_few = (&exact - &few).fro_norm();
        let e_many = (&exact - &many).fro_norm();
        assert!(e_many < e_few, "{e_many} !< {e_few}");
    }

    #[test]
    fn pinv_inverts_well_conditioned() {
        let mut rng = Pcg32::seeded(5);
        // Diagonally dominant → well-conditioned.
        let mut a = Mat::randn(6, 6, 0.1, &mut rng);
        for i in 0..6 {
            a[(i, i)] += 1.0;
        }
        let z = pinv_iterative(&a, 20);
        let prod = matmul(&a, &z);
        assert!(prod.allclose(&Mat::eye(6), 1e-6), "A·A⁺ ≉ I: {prod:?}");
    }

    #[test]
    fn outputs_finite() {
        let inp = inputs(20, 4, 6);
        for m in [1usize, 4, 10] {
            let y = nystrom_attention(&inp, m, 0);
            assert!(y.data().iter().all(|v| v.is_finite()), "m={m}");
        }
        for f in [4usize, 64] {
            let y = performer_attention(&inp, f, 7);
            assert!(y.data().iter().all(|v| v.is_finite()), "features={f}");
        }
    }
}
