//! Static / heuristic rank policies — the paper's Table 1 baselines.

use super::RankPolicy;
use crate::rl::{RankEnv, RankState};
use crate::spectral::{rank_for_energy, soft_threshold_rank};
use crate::util::Pcg32;

/// Fixed Low-Rank (Linformer-style, paper r=32): one rank for every
/// layer, head and input.
pub struct FixedRankPolicy {
    grid: Vec<usize>,
    target_rank: usize,
}

impl FixedRankPolicy {
    pub fn new(grid: Vec<usize>, target_rank: usize) -> Self {
        FixedRankPolicy { grid, target_rank }
    }
}

impl RankPolicy for FixedRankPolicy {
    fn choose(&mut self, _state: &RankState, _spectrum: &[f64], mask: &[bool]) -> usize {
        // Nearest grid entry to the target that is admissible.
        nearest_admissible(&self.grid, self.target_rank, mask)
    }

    fn name(&self) -> &'static str {
        "fixed-low-rank"
    }
}

/// Adaptive SVD (energy-threshold heuristic [34]): smallest rank whose
/// NER reaches the threshold (default 90%).
pub struct AdaptiveSvdPolicy {
    grid: Vec<usize>,
    pub threshold: f64,
}

impl AdaptiveSvdPolicy {
    pub fn new(grid: Vec<usize>, threshold: f64) -> Self {
        AdaptiveSvdPolicy { grid, threshold }
    }
}

impl RankPolicy for AdaptiveSvdPolicy {
    fn choose(&mut self, _state: &RankState, spectrum: &[f64], mask: &[bool]) -> usize {
        let wanted = rank_for_energy(spectrum, self.threshold);
        // Round *up* to the next grid rank (energy guarantee), then mask.
        let target = self
            .grid
            .iter()
            .copied()
            .filter(|&g| g >= wanted)
            .min()
            .unwrap_or_else(|| *self.grid.iter().max().unwrap());
        nearest_admissible(&self.grid, target, mask)
    }

    fn name(&self) -> &'static str {
        "adaptive-svd"
    }
}

/// Soft-thresholding rank rule (SoftLMs, arXiv:2411.10543): keep the
/// singular values surviving `σ_i − τ·σ_0 > 0` and round the count to
/// the nearest admissible grid rank. Unlike Adaptive-SVD's cumulative
/// energy rule, this thresholds each σ_i individually against the
/// spectral norm, so it reacts to the spectrum's *tail height* rather
/// than its integrated mass.
pub struct SoftThresholdPolicy {
    grid: Vec<usize>,
    pub tau: f64,
}

impl SoftThresholdPolicy {
    pub fn new(grid: Vec<usize>, tau: f64) -> Self {
        SoftThresholdPolicy { grid, tau }
    }
}

impl RankPolicy for SoftThresholdPolicy {
    fn choose(&mut self, _state: &RankState, spectrum: &[f64], mask: &[bool]) -> usize {
        nearest_admissible(&self.grid, soft_threshold_rank(spectrum, self.tau), mask)
    }

    fn name(&self) -> &'static str {
        "soft-threshold"
    }
}

/// Random Rank control: uniform over the admissible grid.
pub struct RandomRankPolicy {
    rng: Pcg32,
}

impl RandomRankPolicy {
    pub fn new(seed: u64) -> Self {
        RandomRankPolicy { rng: Pcg32::seeded(seed) }
    }
}

impl RankPolicy for RandomRankPolicy {
    fn choose(&mut self, _state: &RankState, _spectrum: &[f64], mask: &[bool]) -> usize {
        let open: Vec<usize> =
            mask.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect();
        open[self.rng.range(0, open.len())]
    }

    fn name(&self) -> &'static str {
        "random-rank"
    }
}

/// Expensive greedy oracle as a *policy* (upper-bound diagnostic): probes
/// every admissible action on a forked environment. Only usable where a
/// fork of the environment is available.
pub struct OraclePolicy<'e> {
    pub env: &'e RankEnv,
}

impl RankPolicy for OraclePolicy<'_> {
    fn choose(&mut self, _state: &RankState, _spectrum: &[f64], mask: &[bool]) -> usize {
        let mut best = (0usize, f64::NEG_INFINITY);
        for (a, &ok) in mask.iter().enumerate() {
            if !ok {
                continue;
            }
            let mut trial = self.env.fork();
            let res = trial.step(a);
            if res.reward > best.1 {
                best = (a, res.reward);
            }
        }
        best.0
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

/// Pick the admissible grid index whose rank is closest to `target`.
fn nearest_admissible(grid: &[usize], target: usize, mask: &[bool]) -> usize {
    assert_eq!(grid.len(), mask.len());
    grid.iter()
        .enumerate()
        .filter(|(i, _)| mask[*i])
        .min_by_key(|(_, &r)| r.abs_diff(target))
        .map(|(i, _)| i)
        .expect("at least one admissible action")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_state() -> RankState {
        RankState { features: vec![0.0; 4] }
    }

    #[test]
    fn fixed_picks_target_when_open() {
        let mut p = FixedRankPolicy::new(vec![16, 32, 64], 32);
        let a = p.choose(&dummy_state(), &[], &[true, true, true]);
        assert_eq!(a, 1);
    }

    #[test]
    fn fixed_falls_back_when_masked() {
        let mut p = FixedRankPolicy::new(vec![16, 32, 64], 32);
        let a = p.choose(&dummy_state(), &[], &[true, false, true]);
        assert!(a == 0 || a == 2);
    }

    #[test]
    fn adaptive_svd_rank_tracks_spectrum() {
        let mut p = AdaptiveSvdPolicy::new(vec![4, 8, 16, 32], 0.90);
        // Sharply decaying spectrum → tiny rank.
        let sharp: Vec<f64> = (0..32).map(|i| (0.3f64).powi(i)).collect();
        let a_sharp = p.choose(&dummy_state(), &sharp, &[true; 4]);
        assert_eq!(a_sharp, 0);
        // Flat spectrum → max rank.
        let flat = vec![1.0; 32];
        let a_flat = p.choose(&dummy_state(), &flat, &[true; 4]);
        assert_eq!(a_flat, 3);
    }

    #[test]
    fn adaptive_rounds_up_not_down() {
        let mut p = AdaptiveSvdPolicy::new(vec![4, 8, 16], 0.90);
        // Spectrum needing rank 5 → grid 8 (round up), not 4.
        let mut s = vec![1.0; 5];
        s.extend(vec![1e-6; 11]);
        let a = p.choose(&dummy_state(), &s, &[true; 3]);
        assert_eq!(a, 1);
    }

    #[test]
    fn soft_threshold_rank_tracks_tail_height() {
        let mut p = SoftThresholdPolicy::new(vec![4, 8, 16, 32], 0.5);
        // Sharply decaying spectrum → few σ survive half the top σ.
        let sharp: Vec<f64> = (0..32).map(|i| (0.3f64).powi(i)).collect();
        assert_eq!(p.choose(&dummy_state(), &sharp, &[true; 4]), 0);
        // Flat spectrum → everything survives → max grid rank.
        let flat = vec![1.0; 32];
        assert_eq!(p.choose(&dummy_state(), &flat, &[true; 4]), 3);
    }

    #[test]
    fn soft_threshold_respects_mask() {
        let mut p = SoftThresholdPolicy::new(vec![4, 8, 16, 32], 0.9);
        // Wants a tiny rank, but index 0 is masked → nearest open.
        let sharp: Vec<f64> = (0..32).map(|i| (0.3f64).powi(i)).collect();
        assert_eq!(p.choose(&dummy_state(), &sharp, &[false, true, true, true]), 1);
    }

    #[test]
    fn random_respects_mask() {
        let mut p = RandomRankPolicy::new(1);
        for _ in 0..100 {
            let a = p.choose(&dummy_state(), &[], &[false, true, false, true]);
            assert!(a == 1 || a == 3);
        }
    }

    #[test]
    fn random_covers_open_actions() {
        let mut p = RandomRankPolicy::new(2);
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[p.choose(&dummy_state(), &[], &[true, true, true])] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
