//! Randomized partial SVD (Halko–Martinsson–Tropp) — the `O(n²r)` batched
//! partial decomposition the paper attributes to cuSOLVER, rebuilt for the
//! CPU substrate. Also the batched front-end used by the coordinator for
//! per-head decompositions.

use super::kernel::PackedAt;
use super::mat::Mat;
use super::matmul::{matmul, matmul_at};
use super::qr::orthonormalize;
use super::svd::{svd, Svd};
use crate::util::threadpool::SendPtr;
use crate::util::{global_pool, Pcg32};

/// Which kernel path the probe's range finder uses for its repeated
/// `AᵀQ` products.
///
/// [`ProbeKernel::Fused`] packs A's micro-kernel tiles once and reuses
/// them across every subspace iteration; [`ProbeKernel::Direct`] calls
/// `matmul_at` each iteration, re-streaming (and re-packing) A every
/// time. The packed path mirrors `matmul_at`'s exact depth partition,
/// so the two are **bit-identical** — the conformance layer fuzzes the
/// pairing per seed (`probe_kernel_failures`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeKernel {
    /// Pack A once per probe, reuse across subspace iterations (default).
    Fused,
    /// Re-pack A on every `matmul_at` call (reference pairing path).
    Direct,
}

/// Randomized top-k SVD with oversampling and subspace (power) iterations.
///
/// `k` is clamped to min(m, n). `oversample` extra directions and
/// `n_iter` power iterations sharpen accuracy on slowly decaying spectra;
/// defaults (8, 2) are good for attention matrices whose spectra decay
/// fast after softmax.
pub fn partial_svd(a: &Mat, k: usize, oversample: usize, n_iter: usize, seed: u64) -> Svd {
    partial_svd_with(a, k, oversample, n_iter, seed, ProbeKernel::Fused)
}

/// [`partial_svd`] with an explicit kernel-path selection for the
/// range-finder chain `A·Ω → orth → AᵀQ → orth → A·QZ`.
pub fn partial_svd_with(
    a: &Mat,
    k: usize,
    oversample: usize,
    n_iter: usize,
    seed: u64,
    kernel: ProbeKernel,
) -> Svd {
    let (m, n) = a.shape();
    let k = k.min(m).min(n).max(1);
    let p = (k + oversample).min(n);
    let mut rng = Pcg32::seeded(seed ^ 0x9e3779b97f4a7c15);
    // Range finder: Y = A·Ω, Ω ~ N(0,1)^{n×p}.
    let omega = Mat::randn(n, p, 1.0, &mut rng);
    let mut y = matmul(a, &omega);
    // Fused probe pass: the subspace loop hits Aᵀ·Q once per iteration
    // against the *same* A — pack its tiles once and amortize.
    let packed = match kernel {
        ProbeKernel::Fused if n_iter > 0 => Some(PackedAt::pack(a, p)),
        _ => None,
    };
    // Subspace iterations with re-orthonormalization for stability.
    for _ in 0..n_iter {
        let q = orthonormalize(&y);
        let z = match &packed {
            Some(pk) => pk.matmul_at(&q), // Aᵀ Q : n×p, packed tiles reused
            None => matmul_at(a, &q),
        };
        let qz = orthonormalize(&z);
        y = matmul(a, &qz);
    }
    let q = orthonormalize(&y); // m×p
    // Project: B = Qᵀ A  (p×n) — small; full Jacobi SVD on B.
    let b = matmul_at(&q, a);
    let sb = svd(&b);
    // U = Q·Ub, truncated to k.
    let ub = sb.u.take_cols(k.min(sb.s.len()));
    let u = matmul(&q, &ub);
    Svd { u, s: sb.s[..k.min(sb.s.len())].to_vec(), v: sb.v.take_cols(k.min(sb.s.len())) }
}

/// Convenience wrapper with library defaults.
pub fn top_k_svd(a: &Mat, k: usize, seed: u64) -> Svd {
    partial_svd(a, k, 8, 2, seed)
}

/// Batched partial SVD across independent matrices (one per attention
/// head). Parallelized over the global pool — the CPU analogue of the
/// paper's cuSOLVER batched call.
pub fn batched_partial_svd(mats: &[Mat], k: usize, seed: u64) -> Vec<Svd> {
    let mut out: Vec<Option<Svd>> = (0..mats.len()).map(|_| None).collect();
    let out_ptr = SendPtr::new(&mut out);
    global_pool().scoped_for(mats.len(), |i| {
        // SAFETY: each index writes a distinct slot.
        let slot = unsafe { out_ptr.get() };
        let d = top_k_svd(&mats[i], k, seed.wrapping_add(i as u64));
        slot[i] = Some(d);
    });
    out.into_iter().map(|o| o.expect("svd computed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul_naive;

    /// Low-rank-plus-noise test matrix with controlled spectrum.
    fn spiked_matrix(m: usize, n: usize, rank: usize, noise: f64, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        let mut a = Mat::zeros(m, n);
        for r in 0..rank {
            let u = Mat::randn(m, 1, 1.0, &mut rng);
            let v = Mat::randn(n, 1, 1.0, &mut rng);
            let scale = 10.0 / (r + 1) as f64; // decaying spikes
            a.axpy(scale, &matmul_naive(&u, &v.transpose()));
        }
        a.axpy(noise, &Mat::randn(m, n, 1.0, &mut rng));
        a
    }

    #[test]
    fn recovers_dominant_singular_values() {
        let a = spiked_matrix(60, 40, 5, 0.0, 1);
        let exact = svd(&a);
        let approx = top_k_svd(&a, 5, 7);
        for i in 0..5 {
            let rel = (approx.s[i] - exact.s[i]).abs() / exact.s[i].max(1e-12);
            assert!(rel < 1e-6, "σ_{i}: {} vs {}", approx.s[i], exact.s[i]);
        }
    }

    #[test]
    fn low_rank_reconstruction_error_near_optimal() {
        let a = spiked_matrix(50, 50, 8, 0.05, 2);
        let exact = svd(&a);
        let k = 8;
        let approx = top_k_svd(&a, k, 3);
        let err_opt = exact.tail_energy(k);
        let err_rand = (&a - &approx.reconstruct(k)).fro_norm();
        // Randomized error within 10% of the Eckart–Young optimum.
        assert!(err_rand <= err_opt * 1.10 + 1e-9, "{err_rand} vs {err_opt}");
    }

    #[test]
    fn orthonormal_factors() {
        use crate::linalg::matmul::matmul_at;
        let a = spiked_matrix(40, 30, 4, 0.1, 3);
        let d = top_k_svd(&a, 6, 4);
        let utu = matmul_at(&d.u, &d.u);
        let vtv = matmul_at(&d.v, &d.v);
        assert!(utu.allclose(&Mat::eye(6), 1e-7));
        assert!(vtv.allclose(&Mat::eye(6), 1e-7));
    }

    #[test]
    fn k_clamped_to_min_dim() {
        let a = spiked_matrix(10, 4, 2, 0.0, 4);
        let d = top_k_svd(&a, 16, 5);
        assert_eq!(d.s.len(), 4);
    }

    #[test]
    fn batched_matches_single() {
        let mats: Vec<Mat> = (0..6).map(|i| spiked_matrix(24, 24, 3, 0.01, 10 + i)).collect();
        let batch = batched_partial_svd(&mats, 3, 99);
        for (i, m) in mats.iter().enumerate() {
            let single = top_k_svd(m, 3, 99u64.wrapping_add(i as u64));
            for j in 0..3 {
                assert!((batch[i].s[j] - single.s[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn fused_matches_direct_bitwise() {
        // The packed probe pass mirrors matmul_at's partition exactly,
        // so both kernel paths must agree to the bit (the conformance
        // differential fuzzes this same pairing).
        let a = spiked_matrix(48, 36, 5, 0.05, 8);
        let f = partial_svd_with(&a, 5, 8, 2, 21, ProbeKernel::Fused);
        let d = partial_svd_with(&a, 5, 8, 2, 21, ProbeKernel::Direct);
        assert_eq!(f.s.len(), d.s.len());
        for (x, y) in f.s.iter().zip(&d.s) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in f.u.data().iter().zip(d.u.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in f.v.data().iter().zip(d.v.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = spiked_matrix(30, 30, 4, 0.1, 6);
        let d1 = top_k_svd(&a, 4, 42);
        let d2 = top_k_svd(&a, 4, 42);
        assert_eq!(d1.s, d2.s);
    }
}
