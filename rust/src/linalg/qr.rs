//! Householder QR decomposition (thin form), used by the randomized
//! partial SVD for subspace orthonormalization.
//!
//! Reflector application runs row-major on the blocked `kernel::axpy`
//! primitive (contiguous rows of R/Q, per-element accumulation order
//! rows-ascending — deterministic and autovectorizable), instead of the
//! old strided per-column scalar loops.

use super::kernel::{axpy, norm2};
use super::mat::Mat;

/// Thin QR: A (m×n, m>=n) = Q (m×n, orthonormal cols) · R (n×n upper).
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin expects m >= n, got {m}x{n}");
    // Work on a copy; accumulate Householder vectors in-place (LAPACK style).
    let mut r = a.clone();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut x = vec![0.0; m - k];
        for i in k..m {
            x[i - k] = r[(i, k)];
        }
        let alpha = -x[0].signum() * norm2(&x);
        let mut v = x;
        v[0] -= alpha;
        let vnorm = norm2(&v);
        if vnorm > 1e-300 {
            for t in v.iter_mut() {
                *t /= vnorm;
            }
            // Apply H = I - 2vvᵀ to the trailing submatrix of R:
            // dots = Rᵀv over rows ascending, then one fused update per row.
            let mut dots = vec![0.0; n - k];
            for i in k..m {
                axpy(v[i - k], &r.row(i)[k..n], &mut dots);
            }
            for i in k..m {
                axpy(-2.0 * v[i - k], &dots, &mut r.row_mut(i)[k..n]);
            }
        } else {
            v = vec![0.0; m - k];
        }
        vs.push(v);
    }
    // Extract the upper-triangular R (n×n).
    let mut rr = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr[(i, j)] = r[(i, j)];
        }
    }
    // Form thin Q by applying Householder reflectors to the first n columns
    // of the identity, in reverse order.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.iter().all(|&t| t == 0.0) {
            continue;
        }
        let mut dots = vec![0.0; n];
        for i in k..m {
            axpy(v[i - k], q.row(i), &mut dots);
        }
        for i in k..m {
            axpy(-2.0 * v[i - k], &dots, q.row_mut(i));
        }
    }
    (q, rr)
}

/// Orthonormalize the columns of A (thin Q of its QR).
pub fn orthonormalize(a: &Mat) -> Mat {
    qr_thin(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_at, matmul_naive};
    use crate::util::Pcg32;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg32::seeded(10);
        for &(m, n) in &[(5, 5), (10, 4), (32, 16), (7, 1)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            let qr = matmul_naive(&q, &r);
            assert!(a.allclose(&qr, 1e-9), "reconstruct {m}x{n}");
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Pcg32::seeded(11);
        let a = Mat::randn(20, 8, 1.0, &mut rng);
        let (q, _) = qr_thin(&a);
        let qtq = matmul_at(&q, &q);
        assert!(qtq.allclose(&Mat::eye(8), 1e-9));
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg32::seeded(12);
        let a = Mat::randn(9, 6, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn handles_rank_deficiency() {
        // Two identical columns — Q should still be orthonormal.
        let mut rng = Pcg32::seeded(13);
        let col = Mat::randn(10, 1, 1.0, &mut rng);
        let a = col.hcat(&col).hcat(&Mat::randn(10, 1, 1.0, &mut rng));
        let (q, r) = qr_thin(&a);
        assert!(matmul_naive(&q, &r).allclose(&a, 1e-9));
    }
}
