//! Matrix multiplication entry points: naive (reference oracle), plus
//! the packed-panel register-tiled kernels from [`super::kernel`] behind
//! the same serial/parallel switching the crate has always used.
//!
//! All dense inner loops are branch-free (no zero-skip guards — see the
//! 0·inf/NaN note in the kernel module docs), and every partition is a
//! pure function of the problem shape, so serial, parallel and
//! any-pool-size execution produce bit-identical results per kernel
//! version.

use super::kernel::{self, at_range, gemm_rows_dispatch, pack_b, pack_bt, KC, K_CHUNK, MR, NR};
use super::mat::Mat;
use crate::util::threadpool::SendPtr;
use crate::util::{global_pool, ThreadPool};

/// Reference ikj matmul (used by tests as oracle for the blocked
/// kernels; retains the zero-skip guard, so it is a *finite-data*
/// oracle — the packed kernels propagate 0·inf → NaN, this does not).
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let av = a[(i, p)];
            if av == 0.0 {
                continue;
            }
            let brow = b.row(p);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Pack every KC-depth block of row-major `b` (k×n) up front; entries
/// are `(p0, kc, panels)` in ascending depth order.
fn pack_b_blocks(b: &[f64], k: usize, n: usize) -> Vec<(usize, usize, Vec<f64>)> {
    let n_panels = n.div_ceil(NR);
    let mut blocks = Vec::with_capacity(k.div_ceil(KC).max(1));
    for p0 in (0..k).step_by(KC) {
        let kc = (k - p0).min(KC);
        let mut bp = vec![0.0; n_panels * kc * NR];
        pack_b(b, n, p0, kc, &mut bp, n_panels);
        blocks.push((p0, kc, bp));
    }
    blocks
}

/// Same, but packing the transposed operand of A·Bᵀ (`b` is nb×k).
fn pack_bt_blocks(b: &[f64], k: usize, nb: usize) -> Vec<(usize, usize, Vec<f64>)> {
    let n_panels = nb.div_ceil(NR);
    let mut blocks = Vec::with_capacity(k.div_ceil(KC).max(1));
    for p0 in (0..k).step_by(KC) {
        let kc = (k - p0).min(KC);
        let mut bp = vec![0.0; n_panels * kc * NR];
        pack_bt(b, k, nb, p0, kc, &mut bp, n_panels);
        blocks.push((p0, kc, bp));
    }
    blocks
}

/// Compute rows [r0, r1) of C += A·B against pre-packed B blocks.
fn gemm_packed_rows(
    a: &Mat,
    blocks: &[(usize, usize, Vec<f64>)],
    c: &mut Mat,
    n: usize,
    r0: usize,
    r1: usize,
) {
    let k = a.cols();
    let n_panels = n.div_ceil(NR);
    for (p0, kc, bp) in blocks {
        gemm_rows_dispatch(a.data(), k, c.data_mut(), n, r0, r1, *p0, *kc, bp, n_panels);
    }
}

/// Cache-blocked single-threaded matmul on the packed-panel core.
pub fn matmul_blocked(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    let blocks = pack_b_blocks(b.data(), k, n);
    gemm_packed_rows(a, &blocks, &mut c, n, 0, m);
    c
}

/// Parallel matmul over the global thread pool; falls back to the
/// single-threaded sweep for small problems where spawn overhead
/// dominates.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    matmul_pooled(a, b, global_pool())
}

/// [`matmul`] against an explicit pool. Row partitioning never changes
/// per-element accumulation order, so the result is bit-identical for
/// every pool size (including the serial fallback).
pub fn matmul_pooled(a: &Mat, b: &Mat, pool: &ThreadPool) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims: {:?} x {:?}", a.shape(), b.shape());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if m * k * n < 64 * 64 * 64 {
        return matmul_blocked(a, b);
    }
    // Pack B's depth blocks once; row chunks share them read-only.
    let blocks = pack_b_blocks(b.data(), k, n);
    let mut c = Mat::zeros(m, n);
    let c_ptr = SendPtr::new(&mut c);
    pool.chunked_for(m, 16, |r0, r1| {
        // SAFETY: ranges are disjoint row slices of c; &Mat reads are shared.
        let c = unsafe { c_ptr.get() };
        gemm_packed_rows(a, &blocks, c, n, r0, r1);
    });
    c
}

/// C = A·Bᵀ without materializing Bᵀ: B's columns-of-the-product are
/// packed straight out of its rows into the same panel layout, then the
/// shared register-tiled sweep runs (previously a scalar dot loop with
/// no cache blocking).
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    matmul_bt_pooled(a, b, global_pool())
}

/// [`matmul_bt`] against an explicit pool (bit-identical across pool
/// sizes, same argument as [`matmul_pooled`]).
pub fn matmul_bt_pooled(a: &Mat, b: &Mat, pool: &ThreadPool) -> Mat {
    assert_eq!(a.cols(), b.cols(), "inner dims for A·Bt");
    let (m, nb, k) = (a.rows(), b.rows(), a.cols());
    let blocks = pack_bt_blocks(b.data(), k, nb);
    let mut c = Mat::zeros(m, nb);
    if m * nb * k < 64 * 64 * 64 {
        gemm_packed_rows(a, &blocks, &mut c, nb, 0, m);
        return c;
    }
    let c_ptr = SendPtr::new(&mut c);
    pool.chunked_for(m, 16, |r0, r1| {
        // SAFETY: ranges are disjoint row slices of c; &Mat reads are shared.
        let c = unsafe { c_ptr.get() };
        gemm_packed_rows(a, &blocks, c, nb, r0, r1);
    });
    c
}

/// Accumulate depth rows [k0, k1) of the Aᵀ·B contraction into `c`,
/// packing both operands block-by-block.
#[inline]
fn matmul_at_range(a: &Mat, b: &Mat, c: &mut Mat, k0: usize, k1: usize) {
    let (m, n) = (a.cols(), b.cols());
    let n_panels = n.div_ceil(NR);
    let n_tiles = m.div_ceil(MR);
    let mut bp = vec![0.0; n_panels * KC * NR];
    let mut ap = vec![0.0; n_tiles * KC * MR];
    at_range(a.data(), m, b.data(), n, c.data_mut(), k0, k1, &mut bp, &mut ap);
}

/// C = Aᵀ·B without materializing Aᵀ. The contraction runs over A's rows,
/// so (unlike `matmul`/`matmul_bt`) output rows are not disjoint per input
/// chunk; the parallel path gives each chunk of the k-dimension its own
/// partial C and reduces them at the end. Sits on the low-rank hot path
/// via `lowrank_attention_output`; the probe's repeated products against
/// a fixed A should use [`kernel::PackedAt`] instead.
pub fn matmul_at(a: &Mat, b: &Mat) -> Mat {
    matmul_at_pooled(a, b, global_pool())
}

/// [`matmul_at`] against an explicit pool. The K_CHUNK partition and the
/// ascending-chunk reduce order depend only on the problem shape, so the
/// result is bit-identical for every pool size.
pub fn matmul_at_pooled(a: &Mat, b: &Mat, pool: &ThreadPool) -> Mat {
    assert_eq!(a.rows(), b.rows(), "inner dims for At·B");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    if k * m * n < 64 * 64 * 64 {
        matmul_at_range(a, b, &mut c, 0, k);
        return c;
    }
    // The chunk partition depends only on the problem shape — never on
    // pool size or calling context — so the summation association (and
    // thus the f64 result) is identical on any machine, whether the
    // chunks run in parallel, inline on a pool worker, or on a 1-thread
    // pool. SVD seeds and rank decisions downstream rely on this.
    let n_chunks = k.div_ceil(K_CHUNK);
    let mut partials: Vec<Mat> = (0..n_chunks).map(|_| Mat::zeros(m, n)).collect();
    let ptr = SendPtr::new(&mut partials);
    pool.scoped_for(n_chunks, |ci| {
        // SAFETY: each chunk index writes only its own partial.
        let partial = &mut unsafe { ptr.get() }[ci];
        let k0 = ci * K_CHUNK;
        let k1 = (k0 + K_CHUNK).min(k);
        matmul_at_range(a, b, partial, k0, k1);
    });
    // Reduce in fixed chunk order so results are deterministic regardless
    // of worker scheduling (the engine's bit-equivalence tests rely on it).
    for partial in &partials {
        c.add_inplace(partial);
    }
    c
}

/// y = A·x for a vector x (blocked dot per row).
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| kernel::dot(a.row(i), x)).collect()
}

/// y = Aᵀ·x. Branch-free axpy per row (no zero-skip: 0·inf/NaN inputs
/// now propagate per IEEE-754 instead of being silently dropped).
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        kernel::axpy(xi, a.row(i), &mut y);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::seeded(42);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (100, 37, 81)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c1 = matmul_naive(&a, &b);
            let c2 = matmul_blocked(&a, &b);
            assert!(c1.allclose(&c2, 1e-10), "({m},{k},{n})");
        }
    }

    #[test]
    fn rank_bucket_widths_match_naive() {
        // The monomorphized bucket kernels cover exactly these widths.
        let mut rng = Pcg32::seeded(49);
        for &n in &[8, 16, 24, 32, 48, 64] {
            let a = Mat::randn(37, 300, 1.0, &mut rng);
            let b = Mat::randn(300, n, 1.0, &mut rng);
            assert!(
                matmul_blocked(&a, &b).allclose(&matmul_naive(&a, &b), 1e-9),
                "bucket n={n}"
            );
        }
    }

    #[test]
    fn parallel_matches_naive() {
        let mut rng = Pcg32::seeded(43);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(70, 90, 1.0, &mut rng);
        assert!(matmul(&a, &b).allclose(&matmul_naive(&a, &b), 1e-10));
    }

    #[test]
    fn bt_and_at_variants() {
        let mut rng = Pcg32::seeded(44);
        let a = Mat::randn(20, 15, 1.0, &mut rng);
        let b = Mat::randn(25, 15, 1.0, &mut rng);
        let want = matmul_naive(&a, &b.transpose());
        assert!(matmul_bt(&a, &b).allclose(&want, 1e-10));

        let a2 = Mat::randn(15, 20, 1.0, &mut rng);
        let b2 = Mat::randn(15, 25, 1.0, &mut rng);
        let want2 = matmul_naive(&a2.transpose(), &b2);
        assert!(matmul_at(&a2, &b2).allclose(&want2, 1e-10));
    }

    #[test]
    fn parallel_bt_matches_naive_above_threshold() {
        let mut rng = Pcg32::seeded(50);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(90, 70, 1.0, &mut rng);
        let want = matmul_naive(&a, &b.transpose());
        assert!(matmul_bt(&a, &b).allclose(&want, 1e-9));
    }

    #[test]
    fn parallel_at_matches_naive_above_threshold() {
        // Sizes chosen to cross the 64³ work threshold so the chunked
        // partial-accumulation path runs (and one below it for the serial
        // path), both checked against the naive oracle.
        let mut rng = Pcg32::seeded(47);
        for &(k, m, n) in &[(130, 70, 90), (200, 64, 64), (20, 10, 12)] {
            let a = Mat::randn(k, m, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let want = matmul_naive(&a.transpose(), &b);
            assert!(matmul_at(&a, &b).allclose(&want, 1e-9), "({k},{m},{n})");
        }
    }

    #[test]
    fn parallel_at_is_deterministic() {
        let mut rng = Pcg32::seeded(48);
        let a = Mat::randn(150, 80, 1.0, &mut rng);
        let b = Mat::randn(150, 80, 1.0, &mut rng);
        let c1 = matmul_at(&a, &b);
        for _ in 0..4 {
            assert!(matmul_at(&a, &b).allclose(&c1, 0.0), "run-to-run drift");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(45);
        let a = Mat::randn(12, 12, 1.0, &mut rng);
        assert!(matmul(&a, &Mat::eye(12)).allclose(&a, 1e-12));
        assert!(matmul(&Mat::eye(12), &a).allclose(&a, 1e-12));
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(46);
        let a = Mat::randn(9, 13, 1.0, &mut rng);
        let x: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let xm = Mat::from_vec(13, 1, x.clone());
        let want = matmul_naive(&a, &xm);
        let got = matvec(&a, &x);
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-10);
        }
        let y: Vec<f64> = (0..9).map(|i| 1.0 - i as f64 * 0.1).collect();
        let got_t = matvec_t(&a, &y);
        let want_t = matmul_naive(&a.transpose(), &Mat::from_vec(9, 1, y));
        for j in 0..13 {
            assert!((got_t[j] - want_t[(j, 0)]).abs() < 1e-10);
        }
    }
}
