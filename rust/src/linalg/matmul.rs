//! Matrix multiplication kernels: naive (reference), cache-blocked with
//! transposed-B packing, and a thread-pool-parallel variant used on the
//! serving hot path.

use super::mat::Mat;
use crate::util::global_pool;
use crate::util::threadpool::SendPtr;

/// Reference ikj matmul (used by tests as oracle for the blocked kernels).
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let av = a[(i, p)];
            if av == 0.0 {
                continue;
            }
            let brow = b.row(p);
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

/// Cache-blocked single-threaded matmul.
pub fn matmul_blocked(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    matmul_into_range(a, b, &mut c, 0, m);
    let _ = k;
    c
}

/// Compute rows [r0, r1) of C = A·B into a preallocated C.
#[inline]
fn matmul_into_range(a: &Mat, b: &Mat, c: &mut Mat, r0: usize, r1: usize) {
    const MC: usize = 64; // row block
    const KC: usize = 128; // depth block
    let (k, n) = (a.cols(), b.cols());
    for i0 in (r0..r1).step_by(MC) {
        let i1 = (i0 + MC).min(r1);
        for p0 in (0..k).step_by(KC) {
            let p1 = (p0 + KC).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = c.row_mut(i);
                for p in p0..p1 {
                    let av = arow[p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(p);
                    // Inner loop over contiguous memory in both B and C —
                    // auto-vectorizes.
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        }
    }
}

/// Parallel matmul over the global thread pool; falls back to blocked for
/// small problems where spawn overhead dominates.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "inner dims: {:?} x {:?}", a.shape(), b.shape());
    let (m, n) = (a.rows(), b.cols());
    let work = m * a.cols() * n;
    if work < 64 * 64 * 64 {
        return matmul_blocked(a, b);
    }
    let mut c = Mat::zeros(m, n);
    // Split row ranges across the pool; each range writes disjoint rows.
    let c_ptr = SendPtr::new(&mut c);
    global_pool().chunked_for(m, 16, |r0, r1| {
        // SAFETY: ranges are disjoint row slices of c; &Mat reads are shared.
        let c = unsafe { c_ptr.get() };
        matmul_into_range(a, b, c, r0, r1);
    });
    c
}

/// C = A·Bᵀ without materializing Bᵀ (dot-product form, contiguous rows).
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "inner dims for A·Bt");
    let (m, n, k) = (a.rows(), b.rows(), a.cols());
    let mut c = Mat::zeros(m, n);
    let c_ptr = SendPtr::new(&mut c);
    let body = |r0: usize, r1: usize| {
        let c = unsafe { c_ptr.get() };
        for i in r0..r1 {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for j in 0..n {
                let brow = b.row(j);
                let mut acc = 0.0;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                crow[j] = acc;
            }
        }
    };
    if m * n * k < 64 * 64 * 64 {
        body(0, m);
    } else {
        global_pool().chunked_for(m, 16, body);
    }
    c
}

/// Accumulate rows [k0, k1) of the Aᵀ·B contraction into `c`.
#[inline]
fn matmul_at_range(a: &Mat, b: &Mat, c: &mut Mat, k0: usize, k1: usize) {
    let (m, n) = (a.cols(), b.cols());
    for p in k0..k1 {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// C = Aᵀ·B without materializing Aᵀ. The contraction runs over A's rows,
/// so (unlike `matmul`/`matmul_bt`) output rows are not disjoint per input
/// chunk; the parallel path gives each chunk of the k-dimension its own
/// partial C and reduces them at the end. Sits on the low-rank hot path
/// via `lowrank_attention_output`.
pub fn matmul_at(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "inner dims for At·B");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    if k * m * n < 64 * 64 * 64 {
        matmul_at_range(a, b, &mut c, 0, k);
        return c;
    }
    // The chunk partition depends only on the problem shape — never on
    // pool size or calling context — so the summation association (and
    // thus the f64 result) is identical on any machine, whether the
    // chunks run in parallel, inline on a pool worker, or on a 1-thread
    // pool. SVD seeds and rank decisions downstream rely on this.
    const K_CHUNK: usize = 64;
    let n_chunks = k.div_ceil(K_CHUNK);
    let mut partials: Vec<Mat> = (0..n_chunks).map(|_| Mat::zeros(m, n)).collect();
    let ptr = SendPtr::new(&mut partials);
    global_pool().scoped_for(n_chunks, |ci| {
        // SAFETY: each chunk index writes only its own partial.
        let partial = &mut unsafe { ptr.get() }[ci];
        let k0 = ci * K_CHUNK;
        let k1 = (k0 + K_CHUNK).min(k);
        matmul_at_range(a, b, partial, k0, k1);
    });
    // Reduce in fixed chunk order so results are deterministic regardless
    // of worker scheduling (the engine's bit-equivalence tests rely on it).
    for partial in &partials {
        c.add_inplace(partial);
    }
    c
}

/// y = A·x for a vector x.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows())
        .map(|i| a.row(i).iter().zip(x.iter()).map(|(p, q)| p * q).sum())
        .collect()
}

/// y = Aᵀ·x.
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        for (j, aij) in a.row(i).iter().enumerate() {
            y[j] += aij * xi;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::seeded(42);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (100, 37, 81)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c1 = matmul_naive(&a, &b);
            let c2 = matmul_blocked(&a, &b);
            assert!(c1.allclose(&c2, 1e-10), "({m},{k},{n})");
        }
    }

    #[test]
    fn parallel_matches_naive() {
        let mut rng = Pcg32::seeded(43);
        let a = Mat::randn(130, 70, 1.0, &mut rng);
        let b = Mat::randn(70, 90, 1.0, &mut rng);
        assert!(matmul(&a, &b).allclose(&matmul_naive(&a, &b), 1e-10));
    }

    #[test]
    fn bt_and_at_variants() {
        let mut rng = Pcg32::seeded(44);
        let a = Mat::randn(20, 15, 1.0, &mut rng);
        let b = Mat::randn(25, 15, 1.0, &mut rng);
        let want = matmul_naive(&a, &b.transpose());
        assert!(matmul_bt(&a, &b).allclose(&want, 1e-10));

        let a2 = Mat::randn(15, 20, 1.0, &mut rng);
        let b2 = Mat::randn(15, 25, 1.0, &mut rng);
        let want2 = matmul_naive(&a2.transpose(), &b2);
        assert!(matmul_at(&a2, &b2).allclose(&want2, 1e-10));
    }

    #[test]
    fn parallel_at_matches_naive_above_threshold() {
        // Sizes chosen to cross the 64³ work threshold so the chunked
        // partial-accumulation path runs (and one below it for the serial
        // path), both checked against the naive oracle.
        let mut rng = Pcg32::seeded(47);
        for &(k, m, n) in &[(130, 70, 90), (200, 64, 64), (20, 10, 12)] {
            let a = Mat::randn(k, m, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let want = matmul_naive(&a.transpose(), &b);
            assert!(matmul_at(&a, &b).allclose(&want, 1e-9), "({k},{m},{n})");
        }
    }

    #[test]
    fn parallel_at_is_deterministic() {
        let mut rng = Pcg32::seeded(48);
        let a = Mat::randn(150, 80, 1.0, &mut rng);
        let b = Mat::randn(150, 80, 1.0, &mut rng);
        let c1 = matmul_at(&a, &b);
        for _ in 0..4 {
            assert!(matmul_at(&a, &b).allclose(&c1, 0.0), "run-to-run drift");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(45);
        let a = Mat::randn(12, 12, 1.0, &mut rng);
        assert!(matmul(&a, &Mat::eye(12)).allclose(&a, 1e-12));
        assert!(matmul(&Mat::eye(12), &a).allclose(&a, 1e-12));
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(46);
        let a = Mat::randn(9, 13, 1.0, &mut rng);
        let x: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let xm = Mat::from_vec(13, 1, x.clone());
        let want = matmul_naive(&a, &xm);
        let got = matvec(&a, &x);
        for i in 0..9 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-10);
        }
        let y: Vec<f64> = (0..9).map(|i| 1.0 - i as f64 * 0.1).collect();
        let got_t = matvec_t(&a, &y);
        let want_t = matmul_naive(&a.transpose(), &Mat::from_vec(9, 1, y));
        for j in 0..13 {
            assert!((got_t[j] - want_t[(j, 0)]).abs() < 1e-10);
        }
    }
}
