//! Register-tiled, panel-packed GEMM core shared by every matmul
//! variant, plus the blocked dot/axpy primitives the QR and power-iter
//! paths sit on.
//!
//! # Architecture
//!
//! The design is the classic BLIS decomposition scaled to this crate's
//! problem sizes (n ≤ a few hundred, the low-rank hot path n ∈
//! {8..64}):
//!
//! * the depth dimension is blocked at [`KC`] = 256;
//! * per depth block, the right-hand operand is packed once into
//!   contiguous kc×[`NR`] column panels (panel-major: panel `jp`, then
//!   depth row `p`, then `NR` = 8 contiguous doubles, zero-padded past
//!   the matrix edge);
//! * an [`MR`]×[`NR`] = 4×8 register-accumulator micro-kernel walks the
//!   packed panel with a branch-free inner loop — four broadcast
//!   multiply-adds per packed row into `[f64; 8]` accumulators that the
//!   compiler keeps in vector registers (AVX-512: one zmm per row) —
//!   and only bounds the *writeback* by the row/column remainders;
//! * for the rank-bucket widths (n ∈ {8, 16, 24, 32, 48, 64}, i.e.
//!   n = NP·NR, NP ≤ 8) the panel-count loop is monomorphized via
//!   `gemm_rows_bucket::<NP>`, so the low-rank apply and the probe's
//!   skinny products run a kernel whose N extent is compile-known.
//!
//! Row remainders clamp the extra A-row pointers back to the tile's
//! first row (they read valid memory; their accumulator rows are
//! discarded by the `mr`-bounded writeback). Column remainders are
//! zero-padded in the panel and clipped by the `jn`-bounded writeback.
//!
//! # Determinism contract
//!
//! Every partition here — KC blocks, MR tiles, NR panels, the
//! [`K_CHUNK`] reduction chunks of the Aᵀ·B path — is a pure function
//! of the problem shape, never of pool size or calling context. For a
//! fixed output element the accumulation order is: depth blocks
//! ascending, `p` ascending within each block (tile and panel
//! membership do not reorder per-element sums), partial-C chunks
//! reduced in ascending chunk order. Consequently parallel and serial
//! execution, any pool size, and the packed vs. unpacked probe paths
//! are bit-identical by construction — the property the conformance
//! layer's `f64::to_bits` pairings assert. Absolute values may differ
//! from other kernel versions (and from `matmul_naive`): bit-identity
//! is pairwise-per-build, not a cross-version golden.
//!
//! # 0·inf / NaN semantics
//!
//! The old scalar kernels skipped zero multiplicands
//! (`if av == 0.0 { continue; }`), which silently dropped `0 × ±inf`
//! and `0 × NaN` products. The packed core is branch-free: those
//! products now propagate NaN per IEEE-754, matching `matmul_naive`'s
//! documented role as a *finite-data* oracle.

use super::mat::Mat;
use crate::util::global_pool;
use crate::util::threadpool::SendPtr;

/// Micro-kernel row extent (A rows per tile).
pub const MR: usize = 4;
/// Micro-kernel column extent (packed-panel width, f64 lanes).
pub const NR: usize = 8;
/// Depth blocking: the packed panel covers at most KC rows of B.
pub const KC: usize = 256;
/// Depth partition of the parallel Aᵀ·B reduction (see `matmul_at`).
pub const K_CHUNK: usize = 64;

// ---------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------

/// Pack rows [p0, p0+kc) of row-major `b` (row stride `n`) into
/// panel-major kc×NR panels, zero-padding the last panel past column n.
pub(super) fn pack_b(b: &[f64], n: usize, p0: usize, kc: usize, bp: &mut [f64], n_panels: usize) {
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let jn = (n - j0).min(NR);
        let panel = &mut bp[jp * kc * NR..(jp + 1) * kc * NR];
        for p in 0..kc {
            let brow = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jn];
            let dst = &mut panel[p * NR..(p + 1) * NR];
            dst[..jn].copy_from_slice(brow);
            dst[jn..].fill(0.0);
        }
    }
}

/// Pack columns [p0, p0+kc) of row-major `b` (nb×k: the transposed
/// operand of A·Bᵀ) into the same panel layout `pack_b` would produce
/// for Bᵀ.
pub(super) fn pack_bt(
    b: &[f64],
    k: usize,
    nb: usize,
    p0: usize,
    kc: usize,
    bp: &mut [f64],
    n_panels: usize,
) {
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let jn = (nb - j0).min(NR);
        let panel = &mut bp[jp * kc * NR..(jp + 1) * kc * NR];
        for p in 0..kc {
            let dst = &mut panel[p * NR..(p + 1) * NR];
            for (x, d) in dst[..jn].iter_mut().enumerate() {
                *d = b[(j0 + x) * k + (p0 + p)];
            }
            dst[jn..].fill(0.0);
        }
    }
}

/// Pack rows [p0, p0+kc) of row-major `a` (k×m: the transposed left
/// operand of Aᵀ·B) into tile-major kc×MR tiles, zero-padding the last
/// tile past column m.
pub(super) fn pack_at(
    a: &[f64],
    m: usize,
    p0: usize,
    kc: usize,
    ap: &mut [f64],
    n_tiles: usize,
) {
    for t in 0..n_tiles {
        let i0 = t * MR;
        let mr = (m - i0).min(MR);
        let tile = &mut ap[t * kc * MR..(t + 1) * kc * MR];
        for p in 0..kc {
            let arow = &a[(p0 + p) * m + i0..(p0 + p) * m + i0 + mr];
            let dst = &mut tile[p * MR..(p + 1) * MR];
            dst[..mr].copy_from_slice(arow);
            dst[mr..].fill(0.0);
        }
    }
}

// ---------------------------------------------------------------------
// Micro-kernels
// ---------------------------------------------------------------------

/// 4×8 micro-kernel, A rows streamed unpacked (each `aN` starts at its
/// row's depth offset; remainder rows are clamped duplicates whose
/// accumulators the writeback discards).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn kern(
    a0: &[f64],
    a1: &[f64],
    a2: &[f64],
    a3: &[f64],
    panel: &[f64],
    kc: usize,
    c: &mut [f64],
    cs: usize,
    c0: usize,
    mr: usize,
    jn: usize,
) {
    let mut acc0 = [0.0f64; NR];
    let mut acc1 = [0.0f64; NR];
    let mut acc2 = [0.0f64; NR];
    let mut acc3 = [0.0f64; NR];
    let (a0, a1, a2, a3) = (&a0[..kc], &a1[..kc], &a2[..kc], &a3[..kc]);
    let panel = &panel[..kc * NR];
    for p in 0..kc {
        let bv = &panel[p * NR..p * NR + NR];
        let (s0, s1, s2, s3) = (a0[p], a1[p], a2[p], a3[p]);
        for x in 0..NR {
            let b = bv[x];
            acc0[x] += s0 * b;
            acc1[x] += s1 * b;
            acc2[x] += s2 * b;
            acc3[x] += s3 * b;
        }
    }
    writeback(c, cs, c0, mr, jn, &acc0, &acc1, &acc2, &acc3);
}

/// 4×8 micro-kernel over a packed A tile (kc×MR, from [`pack_at`]).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn kern_packed(
    tile: &[f64],
    panel: &[f64],
    kc: usize,
    c: &mut [f64],
    cs: usize,
    c0: usize,
    mr: usize,
    jn: usize,
) {
    let mut acc0 = [0.0f64; NR];
    let mut acc1 = [0.0f64; NR];
    let mut acc2 = [0.0f64; NR];
    let mut acc3 = [0.0f64; NR];
    let tile = &tile[..kc * MR];
    let panel = &panel[..kc * NR];
    for p in 0..kc {
        let bv = &panel[p * NR..p * NR + NR];
        let av = &tile[p * MR..p * MR + MR];
        let (s0, s1, s2, s3) = (av[0], av[1], av[2], av[3]);
        for x in 0..NR {
            let b = bv[x];
            acc0[x] += s0 * b;
            acc1[x] += s1 * b;
            acc2[x] += s2 * b;
            acc3[x] += s3 * b;
        }
    }
    writeback(c, cs, c0, mr, jn, &acc0, &acc1, &acc2, &acc3);
}

/// `mr`/`jn`-bounded accumulator writeback shared by both kernels.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn writeback(
    c: &mut [f64],
    cs: usize,
    c0: usize,
    mr: usize,
    jn: usize,
    acc0: &[f64; NR],
    acc1: &[f64; NR],
    acc2: &[f64; NR],
    acc3: &[f64; NR],
) {
    let crow = &mut c[c0..c0 + jn];
    for x in 0..jn {
        crow[x] += acc0[x];
    }
    if mr > 1 {
        let crow = &mut c[c0 + cs..c0 + cs + jn];
        for x in 0..jn {
            crow[x] += acc1[x];
        }
    }
    if mr > 2 {
        let crow = &mut c[c0 + 2 * cs..c0 + 2 * cs + jn];
        for x in 0..jn {
            crow[x] += acc2[x];
        }
    }
    if mr > 3 {
        let crow = &mut c[c0 + 3 * cs..c0 + 3 * cs + jn];
        for x in 0..jn {
            crow[x] += acc3[x];
        }
    }
}

// ---------------------------------------------------------------------
// Row-sweep drivers
// ---------------------------------------------------------------------

/// General row sweep: accumulate rows [r0, r1) of C += A[·, p0..p0+kc] ·
/// B_packed for a runtime panel count (jn-bounded final panel).
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a: &[f64],
    astride: usize,
    c: &mut [f64],
    n: usize,
    r0: usize,
    r1: usize,
    p0: usize,
    kc: usize,
    bp: &[f64],
    n_panels: usize,
) {
    for i0 in (r0..r1).step_by(MR) {
        let mr = (r1 - i0).min(MR);
        let a0 = &a[i0 * astride + p0..];
        let a1 = &a[(i0 + usize::from(mr > 1)) * astride + p0..];
        let a2 = &a[(i0 + if mr > 2 { 2 } else { 0 }) * astride + p0..];
        let a3 = &a[(i0 + if mr > 3 { 3 } else { 0 }) * astride + p0..];
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let jn = (n - j0).min(NR);
            kern(
                a0,
                a1,
                a2,
                a3,
                &bp[jp * kc * NR..(jp + 1) * kc * NR],
                kc,
                c,
                n,
                i0 * n + j0,
                mr,
                jn,
            );
        }
    }
}

/// Rank-bucket row sweep: n = NP·NR exactly, panel count compile-known,
/// every panel full-width. Bit-identical to [`gemm_rows`] (same
/// accumulation order per element) — only the control flow is
/// monomorphized.
#[allow(clippy::too_many_arguments)]
fn gemm_rows_bucket<const NP: usize>(
    a: &[f64],
    astride: usize,
    c: &mut [f64],
    r0: usize,
    r1: usize,
    p0: usize,
    kc: usize,
    bp: &[f64],
) {
    let n = NP * NR;
    for i0 in (r0..r1).step_by(MR) {
        let mr = (r1 - i0).min(MR);
        let a0 = &a[i0 * astride + p0..];
        let a1 = &a[(i0 + usize::from(mr > 1)) * astride + p0..];
        let a2 = &a[(i0 + if mr > 2 { 2 } else { 0 }) * astride + p0..];
        let a3 = &a[(i0 + if mr > 3 { 3 } else { 0 }) * astride + p0..];
        for jp in 0..NP {
            kern(
                a0,
                a1,
                a2,
                a3,
                &bp[jp * kc * NR..(jp + 1) * kc * NR],
                kc,
                c,
                n,
                i0 * n + jp * NR,
                mr,
                NR,
            );
        }
    }
}

/// Row-sweep dispatch: route the `KernelShape::rank_bucket` widths
/// (n = NP·NR ≤ 64) to their monomorphized variant, everything else to
/// the general sweep.
#[allow(clippy::too_many_arguments)]
pub(super) fn gemm_rows_dispatch(
    a: &[f64],
    astride: usize,
    c: &mut [f64],
    n: usize,
    r0: usize,
    r1: usize,
    p0: usize,
    kc: usize,
    bp: &[f64],
    n_panels: usize,
) {
    if n != 0 && n % NR == 0 && n <= 8 * NR {
        match n / NR {
            1 => gemm_rows_bucket::<1>(a, astride, c, r0, r1, p0, kc, bp),
            2 => gemm_rows_bucket::<2>(a, astride, c, r0, r1, p0, kc, bp),
            3 => gemm_rows_bucket::<3>(a, astride, c, r0, r1, p0, kc, bp),
            4 => gemm_rows_bucket::<4>(a, astride, c, r0, r1, p0, kc, bp),
            5 => gemm_rows_bucket::<5>(a, astride, c, r0, r1, p0, kc, bp),
            6 => gemm_rows_bucket::<6>(a, astride, c, r0, r1, p0, kc, bp),
            7 => gemm_rows_bucket::<7>(a, astride, c, r0, r1, p0, kc, bp),
            8 => gemm_rows_bucket::<8>(a, astride, c, r0, r1, p0, kc, bp),
            _ => unreachable!("n ≤ 8·NR"),
        }
        return;
    }
    gemm_rows(a, astride, c, n, r0, r1, p0, kc, bp, n_panels);
}

/// Accumulate C += Aᵀ[·, ·]·B over depth rows [k0, k1) using packed A
/// tiles and B panels (the shared core of `matmul_at` and
/// [`PackedAt::matmul_at`]). `bp`/`ap` are scratch of at least
/// `n_panels·KC·NR` / `n_tiles·KC·MR`.
#[allow(clippy::too_many_arguments)]
pub(super) fn at_range(
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
    k0: usize,
    k1: usize,
    bp: &mut [f64],
    ap: &mut [f64],
) {
    let n_panels = n.div_ceil(NR);
    let n_tiles = m.div_ceil(MR);
    let mut p0 = k0;
    while p0 < k1 {
        let kc = (k1 - p0).min(KC);
        pack_b(b, n, p0, kc, bp, n_panels);
        pack_at(a, m, p0, kc, ap, n_tiles);
        at_block(&ap[..n_tiles * kc * MR], &bp[..n_panels * kc * NR], m, n, kc, c);
        p0 += kc;
    }
}

/// One packed depth block of the Aᵀ·B sweep: every tile × every panel.
fn at_block(ap: &[f64], bp: &[f64], m: usize, n: usize, kc: usize, c: &mut [f64]) {
    let n_panels = n.div_ceil(NR);
    let n_tiles = m.div_ceil(MR);
    for t in 0..n_tiles {
        let i0 = t * MR;
        let mr = (m - i0).min(MR);
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let jn = (n - j0).min(NR);
            kern_packed(
                &ap[t * kc * MR..(t + 1) * kc * MR],
                &bp[jp * kc * NR..(jp + 1) * kc * NR],
                kc,
                c,
                n,
                i0 * n + j0,
                mr,
                jn,
            );
        }
    }
}

// ---------------------------------------------------------------------
// Reusable packed Aᵀ operand (the fused probe pass)
// ---------------------------------------------------------------------

/// A pre-packed left operand for repeated `Aᵀ·B` products against the
/// same A — the randomized range finder's subspace iterations hit
/// `matmul_at(a, q)` once per iteration, and packing A's tiles once
/// amortizes the dominant re-streaming cost across them.
///
/// The tile partition mirrors `matmul_at`'s exact depth partition for
/// the shape `(k, m, n_hint)` (serial KC blocks below the 64³ work
/// threshold, [`K_CHUNK`] reduction chunks above it), so
/// [`PackedAt::matmul_at`] is **bit-identical** to
/// `matmul::matmul_at(a, b)` by construction — the conformance layer
/// fuzzes that pairing per seed.
pub struct PackedAt {
    k: usize,
    m: usize,
    n_hint: usize,
    serial: bool,
    /// Packed tile data per depth block, ascending `p0`; on the chunked
    /// path blocks correspond 1:1 with the K_CHUNK partition.
    blocks: Vec<AtBlock>,
}

struct AtBlock {
    p0: usize,
    kc: usize,
    tiles: Vec<f64>,
}

impl PackedAt {
    /// Pack `a` (k×m) for repeated Aᵀ·B products whose right-hand side
    /// has `n_hint` columns (the partition — and therefore the summation
    /// association — depends on the full problem shape).
    pub fn pack(a: &Mat, n_hint: usize) -> PackedAt {
        let (k, m) = a.shape();
        let n_tiles = m.div_ceil(MR);
        let serial = k * m * n_hint < 64 * 64 * 64;
        let step = if serial { KC } else { K_CHUNK };
        let mut blocks = Vec::new();
        let mut p0 = 0;
        while p0 < k {
            let kc = (k - p0).min(step);
            let mut tiles = vec![0.0; n_tiles * kc * MR];
            pack_at(a.data(), m, p0, kc, &mut tiles, n_tiles);
            blocks.push(AtBlock { p0, kc, tiles });
            p0 += kc;
        }
        PackedAt { k, m, n_hint, serial, blocks }
    }

    /// C = Aᵀ·B against the packed operand. Requires the shape the pack
    /// was built for (`b.cols() == n_hint`); bit-identical to
    /// `matmul::matmul_at` on the unpacked A.
    pub fn matmul_at(&self, b: &Mat) -> Mat {
        assert_eq!(self.k, b.rows(), "inner dims for packed At·B");
        assert_eq!(self.n_hint, b.cols(), "PackedAt was packed for n = {}", self.n_hint);
        let (m, n) = (self.m, b.cols());
        let n_panels = n.div_ceil(NR);
        let mut c = Mat::zeros(m, n);
        if self.serial {
            let mut bp = vec![0.0; n_panels * KC * NR];
            for blk in &self.blocks {
                pack_b(b.data(), n, blk.p0, blk.kc, &mut bp, n_panels);
                at_block(&blk.tiles, &bp[..n_panels * blk.kc * NR], m, n, blk.kc, c.data_mut());
            }
            return c;
        }
        // Chunked reduction: same partition, partial order and reduce
        // order as `matmul_at` (see the determinism contract above).
        let n_chunks = self.blocks.len();
        let mut partials: Vec<Mat> = (0..n_chunks).map(|_| Mat::zeros(m, n)).collect();
        let ptr = SendPtr::new(&mut partials);
        global_pool().scoped_for(n_chunks, |ci| {
            // SAFETY: each chunk index writes only its own partial.
            let partial = &mut unsafe { ptr.get() }[ci];
            let blk = &self.blocks[ci];
            let mut bp = vec![0.0; n_panels * blk.kc * NR];
            pack_b(b.data(), n, blk.p0, blk.kc, &mut bp, n_panels);
            at_block(&blk.tiles, &bp, m, n, blk.kc, partial.data_mut());
        });
        for partial in &partials {
            c.add_inplace(partial);
        }
        c
    }
}

// ---------------------------------------------------------------------
// Blocked vector primitives
// ---------------------------------------------------------------------

/// Blocked dot product: eight independent lanes reduced by a fixed tree,
/// then the scalar tail. The reduction order is a pure function of the
/// length, so results are deterministic across call sites and builds of
/// the same kernel version.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    let split = x.len() - x.len() % NR;
    let (xm, xt) = x.split_at(split);
    let (ym, yt) = y.split_at(split);
    let mut acc = [0.0f64; NR];
    for (xc, yc) in xm.chunks_exact(NR).zip(ym.chunks_exact(NR)) {
        for l in 0..NR {
            acc[l] += xc[l] * yc[l];
        }
    }
    let mut tail = 0.0;
    for (a, b) in xt.iter().zip(yt) {
        tail += a * b;
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// y += alpha·x, branch-free (no zero-skip: see the 0·inf note above).
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// ‖x‖₂ on the blocked dot.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_at, matmul_naive};
    use crate::util::Pcg32;

    #[test]
    fn dot_matches_naive_and_is_deterministic() {
        let mut rng = Pcg32::seeded(90);
        for len in [0, 1, 7, 8, 9, 16, 63, 100] {
            let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            let d = dot(&x, &y);
            assert!((d - naive).abs() <= 1e-12 * (1.0 + naive.abs()), "len {len}");
            assert_eq!(d.to_bits(), dot(&x, &y).to_bits(), "len {len} rerun");
        }
    }

    #[test]
    fn axpy_accumulates_without_zero_skip() {
        let x = vec![1.0, f64::INFINITY, 2.0];
        let mut y = vec![0.0; 3];
        axpy(0.0, &x, &mut y);
        // 0·inf = NaN must propagate (the old guarded loops dropped it).
        assert!(y[1].is_nan());
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn packed_at_is_bit_identical_to_matmul_at() {
        let mut rng = Pcg32::seeded(91);
        // One shape under the serial threshold, one over it (chunked).
        for &(k, m, n) in &[(40, 24, 12), (150, 80, 40)] {
            let a = Mat::randn(k, m, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let direct = matmul_at(&a, &b);
            let packed = PackedAt::pack(&a, n).matmul_at(&b);
            assert_eq!(direct.shape(), packed.shape());
            for (x, y) in direct.data().iter().zip(packed.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "({k},{m},{n})");
            }
        }
    }

    #[test]
    fn packed_at_reuse_matches_oracle() {
        let mut rng = Pcg32::seeded(92);
        let a = Mat::randn(33, 17, 1.0, &mut rng);
        let packed = PackedAt::pack(&a, 5);
        for trial in 0..3 {
            let b = Mat::randn(33, 5, 1.0, &mut rng);
            let want = matmul_naive(&a.transpose(), &b);
            assert!(packed.matmul_at(&b).allclose(&want, 1e-10), "trial {trial}");
        }
    }

    #[test]
    fn norm2_matches_reference() {
        let x = vec![3.0, 4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
    }
}
