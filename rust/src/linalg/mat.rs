//! Dense row-major matrix type used across the Rust layer.
//!
//! Numerics run in f64 (SVD / perturbation bounds need the headroom);
//! conversion to the f32 XLA literals happens at the runtime boundary.

use crate::util::Pcg32;
use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Dense row-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// i.i.d. N(0, std) entries.
    pub fn randn(rows: usize, cols: usize, std: f64, rng: &mut Pcg32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal() * std;
        }
        m
    }

    /// Uniform [lo, hi) entries.
    pub fn rand_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Pcg32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.uniform(lo, hi);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Select the first `k` columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Select the first `k` rows.
    pub fn take_rows(&self, k: usize) -> Mat {
        assert!(k <= self.rows);
        Mat::from_vec(k, self.cols, self.data[..k * self.cols].to_vec())
    }

    /// Horizontally concatenate `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertically concatenate `[self; other]`.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v *= s;
        }
        out
    }

    pub fn scale_inplace(&mut self, s: f64) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    pub fn add_inplace(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    pub fn sub_inplace(&mut self, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
    }

    /// axpy: self += alpha * other.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// Elementwise map.
    pub fn map<F: Fn(f64) -> f64>(&self, f: F) -> Mat {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v = f(*v);
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    pub fn variance(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let m = self.mean();
        self.data.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / self.data.len() as f64
    }

    pub fn abs_max(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Max |a-b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Check closeness with absolute tolerance.
    pub fn allclose(&self, other: &Mat, tol: f64) -> bool {
        self.shape() == other.shape() && self.max_abs_diff(other) <= tol
    }

    /// Flattened cosine similarity between two matrices (Eq. 8 `sim`).
    pub fn cosine_sim(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        let dot: f64 = self.data.iter().zip(other.data.iter()).map(|(a, b)| a * b).sum();
        let na = self.fro_norm();
        let nb = other.fro_norm();
        if na == 0.0 || nb == 0.0 {
            return 0.0;
        }
        dot / (na * nb)
    }

    /// Convert to f32 (runtime boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, rhs: &Mat) -> Mat {
        let mut out = self.clone();
        out.add_inplace(rhs);
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, rhs: &Mat) -> Mat {
        let mut out = self.clone();
        out.sub_inplace(rhs);
        out
    }
}

impl Mul for &Mat {
    type Output = Mat;
    fn mul(self, rhs: &Mat) -> Mat {
        super::matmul::matmul(self, rhs)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Mat::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let m = Mat::randn(17, 23, 1.0, &mut rng);
        let t = m.transpose();
        assert_eq!(t.shape(), (23, 17));
        assert!(m.allclose(&t.transpose(), 0.0));
        assert_eq!(m[(3, 7)], t[(7, 3)]);
    }

    #[test]
    fn concat() {
        let a = Mat::filled(2, 2, 1.0);
        let b = Mat::filled(2, 3, 2.0);
        let h = a.hcat(&b);
        assert_eq!(h.shape(), (2, 5));
        assert_eq!(h[(0, 4)], 2.0);
        let c = Mat::filled(3, 2, 3.0);
        let v = a.vcat(&c);
        assert_eq!(v.shape(), (5, 2));
        assert_eq!(v[(4, 1)], 3.0);
    }

    #[test]
    fn norms_and_stats() {
        let m = Mat::from_vec(2, 2, vec![3.0, 0.0, 4.0, 0.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert!((m.mean() - 1.75).abs() < 1e-12);
        let eye = Mat::eye(3);
        assert!((eye.cosine_sim(&eye) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_of_orthogonal_is_zero() {
        let a = Mat::from_vec(1, 2, vec![1.0, 0.0]);
        let b = Mat::from_vec(1, 2, vec![0.0, 1.0]);
        assert!(a.cosine_sim(&b).abs() < 1e-12);
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Pcg32::seeded(2);
        let m = Mat::randn(5, 7, 1.0, &mut rng);
        let back = Mat::from_f32(5, 7, &m.to_f32());
        assert!(m.allclose(&back, 1e-6));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 2);
        let b = Mat::zeros(3, 3);
        let _ = &a + &b;
    }

    #[test]
    fn take_cols_rows() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let c = m.take_cols(2);
        assert_eq!(c.data(), &[1., 2., 4., 5.]);
        let r = m.take_rows(1);
        assert_eq!(r.data(), &[1., 2., 3.]);
    }
}
