//! Full SVD via one-sided Jacobi rotations.
//!
//! One-sided Jacobi is simple, numerically robust and accurate to machine
//! precision — exactly what the perturbation-bound tests need as ground
//! truth. Cost is O(mn²) per sweep; for the partial / batched cases on the
//! hot path use `partial_svd` instead.

use super::mat::Mat;

/// Result of an SVD: A = U · diag(s) · Vᵀ with singular values descending.
#[derive(Debug, Clone)]
pub struct Svd {
    /// m×k with orthonormal columns (k = min(m, n)).
    pub u: Mat,
    /// Singular values, descending, length k.
    pub s: Vec<f64>,
    /// n×k with orthonormal columns.
    pub v: Mat,
}

impl Svd {
    /// Reconstruct the rank-r truncation  Σ_{i<r} σ_i u_i v_iᵀ (Eq. 2).
    pub fn reconstruct(&self, r: usize) -> Mat {
        let r = r.min(self.s.len());
        let (m, n) = (self.u.rows(), self.v.rows());
        let mut out = Mat::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..m {
                let uik = self.u[(i, k)] * sk;
                if uik == 0.0 {
                    continue;
                }
                let row = out.row_mut(i);
                for j in 0..n {
                    row[j] += uik * self.v[(j, k)];
                }
            }
        }
        out
    }

    /// Tail energy  sqrt(Σ_{i>=r} σ_i²)  — the Eckart–Young error (Eq. 3).
    pub fn tail_energy(&self, r: usize) -> f64 {
        self.s.iter().skip(r).map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Energy in the band (r, r'] — the incremental perturbation (Eq. 4).
    pub fn band_energy(&self, r: usize, r2: usize) -> f64 {
        assert!(r <= r2);
        self.s[r.min(self.s.len())..r2.min(self.s.len())]
            .iter()
            .map(|x| x * x)
            .sum::<f64>()
            .sqrt()
    }
}

/// Full SVD of an arbitrary matrix. Handles wide matrices by transposing.
pub fn svd(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    if m >= n {
        svd_tall(a)
    } else {
        let t = svd_tall(&a.transpose());
        Svd { u: t.v, s: t.s, v: t.u }
    }
}

/// One-sided Jacobi on a tall (m≥n) matrix.
///
/// §Perf iteration 3: the working arrays are stored *transposed* (each
/// original column is a contiguous row), so every Jacobi rotation is two
/// contiguous-row AXPYs instead of strided column walks — ~3× faster at
/// the serving-probe sizes (n=128).
fn svd_tall(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    debug_assert!(m >= n);
    // wt row j = column j of A; vt row j = column j of V.
    let mut wt = a.transpose();
    let mut vt = Mat::eye(n);
    let eps = 1e-10;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for the (p, q) column pair (contiguous rows).
                let (app, aqq, apq) = {
                    let rp = wt.row(p);
                    let rq = wt.row(q);
                    let mut app = 0.0;
                    let mut aqq = 0.0;
                    let mut apq = 0.0;
                    for i in 0..m {
                        let wp = rp[i];
                        let wq = rq[i];
                        app += wp * wp;
                        aqq += wq * wq;
                        apq += wp * wq;
                    }
                    (app, aqq, apq)
                };
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation zeroing the off-diagonal Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate_rows(&mut wt, p, q, c, s);
                rotate_rows(&mut vt, p, q, c, s);
            }
        }
        if off < 1e-9 {
            break;
        }
    }
    // Row norms of wt → singular values; normalized rows → U columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> =
        (0..n).map(|j| wt.row(j).iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s = vec![0.0; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let nrm = norms[old_j];
        s[new_j] = nrm;
        if nrm > 1e-300 {
            let row = wt.row(old_j);
            for i in 0..m {
                u[(i, new_j)] = row[i] / nrm;
            }
        }
        let vrow = vt.row(old_j);
        for i in 0..n {
            vv[(i, new_j)] = vrow[i];
        }
    }
    Svd { u, s, v: vv }
}

/// Apply a Givens rotation to rows p and q of `m` in place.
#[inline]
fn rotate_rows(m: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    debug_assert!(p < q);
    let cols = m.cols();
    let data = m.data_mut();
    let (head, tail) = data.split_at_mut(q * cols);
    let rp = &mut head[p * cols..p * cols + cols];
    let rq = &mut tail[..cols];
    for i in 0..cols {
        let wp = rp[i];
        let wq = rq[i];
        rp[i] = c * wp - s * wq;
        rq[i] = s * wp + c * wq;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul_at, matmul_naive};
    use crate::util::Pcg32;

    fn check_svd(a: &Mat, tol: f64) {
        let d = svd(a);
        // Reconstruction at full rank.
        let full = d.reconstruct(d.s.len());
        assert!(a.allclose(&full, tol), "reconstruction failed: {:?}", a.shape());
        // Orthonormality.
        let k = d.s.len();
        assert!(matmul_at(&d.u, &d.u).allclose(&Mat::eye(k), 1e-8));
        assert!(matmul_at(&d.v, &d.v).allclose(&Mat::eye(k), 1e-8));
        // Descending σ.
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn svd_various_shapes() {
        let mut rng = Pcg32::seeded(20);
        for &(m, n) in &[(1, 1), (4, 4), (10, 6), (6, 10), (33, 17)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            check_svd(&a, 1e-8);
        }
    }

    #[test]
    fn eckart_young_error_matches_tail() {
        let mut rng = Pcg32::seeded(21);
        let a = Mat::randn(20, 20, 1.0, &mut rng);
        let d = svd(&a);
        for r in [1, 5, 10, 15] {
            let ar = d.reconstruct(r);
            let err = (&a - &ar).fro_norm();
            let tail = d.tail_energy(r);
            assert!((err - tail).abs() < 1e-8, "r={r}: {err} vs {tail}");
        }
    }

    #[test]
    fn rank_one_matrix() {
        let mut rng = Pcg32::seeded(22);
        let u = Mat::randn(8, 1, 1.0, &mut rng);
        let v = Mat::randn(6, 1, 1.0, &mut rng);
        let a = matmul_naive(&u, &v.transpose());
        let d = svd(&a);
        assert!(d.s[0] > 1e-8);
        for &sv in &d.s[1..] {
            assert!(sv < 1e-8, "rank-1 matrix must have one σ: {:?}", d.s);
        }
    }

    #[test]
    fn known_diagonal_singular_values() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -2.0; // sign goes into U/V
        a[(2, 2)] = 1.0;
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-10);
        assert!((d.s[1] - 2.0).abs() < 1e-10);
        assert!((d.s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn band_energy_consistency() {
        let mut rng = Pcg32::seeded(23);
        let a = Mat::randn(16, 16, 1.0, &mut rng);
        let d = svd(&a);
        // ||A_r' - A_r||_F = band energy (Eq. 4).
        let (r, r2) = (4, 9);
        let diff = (&d.reconstruct(r2) - &d.reconstruct(r)).fro_norm();
        assert!((diff - d.band_energy(r, r2)).abs() < 1e-8);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(5, 3);
        let d = svd(&a);
        assert!(d.s.iter().all(|&x| x == 0.0));
        assert!(d.reconstruct(3).allclose(&a, 1e-12));
    }
}
