//! Pure-Rust dense linear algebra substrate.
//!
//! Everything the DR-RL agent needs at run time — matmuls, full Jacobi
//! SVD (ground truth), randomized/batched partial SVD (`O(n²r)`, the
//! paper's cuSOLVER substitute), incremental rank extension (Eq. 12) and
//! power-iteration spectral norms (Eq. 16) — with no external crates.

pub mod incremental;
pub mod mat;
pub mod matmul;
pub mod partial_svd;
pub mod power_iter;
pub mod qr;
pub mod svd;

pub use incremental::{extend, truncate, IncrementalCache};
pub use mat::Mat;
pub use matmul::{matmul, matmul_at, matmul_bt, matvec, matvec_t};
pub use partial_svd::{batched_partial_svd, partial_svd, top_k_svd};
pub use power_iter::{spectral_norm, spectral_norm_fast};
pub use qr::{orthonormalize, qr_thin};
pub use svd::{svd, Svd};
