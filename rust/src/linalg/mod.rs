//! Pure-Rust dense linear algebra substrate.
//!
//! Everything the DR-RL agent needs at run time — matmuls, full Jacobi
//! SVD (ground truth), randomized/batched partial SVD (`O(n²r)`, the
//! paper's cuSOLVER substitute), incremental rank extension (Eq. 12) and
//! power-iteration spectral norms (Eq. 16) — with no external crates.
//!
//! # Kernel architecture
//!
//! Every dense product routes through the register-tiled, panel-packed
//! GEMM core in [`kernel`]:
//!
//! * **Packing layout** — the depth dimension is blocked at
//!   `kernel::KC` = 256; per block the right-hand operand is packed into
//!   contiguous kc×`NR` column panels (`NR` = 8 f64 lanes, zero-padded
//!   at the matrix edge), and the Aᵀ·B path additionally packs the left
//!   operand into kc×`MR` row tiles (`MR` = 4).
//! * **Tile constants** — the `MR`×`NR` = 4×8 micro-kernel accumulates
//!   into `[f64; 8]` register lanes with a branch-free inner loop; for
//!   the rank-bucket widths n ∈ {8, 16, 24, 32, 48, 64} the panel loop
//!   is monomorphized (`gemm_rows_bucket::<NP>`), so the low-rank apply
//!   and probe products run compile-known-N kernels.
//! * **Determinism contract** — all partitions (KC blocks, tiles,
//!   panels, the `K_CHUNK` = 64 reduction chunks of Aᵀ·B) are pure
//!   functions of the problem shape, never of pool size; per-element
//!   accumulation order is depth-ascending with a fixed reduce order,
//!   so serial/parallel/any-pool-size runs — and the fused vs. direct
//!   probe paths — are bit-identical per kernel version. Bit values are
//!   *not* stable across kernel versions; tests pin `matmul_naive` as a
//!   tolerance oracle, and the conformance layer's bit pairings compare
//!   like-for-like within one build.

pub mod incremental;
pub mod kernel;
pub mod mat;
pub mod matmul;
pub mod partial_svd;
pub mod power_iter;
pub mod qr;
pub mod svd;

pub use incremental::{extend, truncate, IncrementalCache};
pub use kernel::{axpy, dot, norm2, PackedAt};
pub use mat::Mat;
pub use matmul::{
    matmul, matmul_at, matmul_at_pooled, matmul_bt, matmul_bt_pooled, matmul_pooled, matvec,
    matvec_t,
};
pub use partial_svd::{batched_partial_svd, partial_svd, partial_svd_with, top_k_svd, ProbeKernel};
pub use power_iter::{spectral_norm, spectral_norm_fast};
pub use qr::{orthonormalize, qr_thin};
pub use svd::{svd, Svd};
