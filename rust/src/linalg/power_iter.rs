//! Power iteration for spectral norms (paper Eq. 16).
//!
//! The perturbation safety check needs ‖M‖₂ for Q/K residuals on every
//! decision step; the paper approximates it with K≈3 iterations of
//! v ← MᵀMv / ‖MᵀMv‖ instead of an eigendecomposition. Mirrored by the
//! Pallas kernel `power_iter.py` at L1.

use super::mat::Mat;
use super::matmul::{matvec, matvec_t};
use crate::util::Pcg32;

/// Estimate the spectral norm (largest singular value) of `a` with `k`
/// power iterations starting from a seeded random unit vector.
pub fn spectral_norm(a: &Mat, k: usize, seed: u64) -> f64 {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        return 0.0;
    }
    let mut rng = Pcg32::seeded(seed ^ 0x5851f42d4c957f2d);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    normalize(&mut v);
    let mut sigma = 0.0;
    for _ in 0..k.max(1) {
        // w = A v ; v ← Aᵀ w, normalize — one iteration of MᵀM.
        let w = matvec(a, &v);
        let mut av = matvec_t(a, &w);
        let nrm = norm(&av);
        if nrm < 1e-300 {
            return 0.0;
        }
        for x in av.iter_mut() {
            *x /= nrm;
        }
        v = av;
        // Rayleigh quotient estimate σ ≈ ‖A v‖.
        sigma = norm(&matvec(a, &v));
    }
    sigma
}

/// Spectral norm with the paper's default K=3.
pub fn spectral_norm_fast(a: &Mat, seed: u64) -> f64 {
    spectral_norm(a, 3, seed)
}

fn norm(v: &[f64]) -> f64 {
    // Blocked dot with fixed reduction tree — deterministic and SIMD-friendly.
    super::kernel::norm2(v)
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 1e-300 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;

    #[test]
    fn matches_svd_on_random_matrices() {
        let mut rng = Pcg32::seeded(30);
        for trial in 0..5 {
            let a = Mat::randn(30, 20, 1.0, &mut rng);
            let exact = svd(&a).s[0];
            let approx = spectral_norm(&a, 200, trial);
            let rel = (approx - exact).abs() / exact;
            // Random Gaussian matrices have closely spaced leading singular
            // values, so convergence is slow — 1e-4 relative is plenty.
            assert!(rel < 1e-4, "trial {trial}: {approx} vs {exact}");
        }
    }

    #[test]
    fn three_iterations_close_on_decaying_spectrum() {
        // Attention-like spectra decay fast, so K=3 is already tight —
        // this is the paper's operating regime.
        let mut rng = Pcg32::seeded(31);
        let u = crate::linalg::qr::orthonormalize(&Mat::randn(24, 24, 1.0, &mut rng));
        let v = crate::linalg::qr::orthonormalize(&Mat::randn(24, 24, 1.0, &mut rng));
        let mut a = Mat::zeros(24, 24);
        for k in 0..24 {
            let s = 5.0 * (0.5f64).powi(k as i32);
            a.axpy(s, &crate::linalg::incremental::outer(&u.col(k), &v.col(k)));
        }
        let exact = svd(&a).s[0];
        let approx = spectral_norm_fast(&a, 1);
        assert!((approx - exact).abs() / exact < 0.01, "{approx} vs {exact}");
    }

    #[test]
    fn underestimates_never_exceed_true_norm() {
        // Power iteration converges from below (Rayleigh quotient ≤ σ₁).
        let mut rng = Pcg32::seeded(32);
        let a = Mat::randn(15, 15, 1.0, &mut rng);
        let exact = svd(&a).s[0];
        for k in 1..6 {
            let est = spectral_norm(&a, k, 9);
            assert!(est <= exact + 1e-9, "k={k}");
        }
    }

    #[test]
    fn zero_matrix_norm_zero() {
        let a = Mat::zeros(8, 8);
        assert_eq!(spectral_norm_fast(&a, 0), 0.0);
    }

    #[test]
    fn vector_shapes() {
        let a = Mat::from_vec(1, 4, vec![3.0, 0.0, 4.0, 0.0]);
        let est = spectral_norm(&a, 10, 0);
        assert!((est - 5.0).abs() < 1e-9);
    }
}
