//! Incremental SVD rank updates (paper Eq. 12).
//!
//! When the agent raises the rank from r to r', only the singular
//! components {r+1, …, r'} are computed — by deflating the known top-r
//! part and running the randomized range finder on the residual — and the
//! factor matrices are extended in place:  U_{r'} = [U_r, u_{r+1} … u_{r'}].
//! Rank decreases are plain truncations (free).

use super::mat::Mat;
use super::matmul::matmul;
use super::partial_svd::partial_svd;
use super::svd::Svd;

/// Truncate an SVD to rank r (cheap path for rank decreases).
pub fn truncate(d: &Svd, r: usize) -> Svd {
    let r = r.min(d.s.len());
    Svd { u: d.u.take_cols(r), s: d.s[..r].to_vec(), v: d.v.take_cols(r) }
}

/// Extend a top-r SVD of `a` to rank `r_new` by computing only the new
/// band of components on the deflated residual (Eq. 12).
///
/// Returns the extended decomposition. If `r_new <= current`, truncates.
pub fn extend(a: &Mat, d: &Svd, r_new: usize, seed: u64) -> Svd {
    let r_cur = d.s.len();
    let r_new = r_new.min(a.rows()).min(a.cols());
    if r_new <= r_cur {
        return truncate(d, r_new);
    }
    // Residual R = A − U_r Σ_r V_rᵀ. (The residual's top components are
    // exactly A's components r+1…; deflation makes the band computable
    // without touching the already-known part.)
    let mut resid = a.clone();
    resid.sub_inplace(&d.reconstruct(r_cur));
    let band = r_new - r_cur;
    let extra = partial_svd(&resid, band, 8, 2, seed);
    // Stitch: U ← [U_r | U_band], etc. Singular values of the residual are
    // A's tail values so global descending order is preserved.
    let u = d.u.hcat(&extra.u.take_cols(band.min(extra.s.len())));
    let v = d.v.hcat(&extra.v.take_cols(band.min(extra.s.len())));
    let mut s = d.s.clone();
    s.extend_from_slice(&extra.s[..band.min(extra.s.len())]);
    Svd { u, s, v }
}

/// Cost model for the incremental update: fraction of a full rank-r'
/// decomposition that the incremental path avoids, ≈ (r'-r)/r' speedup
/// claim in §4.3.2 of the paper.
pub fn incremental_saving(r_old: usize, r_new: usize) -> f64 {
    if r_new == 0 || r_new <= r_old {
        return 1.0; // truncation is free
    }
    1.0 - (r_new - r_old) as f64 / r_new as f64
}

/// Stateful per-head incremental decomposition cache used by the
/// coordinator: holds the current factors and serves rank transitions.
#[derive(Debug, Clone)]
pub struct IncrementalCache {
    current: Option<Svd>,
    seed: u64,
    /// Count of full recomputes vs incremental extensions (for metrics).
    pub full_computes: usize,
    pub incremental_updates: usize,
    pub truncations: usize,
}

impl IncrementalCache {
    pub fn new(seed: u64) -> Self {
        IncrementalCache {
            current: None,
            seed,
            full_computes: 0,
            incremental_updates: 0,
            truncations: 0,
        }
    }

    /// Invalidate (new attention matrix — e.g. new segment).
    pub fn reset(&mut self) {
        self.current = None;
    }

    /// The cached decomposition, if any.
    pub fn current(&self) -> Option<&Svd> {
        self.current.as_ref()
    }

    /// Get a rank-r decomposition of `a`, reusing cached factors when the
    /// matrix is unchanged and only the rank moved.
    pub fn decompose(&mut self, a: &Mat, r: usize) -> &Svd {
        self.seed = self.seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        match self.current.take() {
            None => {
                self.full_computes += 1;
                // §Perf iteration 2/3: probe-tuned randomized SVD
                // (oversample 4, one subspace iteration) — ~2× faster at
                // σ accuracy ~1e-5, far below featurization noise.
                self.current = Some(partial_svd(a, r, 4, 1, self.seed));
            }
            Some(d) => {
                if r <= d.s.len() {
                    self.truncations += 1;
                    self.current = Some(truncate(&d, r));
                } else {
                    self.incremental_updates += 1;
                    self.current = Some(extend(a, &d, r, self.seed));
                }
            }
        }
        self.current.as_ref().unwrap()
    }
}

/// Rank-1 outer-product helper used in tests and the oracle.
pub fn outer(u: &[f64], v: &[f64]) -> Mat {
    let mut m = Mat::zeros(u.len(), v.len());
    for i in 0..u.len() {
        for j in 0..v.len() {
            m[(i, j)] = u[i] * v[j];
        }
    }
    m
}

#[allow(dead_code)]
fn unused(_: fn(&Mat, &Mat) -> Mat) {}
const _: () = {
    let _ = matmul as fn(&Mat, &Mat) -> Mat;
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::partial_svd::top_k_svd;
    use crate::linalg::svd::svd;
    use crate::util::Pcg32;

    fn decaying_matrix(n: usize, seed: u64) -> Mat {
        // Matrix with geometric spectral decay — representative of
        // post-softmax attention.
        let mut rng = Pcg32::seeded(seed);
        let u = crate::linalg::qr::orthonormalize(&Mat::randn(n, n, 1.0, &mut rng));
        let v = crate::linalg::qr::orthonormalize(&Mat::randn(n, n, 1.0, &mut rng));
        let mut a = Mat::zeros(n, n);
        for k in 0..n {
            let s = 4.0 * (0.7f64).powi(k as i32);
            a.axpy(s, &outer(&u.col(k), &v.col(k)));
        }
        a
    }

    #[test]
    fn extend_matches_direct_partial() {
        let a = decaying_matrix(32, 1);
        let d8 = top_k_svd(&a, 8, 42);
        let d16 = extend(&a, &d8, 16, 43);
        assert_eq!(d16.s.len(), 16);
        let exact = svd(&a);
        for i in 0..16 {
            let rel = (d16.s[i] - exact.s[i]).abs() / exact.s[i].max(1e-12);
            assert!(rel < 1e-4, "σ_{i}: {} vs {}", d16.s[i], exact.s[i]);
        }
        // Reconstruction quality ≈ Eckart–Young at rank 16.
        let err = (&a - &d16.reconstruct(16)).fro_norm();
        let opt = exact.tail_energy(16);
        assert!(err <= 1.1 * opt + 1e-9, "{err} vs {opt}");
    }

    #[test]
    fn truncation_is_exact_prefix() {
        let a = decaying_matrix(24, 2);
        let d = top_k_svd(&a, 12, 7);
        let t = truncate(&d, 5);
        assert_eq!(t.s.len(), 5);
        assert_eq!(&t.s[..], &d.s[..5]);
        assert!(t.u.allclose(&d.u.take_cols(5), 0.0));
    }

    #[test]
    fn saving_formula() {
        assert!((incremental_saving(16, 64) - 0.25).abs() < 1e-12);
        assert_eq!(incremental_saving(32, 16), 1.0);
        assert_eq!(incremental_saving(0, 0), 1.0);
    }

    #[test]
    fn cache_counts_paths() {
        let a = decaying_matrix(20, 3);
        let mut cache = IncrementalCache::new(5);
        cache.decompose(&a, 4); // full
        cache.decompose(&a, 8); // incremental
        cache.decompose(&a, 3); // truncation
        cache.reset();
        cache.decompose(&a, 6); // full again
        assert_eq!(cache.full_computes, 2);
        assert_eq!(cache.incremental_updates, 1);
        assert_eq!(cache.truncations, 1);
    }

    #[test]
    fn cache_rank_correctness_after_transitions() {
        let a = decaying_matrix(28, 4);
        let exact = svd(&a);
        let mut cache = IncrementalCache::new(11);
        for &r in &[4usize, 10, 6, 14] {
            let d = cache.decompose(&a, r);
            assert_eq!(d.s.len(), r);
            for i in 0..r {
                let rel = (d.s[i] - exact.s[i]).abs() / exact.s[i].max(1e-12);
                assert!(rel < 1e-3, "after transition to {r}, σ_{i} off by {rel}");
            }
        }
    }
}
