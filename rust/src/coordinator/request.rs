//! Request/response types for the serving engine.

use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// Why a queued request did not produce a result. Sent as an explicit
/// error response instead of silently dropping the reply channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineError {
    pub id: RequestId,
    pub message: String,
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {}: {}", self.id, self.message)
    }
}

impl std::error::Error for EngineError {}

/// What a reply channel carries: the response or an explicit error.
pub type EngineResult<T> = Result<T, EngineError>;

/// Receiving half of a reply channel, as handed back by `submit_*`.
pub type ResponseReceiver<T> = std::sync::mpsc::Receiver<EngineResult<T>>;

/// A generation request (LM serving path).
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// An attention request (the DR-RL adaptive path): one decision segment
/// of per-head attention offloaded to the rank-bucket executables.
#[derive(Debug, Clone)]
pub struct AttentionRequest {
    pub id: RequestId,
    /// Layer input activations (n × d_model), row-major f64.
    pub x: Vec<f64>,
    pub n: usize,
    pub d_model: usize,
    pub layer: usize,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub queued_ms: f64,
    pub compute_ms: f64,
    /// Number of generation requests co-batched in the same drained
    /// batch (same-type convention as `AttentionResponse::batch_size`).
    pub batch_size: usize,
}

/// Completed attention segment.
#[derive(Debug, Clone)]
pub struct AttentionResponse {
    pub id: RequestId,
    /// Output activations (n × d_model).
    pub y: Vec<f64>,
    /// Ranks chosen per head.
    pub ranks: Vec<usize>,
    /// Analytic FLOPs spent vs the full-rank cost.
    pub flops_spent: u64,
    pub flops_full: u64,
    pub queued_ms: f64,
    /// Wall-clock of the staged pipeline run that served this request's
    /// drained batch (shared by every co-batched request, mirroring the
    /// per-chunk convention of the generate path).
    pub compute_ms: f64,
    /// Number of attention requests co-batched into that pipeline run.
    pub batch_size: usize,
}

/// Internal envelope carrying arrival time.
pub struct Pending<T> {
    pub inner: T,
    pub arrived: Instant,
}

impl<T> Pending<T> {
    pub fn now(inner: T) -> Self {
        Pending { inner, arrived: Instant::now() }
    }

    pub fn queued_ms(&self) -> f64 {
        self.arrived.elapsed().as_secs_f64() * 1e3
    }
}
