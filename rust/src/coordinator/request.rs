//! Request/response types for the serving engine.

use std::time::{Duration, Instant};

/// Unique request id.
pub type RequestId = u64;

/// Machine-readable classification of an [`EngineError`]. Clients branch
/// on the kind (retry on `Rejected`, drop on `Cancelled`/`DeadlineExceeded`,
/// fail over on `Shutdown`) and log the message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The request failed submit-time validation (shape/layer checks) and
    /// was never queued.
    Invalid,
    /// Backpressure: the bounded submit queue was full.
    Rejected,
    /// The ticket was cancelled before the request ran.
    Cancelled,
    /// The request's deadline expired before the request ran.
    DeadlineExceeded,
    /// The engine stopped before the request ran.
    Shutdown,
    /// Execution failed inside the worker.
    Internal,
}

impl std::fmt::Display for ErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ErrorKind::Invalid => "invalid",
            ErrorKind::Rejected => "rejected",
            ErrorKind::Cancelled => "cancelled",
            ErrorKind::DeadlineExceeded => "deadline exceeded",
            ErrorKind::Shutdown => "shutdown",
            ErrorKind::Internal => "internal",
        };
        f.write_str(s)
    }
}

/// Why a request did not produce a result. Posted as an explicit error
/// completion instead of silently dropping the ticket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineError {
    pub id: RequestId,
    pub kind: ErrorKind,
    pub message: String,
}

impl EngineError {
    pub fn new(id: RequestId, kind: ErrorKind, message: impl Into<String>) -> Self {
        EngineError { id, kind, message: message.into() }
    }

    pub(crate) fn cancelled(id: RequestId) -> Self {
        Self::new(id, ErrorKind::Cancelled, "request cancelled before it ran")
    }

    pub(crate) fn deadline_exceeded(id: RequestId) -> Self {
        Self::new(id, ErrorKind::DeadlineExceeded, "deadline expired before the request ran")
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {} [{}]: {}", self.id, self.kind, self.message)
    }
}

impl std::error::Error for EngineError {}

/// What a completion carries: the response or an explicit error.
pub type EngineResult<T> = Result<T, EngineError>;

/// Per-request submission options.
///
/// The default is the old behavior: no deadline, fail-fast backpressure.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Drop the request with [`ErrorKind::DeadlineExceeded`] if it has not
    /// *started executing* by this instant. Requests with deadlines are
    /// also queue-prioritized earliest-deadline-first ahead of requests
    /// without one.
    pub deadline: Option<Instant>,
    /// When the bounded queue is full, block until space frees up (or the
    /// deadline passes) instead of failing fast with [`ErrorKind::Rejected`].
    pub blocking: bool,
}

impl SubmitOptions {
    /// Options with a deadline `timeout` from now.
    pub fn deadline_in(timeout: Duration) -> Self {
        SubmitOptions { deadline: Some(Instant::now() + timeout), ..Default::default() }
    }

    /// Set the absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Block on a full queue instead of rejecting.
    pub fn with_blocking(mut self) -> Self {
        self.blocking = true;
        self
    }
}

/// A generation request (LM serving path).
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// An attention request (the DR-RL adaptive path): one decision segment
/// of per-head attention offloaded to the rank-bucket executables.
#[derive(Debug, Clone)]
pub struct AttentionRequest {
    pub id: RequestId,
    /// Layer input activations (n × d_model), row-major f64.
    pub x: Vec<f64>,
    pub n: usize,
    pub d_model: usize,
    pub layer: usize,
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub queued_ms: f64,
    pub compute_ms: f64,
    /// Number of generation requests co-batched in the same drained
    /// batch (same-type convention as `AttentionResponse::batch_size`).
    pub batch_size: usize,
    /// Projected device latency of the LM chunk that decoded this
    /// request (shared by every request packed into the chunk, like
    /// `compute_ms`), when a projection profile is in scope — the sim
    /// backend's own, or the engine's configured `reward_profile`.
    pub projected_ms: Option<f64>,
}

/// One incremental token produced by a streaming generation ticket,
/// surfaced as soon as the decode step that produced it completes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenerateDelta {
    pub id: RequestId,
    /// Position of this token in the generated suffix (0-based).
    pub index: usize,
    pub token: i32,
}

/// Completed attention segment.
#[derive(Debug, Clone)]
pub struct AttentionResponse {
    pub id: RequestId,
    /// Output activations (n × d_model).
    pub y: Vec<f64>,
    /// Ranks chosen per head.
    pub ranks: Vec<usize>,
    /// Analytic FLOPs spent vs the full-rank cost.
    pub flops_spent: u64,
    pub flops_full: u64,
    pub queued_ms: f64,
    /// Wall-clock of the staged pipeline run that served this request's
    /// drained batch (shared by every co-batched request, mirroring the
    /// per-chunk convention of the generate path).
    pub compute_ms: f64,
    /// Number of attention requests co-batched into that pipeline run.
    pub batch_size: usize,
    /// Projected device latency attributable to *this request's* backend
    /// kernel charges (summed over its heads), when a projection profile
    /// is in scope. Per-request — unlike `compute_ms`, co-batched
    /// requests do not share it; summing it across a wave reproduces the
    /// sim backend's ledger charge for that wave.
    pub projected_ms: Option<f64>,
}

/// Internal envelope carrying arrival time and the optional deadline
/// (the batcher orders deadlined items earliest-deadline-first).
pub struct Pending<T> {
    pub inner: T,
    pub arrived: Instant,
    pub deadline: Option<Instant>,
}

impl<T> Pending<T> {
    pub fn now(inner: T) -> Self {
        Pending { inner, arrived: Instant::now(), deadline: None }
    }

    pub fn with_deadline(inner: T, deadline: Option<Instant>) -> Self {
        Pending { inner, arrived: Instant::now(), deadline }
    }

    pub fn queued_ms(&self) -> f64 {
        self.arrived.elapsed().as_secs_f64() * 1e3
    }
}
