//! Dynamic batcher: groups queued requests into batches bounded by a
//! maximum size (the artifact's static batch dimension) and a maximum
//! queue delay, with bounded-queue backpressure — the standard
//! continuous-batching front-end of serving systems (vLLM-style).
//!
//! Drained batches preserve submission (FIFO) order. The engine's
//! cross-request attention pipeline relies on this: its decision replay
//! runs in drained order, which is what makes a co-batched run
//! bit-identical to serving the same requests one at a time.

use super::request::Pending;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch (typically the artifact batch dim).
    pub max_batch: usize,
    /// Max time the *oldest* request may wait before a partial batch is
    /// released.
    pub max_wait: Duration,
    /// Queue capacity; `submit` rejects beyond this (backpressure).
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), capacity: 1024 }
    }
}

/// Thread-safe dynamic batching queue.
pub struct DynamicBatcher<T> {
    policy: BatchPolicy,
    state: Mutex<Inner<T>>,
    cv: Condvar,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

/// Why `submit` failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Backpressure: queue full.
    Full,
    Closed,
}

impl<T> DynamicBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher {
            policy,
            state: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (non-blocking).
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.state.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.queue.len() >= self.policy.capacity {
            return Err(SubmitError::Full);
        }
        g.queue.push_back(Pending::now(item));
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue; pullers drain whatever remains, then get None.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Blocking pull of the next batch. Returns when
    ///   * max_batch requests are ready, or
    ///   * the oldest waiter exceeded max_wait and the queue is non-empty.
    /// Returns None once closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if g.queue.len() >= self.policy.max_batch {
                return Some(drain(&mut g.queue, self.policy.max_batch));
            }
            if !g.queue.is_empty() {
                let oldest = g.queue.front().unwrap().arrived;
                let elapsed = oldest.elapsed();
                if elapsed >= self.policy.max_wait {
                    let n = g.queue.len().min(self.policy.max_batch);
                    return Some(drain(&mut g.queue, n));
                }
                // Wait the remaining window (or for more arrivals).
                let remaining = self.policy.max_wait - elapsed;
                let (ng, _timeout) = self.cv.wait_timeout(g, remaining).unwrap();
                g = ng;
            } else {
                if g.closed {
                    return None;
                }
                g = self.cv.wait(g).unwrap();
            }
            if g.closed && g.queue.is_empty() {
                return None;
            }
        }
    }

    /// Non-blocking: batch only if one is ready *right now*.
    pub fn try_next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.state.lock().unwrap();
        if g.queue.len() >= self.policy.max_batch {
            return Some(drain(&mut g.queue, self.policy.max_batch));
        }
        if let Some(front) = g.queue.front() {
            if front.arrived.elapsed() >= self.policy.max_wait {
                let n = g.queue.len().min(self.policy.max_batch);
                return Some(drain(&mut g.queue, n));
            }
        }
        None
    }
}

fn drain<T>(q: &mut VecDeque<Pending<T>>, n: usize) -> Vec<Pending<T>> {
    q.drain(..n).collect()
}

/// Helper for tests/benches: deadline-aware arrival clock.
pub fn wait_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_released_immediately() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
            capacity: 100,
        });
        for i in 0..4 {
            b.submit(i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_batch_released_after_max_wait() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            capacity: 100,
        });
        b.submit(1).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(15), "waited {waited:?}");
    }

    #[test]
    fn drained_batches_preserve_fifo_order() {
        // The pipeline's decision-ordering invariant depends on this.
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
            capacity: 100,
        });
        for i in 0..7 {
            b.submit(i).unwrap();
        }
        let mut seen = Vec::new();
        while seen.len() < 7 {
            let batch = b.next_batch().unwrap();
            seen.extend(batch.into_iter().map(|p| p.inner));
        }
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 3,
        });
        for i in 0..3 {
            b.submit(i).unwrap();
        }
        assert_eq!(b.submit(99), Err(SubmitError::Full));
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 10,
        });
        b.submit(1).unwrap();
        b.close();
        assert_eq!(b.submit(2), Err(SubmitError::Closed));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_single_consumer() {
        let b = Arc::new(DynamicBatcher::new(BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            capacity: 10_000,
        }));
        let n_producers = 4;
        let per = 100;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    while b.submit(p * per + i).is_err() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while seen.len() < n_producers * per {
                    if let Some(batch) = b.next_batch() {
                        seen.extend(batch.into_iter().map(|p| p.inner));
                    }
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = consumer.join().unwrap();
        seen.sort();
        assert_eq!(seen, (0..n_producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn try_next_batch_nonblocking() {
        let b: DynamicBatcher<u32> = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
            capacity: 10,
        });
        assert!(b.try_next_batch().is_none());
        b.submit(1).unwrap();
        // Not full and not timed out → still none.
        assert!(b.try_next_batch().is_none());
    }
}
