//! Dynamic batcher: groups queued requests into batches bounded by a
//! maximum size (the artifact's static batch dimension) and a maximum
//! queue delay, with bounded-queue backpressure — the standard
//! continuous-batching front-end of serving systems (vLLM-style).
//!
//! Drained batches preserve submission (FIFO) order among undeadlined
//! requests. The engine's cross-request attention pipeline relies on
//! this: its decision replay runs in drained order, which is what makes
//! a co-batched run bit-identical to serving the same requests one at a
//! time. Requests submitted *with* a deadline opt out of strict FIFO:
//! they are inserted earliest-deadline-first ahead of undeadlined
//! traffic, trading replay position for latency.
//!
//! Two extensions over the plain bounded queue:
//!
//! * **Blocking submit** — `submit_opts(_, _, blocking=true)` parks the
//!   submitter until space frees (or its deadline passes) instead of
//!   failing fast, for clients that prefer throttling to retry loops.
//! * **Same-key over-drain** — a batcher built `with_key` may drain past
//!   `max_batch` (up to `max_batch + overdrain`) as long as the next
//!   queued items share the batch head's key. The engine keys attention
//!   requests by layer, so a deep same-layer backlog becomes one deeper
//!   co-batch → one probe wave — instead of several shallow ones.

use super::request::Pending;
use crate::util::{CondvarExt, LockExt};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch (typically the artifact batch dim).
    pub max_batch: usize,
    /// Max time the *oldest* request may wait before a partial batch is
    /// released.
    pub max_wait: Duration,
    /// Queue capacity; `submit` rejects beyond this (backpressure).
    pub capacity: usize,
    /// Extra items a keyed batcher may drain past `max_batch` while the
    /// queue front shares the batch head's key (0 disables over-drain).
    pub overdrain: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            capacity: 1024,
            overdrain: 8,
        }
    }
}

/// Thread-safe dynamic batching queue.
pub struct DynamicBatcher<T> {
    policy: BatchPolicy,
    /// Over-drain affinity key (e.g. attention layer); `None` keys never
    /// extend a batch.
    key: Option<fn(&T) -> Option<usize>>,
    state: Mutex<Inner<T>>,
    /// Consumers wait here for arrivals.
    cv: Condvar,
    /// Blocking submitters wait here for queue space.
    space_cv: Condvar,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
    /// Arrival time of the earliest-*submitted* queued item. EDF
    /// inserts reorder the queue, so the front is not necessarily the
    /// oldest; the max_wait flush clock must read this instead.
    oldest: Option<Instant>,
    /// Length of the EDF-sorted deadlined prefix (everything after it is
    /// arrival-ordered FIFO), so `refresh_oldest` scans only the prefix.
    n_deadlined: usize,
}

impl<T> Inner<T> {
    /// Recompute `oldest` after front removals. The FIFO tail is
    /// arrival-sorted, so the overall minimum is min(deadlined prefix,
    /// first FIFO item) — O(prefix), not O(queue).
    fn refresh_oldest(&mut self) {
        self.oldest = self.queue.iter().take(self.n_deadlined + 1).map(|p| p.arrived).min();
    }
}

/// Why `submit` failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Backpressure: queue full.
    Full,
    Closed,
    /// A blocking submit's deadline passed while waiting for space.
    Expired,
}

impl<T> DynamicBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher {
            policy,
            key: None,
            state: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
                oldest: None,
                n_deadlined: 0,
            }),
            cv: Condvar::new(),
            space_cv: Condvar::new(),
        }
    }

    /// A batcher with a same-key over-drain affinity function.
    pub fn with_key(policy: BatchPolicy, key: fn(&T) -> Option<usize>) -> Self {
        DynamicBatcher { key: Some(key), ..Self::new(policy) }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (non-blocking, no deadline).
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        self.submit_opts(item, None, false)
    }

    /// Enqueue with an optional deadline (earliest-deadline-first
    /// priority) and an optional blocking mode that waits for queue
    /// space instead of failing fast.
    pub fn submit_opts(
        &self,
        item: T,
        deadline: Option<Instant>,
        blocking: bool,
    ) -> Result<(), SubmitError> {
        let mut g = self.state.lock_unpoisoned();
        loop {
            if g.closed {
                return Err(SubmitError::Closed);
            }
            if g.queue.len() < self.policy.capacity {
                break;
            }
            if !blocking {
                return Err(SubmitError::Full);
            }
            match deadline {
                None => g = self.space_cv.wait_unpoisoned(g),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(SubmitError::Expired);
                    }
                    let (ng, _) = self.space_cv.wait_timeout_unpoisoned(g, d - now);
                    g = ng;
                }
            }
        }
        let item = Pending::with_deadline(item, deadline);
        // Arrivals are monotone, so a non-empty queue's oldest stays put.
        if g.oldest.is_none() {
            g.oldest = Some(item.arrived);
        }
        match deadline {
            None => g.queue.push_back(item),
            Some(d) => {
                // EDF: ahead of every queued item that has no deadline or
                // a strictly later one (stable among equal deadlines).
                // The queue is always a sorted-by-deadline prefix followed
                // by FIFO undeadlined items, so binary search finds the
                // position without an O(n) scan under the lock.
                let pos = g
                    .queue
                    .partition_point(|q| matches!(q.deadline, Some(qd) if qd <= d));
                g.queue.insert(pos, item);
                g.n_deadlined += 1;
            }
        }
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.state.lock_unpoisoned().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue; pullers drain whatever remains, then get None.
    pub fn close(&self) {
        self.state.lock_unpoisoned().closed = true;
        self.cv.notify_all();
        self.space_cv.notify_all();
    }

    /// Drain up to `n` items, then extend past `max_batch` while the
    /// queue front shares the batch head's key (capped by `overdrain`).
    /// Wakes blocking submitters since space was freed.
    fn drain(&self, g: &mut Inner<T>, n: usize) -> Vec<Pending<T>> {
        let mut batch: Vec<Pending<T>> = g.queue.drain(..n).collect();
        if let Some(key_fn) = self.key {
            if batch.len() == self.policy.max_batch && self.policy.overdrain > 0 {
                if let Some(head_key) = key_fn(&batch[0].inner) {
                    let cap = self.policy.max_batch + self.policy.overdrain;
                    while batch.len() < cap {
                        match g.queue.front() {
                            Some(p) if key_fn(&p.inner) == Some(head_key) => {
                                batch.push(g.queue.pop_front().unwrap());
                            }
                            _ => break,
                        }
                    }
                }
            }
        }
        g.n_deadlined -= batch.iter().filter(|p| p.deadline.is_some()).count();
        g.refresh_oldest();
        self.space_cv.notify_all();
        batch
    }

    /// Blocking pull of the next batch. Returns when
    ///   * max_batch requests are ready, or
    ///   * the oldest waiter exceeded max_wait and the queue is non-empty.
    /// Returns None once closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.state.lock_unpoisoned();
        loop {
            if g.queue.len() >= self.policy.max_batch {
                return Some(self.drain(&mut g, self.policy.max_batch));
            }
            if !g.queue.is_empty() {
                let oldest = g.oldest.expect("non-empty queue tracks its oldest arrival");
                let elapsed = oldest.elapsed();
                if elapsed >= self.policy.max_wait {
                    let n = g.queue.len().min(self.policy.max_batch);
                    return Some(self.drain(&mut g, n));
                }
                // Wait the remaining window (or for more arrivals).
                let remaining = self.policy.max_wait - elapsed;
                let (ng, _timeout) = self.cv.wait_timeout_unpoisoned(g, remaining);
                g = ng;
            } else {
                if g.closed {
                    return None;
                }
                g = self.cv.wait_unpoisoned(g);
            }
            if g.closed && g.queue.is_empty() {
                return None;
            }
        }
    }

    /// Non-blocking: batch only if one is ready *right now*.
    pub fn try_next_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.state.lock_unpoisoned();
        if g.queue.len() >= self.policy.max_batch {
            return Some(self.drain(&mut g, self.policy.max_batch));
        }
        if let Some(oldest) = g.oldest {
            if oldest.elapsed() >= self.policy.max_wait {
                let n = g.queue.len().min(self.policy.max_batch);
                return Some(self.drain(&mut g, n));
            }
        }
        None
    }
}

/// Helper for tests/benches: deadline-aware arrival clock.
pub fn wait_until(t: Instant) {
    let now = Instant::now();
    if t > now {
        std::thread::sleep(t - now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn policy(max_batch: usize, max_wait_ms: u64, capacity: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(max_wait_ms),
            capacity,
            overdrain: 0,
        }
    }

    #[test]
    fn full_batch_released_immediately() {
        let b = DynamicBatcher::new(policy(4, 10_000, 100));
        for i in 0..4 {
            b.submit(i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_batch_released_after_max_wait() {
        let b = DynamicBatcher::new(policy(8, 20, 100));
        b.submit(1).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(15), "waited {waited:?}");
    }

    #[test]
    fn drained_batches_preserve_fifo_order() {
        // The pipeline's decision-ordering invariant depends on this.
        let b = DynamicBatcher::new(policy(3, 1, 100));
        for i in 0..7 {
            b.submit(i).unwrap();
        }
        let mut seen = Vec::new();
        while seen.len() < 7 {
            let batch = b.next_batch().unwrap();
            seen.extend(batch.into_iter().map(|p| p.inner));
        }
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let b = DynamicBatcher::new(policy(2, 1, 3));
        for i in 0..3 {
            b.submit(i).unwrap();
        }
        assert_eq!(b.submit(99), Err(SubmitError::Full));
    }

    #[test]
    fn close_drains_then_none() {
        let b = DynamicBatcher::new(policy(2, 1, 10));
        b.submit(1).unwrap();
        b.close();
        assert_eq!(b.submit(2), Err(SubmitError::Closed));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_single_consumer() {
        let b = Arc::new(DynamicBatcher::new(policy(16, 5, 10_000)));
        let n_producers = 4;
        let per = 100;
        let mut handles = Vec::new();
        for p in 0..n_producers {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    while b.submit(p * per + i).is_err() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while seen.len() < n_producers * per {
                    if let Some(batch) = b.next_batch() {
                        seen.extend(batch.into_iter().map(|p| p.inner));
                    }
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut seen = consumer.join().unwrap();
        seen.sort();
        assert_eq!(seen, (0..n_producers * per).collect::<Vec<_>>());
    }

    #[test]
    fn try_next_batch_nonblocking() {
        let b: DynamicBatcher<u32> = DynamicBatcher::new(policy(4, 1000, 10));
        assert!(b.try_next_batch().is_none());
        b.submit(1).unwrap();
        // Not full and not timed out → still none.
        assert!(b.try_next_batch().is_none());
    }

    #[test]
    fn deadline_items_are_edf_prioritized() {
        let b = DynamicBatcher::new(policy(8, 1, 100));
        b.submit('a').unwrap();
        b.submit('b').unwrap();
        let soon = Instant::now() + Duration::from_secs(1);
        let later = Instant::now() + Duration::from_secs(2);
        b.submit_opts('d', Some(later), false).unwrap();
        b.submit_opts('c', Some(soon), false).unwrap();
        let batch = b.next_batch().unwrap();
        let order: Vec<char> = batch.into_iter().map(|p| p.inner).collect();
        // Deadlined items jump ahead of FIFO traffic, earliest first;
        // undeadlined items keep their relative order.
        assert_eq!(order, vec!['c', 'd', 'a', 'b']);
    }

    #[test]
    fn edf_insert_does_not_reset_the_max_wait_clock() {
        // The flush window is measured from the earliest *submission*
        // still queued; a deadlined item jumping to the queue front must
        // not make the consumer re-wait its max_wait from scratch.
        let b = DynamicBatcher::new(policy(8, 100, 100));
        let t0 = Instant::now();
        b.submit('a').unwrap();
        std::thread::sleep(Duration::from_millis(60));
        b.submit_opts('b', Some(Instant::now() + Duration::from_secs(10)), false).unwrap();
        let batch = b.next_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch.len(), 2);
        assert!(
            waited < Duration::from_millis(150),
            "flush must key off 'a' (~100ms), not 'b' (~160ms): waited {waited:?}"
        );
    }

    #[test]
    fn blocking_submit_waits_for_space() {
        let b = Arc::new(DynamicBatcher::new(policy(2, 1, 2)));
        b.submit(0).unwrap();
        b.submit(1).unwrap();
        assert_eq!(b.submit(2), Err(SubmitError::Full));
        let submitter = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || b.submit_opts(2, None, true))
        };
        // Draining a batch frees space and wakes the blocked submitter.
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(submitter.join().unwrap(), Ok(()));
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn blocking_submit_expires_at_deadline() {
        let b = DynamicBatcher::new(policy(2, 10_000, 1));
        b.submit(0).unwrap();
        let d = Instant::now() + Duration::from_millis(20);
        assert_eq!(b.submit_opts(1, Some(d), true), Err(SubmitError::Expired));
    }

    #[test]
    fn overdrain_extends_same_key_runs() {
        // Key = value; all items share key 0 except the 4th.
        let keyed: fn(&usize) -> Option<usize> = |v| Some(*v % 10);
        let b = DynamicBatcher::with_key(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                capacity: 100,
                overdrain: 4,
            },
            keyed,
        );
        // Queue: 10, 20, 30, 41, 50 → keys 0,0,0,1,0.
        for v in [10, 20, 30, 41, 50] {
            b.submit(v).unwrap();
        }
        let batch = b.next_batch().unwrap();
        // Drains max_batch=2, then extends while the front matches the
        // head key: 30 matches, 41 stops the run.
        assert_eq!(batch.into_iter().map(|p| p.inner).collect::<Vec<_>>(), vec![10, 20, 30]);
        let rest = b.next_batch().unwrap();
        assert_eq!(rest.into_iter().map(|p| p.inner).collect::<Vec<_>>(), vec![41, 50]);
    }

    #[test]
    fn overdrain_respects_cap() {
        let keyed: fn(&usize) -> Option<usize> = |_| Some(0);
        let b = DynamicBatcher::with_key(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                capacity: 100,
                overdrain: 3,
            },
            keyed,
        );
        for v in 0..10 {
            b.submit(v).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 5, "max_batch + overdrain caps the extension");
    }

    #[test]
    fn no_key_means_no_overdrain() {
        let keyed: fn(&usize) -> Option<usize> = |_| None;
        let b = DynamicBatcher::with_key(
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                capacity: 100,
                overdrain: 4,
            },
            keyed,
        );
        for v in 0..5 {
            b.submit(v).unwrap();
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
    }
}
