//! L3 coordinator — the paper's serving-side system contribution:
//! request routing, dynamic batching with backpressure, the segment-
//! level DR-RL rank controller (featurize → policy → trust region →
//! incremental SVD → device dispatch), the staged cross-request
//! attention pipeline, and serving metrics.

pub mod batcher;
pub mod engine;
pub mod metrics;
mod pipeline;
pub mod rank_controller;
pub mod request;
pub mod router;

pub use batcher::{BatchPolicy, DynamicBatcher, SubmitError};
pub use engine::{EngineConfig, ServingEngine};
pub use metrics::Metrics;
pub use rank_controller::{ControllerConfig, Decision, PolicySource, RankController};
pub use request::{
    AttentionRequest, AttentionResponse, EngineError, EngineResult, GenerateRequest,
    GenerateResponse, RequestId, ResponseReceiver,
};
pub use router::{RouteStrategy, Router};
