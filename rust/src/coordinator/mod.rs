//! L3 coordinator — the paper's serving-side system contribution:
//! request routing, dynamic batching with backpressure, the segment-
//! level DR-RL rank controller (featurize → policy → trust region →
//! incremental SVD → device dispatch), the staged cross-request
//! attention pipeline, and serving metrics.
//!
//! ## Ticket / completion-queue lifecycle
//!
//! Submission is asynchronous end to end. `submit_generate` /
//! `submit_attention` (and their `_opts` variants taking
//! [`SubmitOptions`]) enqueue the request and return a typed
//! [`Ticket`] — the request id plus a shared completion slot.
//! Attention requests are shape/layer-validated at submit time and
//! rejected with [`ErrorKind::Invalid`] before queueing (generation
//! requests have no shape constraints: prompts are windowed to the
//! artifact's sequence length at decode). From there a client can:
//!
//! * [`Ticket::poll`] — non-blocking check for the result;
//! * [`Ticket::wait`] / [`Ticket::wait_timeout`] — block like the old
//!   receiver API did;
//! * [`Ticket::cancel`] (or a [`CancelToken`]) — abandon stale work:
//!   the queued request is dropped at drain time, *before* any
//!   probe/SVD compute, and completes with [`ErrorKind::Cancelled`];
//! * move the ticket into a [`CompletionQueue`] — one client thread
//!   drains completions for any number of in-flight tickets, of both
//!   request types, across every engine behind a [`Router`], in
//!   arrival-of-completion order ([`CompletionQueue::next`] returns
//!   `None` once all added tickets have resolved, so drain loops
//!   terminate on their own). [`CompletionQueue::select`] extends this
//!   across *queues*: one thread waits on several completion queues at
//!   once (e.g. two routers' queues) and is told which queue fired.
//!
//! [`SubmitOptions::deadline`] bounds queueing: an expired request is
//! dropped undrained with [`ErrorKind::DeadlineExceeded`], and
//! deadlined requests are queue-prioritized earliest-deadline-first.
//! [`SubmitOptions::blocking`] turns bounded-queue backpressure from
//! fail-fast rejection into throttling. The generate path additionally
//! offers `submit_generate_streaming`, whose [`StreamingTicket`]
//! surfaces per-token [`GenerateDelta`]s as decode steps complete.
//!
//! Every submitted request resolves exactly once — success, typed
//! [`EngineError`], or a `Shutdown`-kind error posted to all
//! outstanding tickets when the engine stops — so neither `wait` nor a
//! queue drain can hang.
//!
//! ### Migration from the receiver API
//!
//! `submit_*` used to hand back `(RequestId, mpsc::Receiver)`. The
//! mapping is mechanical:
//!
//! | old                                  | new                          |
//! |--------------------------------------|------------------------------|
//! | `let (id, rx) = submit_*(…)?`        | `let ticket = submit_*(…)?`  |
//! | `rx.recv()`                          | `ticket.wait()`              |
//! | `rx.recv_timeout(d)` (`Err` = time)  | `ticket.wait_timeout(d)` (`None` = time) |
//! | `rx.try_recv()`                      | `ticket.poll()`              |
//! | one thread parked per receiver       | one [`CompletionQueue`] for all tickets |
//!
//! Submit-side errors are now typed [`EngineError`]s (kinds `Rejected`,
//! `Invalid`, `Shutdown`, `DeadlineExceeded`) instead of the batcher's
//! raw `SubmitError`.

pub mod batcher;
pub mod completion;
pub mod engine;
pub mod metrics;
mod pipeline;
pub mod rank_controller;
pub mod request;
pub mod router;

pub use batcher::{BatchPolicy, DynamicBatcher, SubmitError};
pub use completion::{
    CancelToken, Completion, CompletionPayload, CompletionQueue, StreamingTicket, Ticket,
};
pub use engine::{DecideEvent, EngineConfig, PipelineHooks, ServingEngine};
pub use metrics::Metrics;
pub use rank_controller::{ControllerConfig, Decision, PolicySource, RankController};
pub use request::{
    AttentionRequest, AttentionResponse, EngineError, EngineResult, ErrorKind,
    GenerateDelta, GenerateRequest, GenerateResponse, RequestId, SubmitOptions,
};
pub use router::{RouteStrategy, Router};
