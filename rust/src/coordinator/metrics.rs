//! Serving metrics: latency percentiles, batch-size histogram, rank
//! histogram, the FLOPs ledger (spent vs full-rank counterfactual) and
//! safety-check counters — everything EXPERIMENTS.md reports for the
//! serving examples.

use crate::util::LatencyStats;
use std::sync::Mutex;

/// Aggregated metrics, cheap to share behind a Mutex (all updates are
/// off the device-thread critical path).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    queued: LatencyStats,
    compute: LatencyStats,
    e2e: LatencyStats,
    batch_sizes: Vec<u64>, // histogram: index = batch size
    rank_counts: Vec<u64>, // histogram: index = rank
    flops_spent: u64,
    flops_full: u64,
    requests: u64,
    rejected: u64,
    safety_masked: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, queued_ms: f64, compute_ms: f64, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.queued.record(queued_ms);
        g.compute.record(compute_ms);
        g.e2e.record(queued_ms + compute_ms);
        if g.batch_sizes.len() <= batch_size {
            g.batch_sizes.resize(batch_size + 1, 0);
        }
        g.batch_sizes[batch_size] += 1;
        g.requests += 1;
    }

    pub fn record_rank(&self, rank: usize) {
        let mut g = self.inner.lock().unwrap();
        if g.rank_counts.len() <= rank {
            g.rank_counts.resize(rank + 1, 0);
        }
        g.rank_counts[rank] += 1;
    }

    pub fn record_flops(&self, spent: u64, full: u64) {
        let mut g = self.inner.lock().unwrap();
        g.flops_spent += spent;
        g.flops_full += full;
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_safety_mask(&self) {
        self.inner.lock().unwrap().safety_masked += 1;
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock().unwrap().rejected
    }

    pub fn safety_masked(&self) -> u64 {
        self.inner.lock().unwrap().safety_masked
    }

    /// 1 − spent/full: the served FLOPs saving.
    pub fn flops_saving(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.flops_full == 0 {
            0.0
        } else {
            1.0 - g.flops_spent as f64 / g.flops_full as f64
        }
    }

    /// Mean selected rank.
    pub fn mean_rank(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        let total: u64 = g.rank_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        g.rank_counts
            .iter()
            .enumerate()
            .map(|(r, &c)| r as f64 * c as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Text report for examples/benches.
    pub fn report(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mean_batch = {
            let total: u64 = g.batch_sizes.iter().sum();
            if total == 0 {
                0.0
            } else {
                g.batch_sizes
                    .iter()
                    .enumerate()
                    .map(|(s, &c)| s as f64 * c as f64)
                    .sum::<f64>()
                    / total as f64
            }
        };
        let saving = if g.flops_full == 0 {
            0.0
        } else {
            1.0 - g.flops_spent as f64 / g.flops_full as f64
        };
        format!(
            "requests={} rejected={} safety_masked={}\n\
             queue  : {}\n\
             compute: {}\n\
             e2e    : {}\n\
             mean_batch={:.2} flops_saving={:.1}%",
            g.requests,
            g.rejected,
            g.safety_masked,
            g.queued.summary(),
            g.compute.summary(),
            g.e2e.summary(),
            mean_batch,
            saving * 1e2,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_requests() {
        let m = Metrics::new();
        m.record_request(1.0, 2.0, 4);
        m.record_request(3.0, 4.0, 8);
        assert_eq!(m.requests(), 2);
        let rep = m.report();
        assert!(rep.contains("requests=2"), "{rep}");
    }

    #[test]
    fn flops_saving_math() {
        let m = Metrics::new();
        m.record_flops(60, 100);
        m.record_flops(0, 100);
        assert!((m.flops_saving() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn mean_rank_weighted() {
        let m = Metrics::new();
        m.record_rank(16);
        m.record_rank(16);
        m.record_rank(64);
        assert!((m.mean_rank() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.flops_saving(), 0.0);
        assert_eq!(m.mean_rank(), 0.0);
    }
}
