//! Serving metrics: latency percentiles, batch-size histogram, rank
//! histogram, the FLOPs ledger (spent vs full-rank counterfactual), the
//! projected-device-latency ledger (per `DeviceProfile` roofline — spent
//! vs full-rank counterfactual, matching the sim backend's charges) and
//! safety-check counters — everything EXPERIMENTS.md reports for the
//! serving examples.

use crate::runtime::OpCounters;
use crate::util::{LatencyStats, LockExt};
use std::sync::{Arc, Mutex};

/// Aggregated metrics, cheap to share behind a Mutex (all updates are
/// off the device-thread critical path).
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Backend per-op execute counters (shared with the backend itself);
    /// attached by the engine at start so `report()` folds typed op
    /// counts and LM-cache hits in — replacing the old per-artifact
    /// `stats()` BTreeMap plumbing.
    backend_ops: Mutex<Option<Arc<OpCounters>>>,
}

#[derive(Default)]
struct Inner {
    queued: LatencyStats,
    compute: LatencyStats,
    e2e: LatencyStats,
    batch_sizes: Vec<u64>, // histogram: index = batch size
    rank_counts: Vec<u64>, // histogram: index = rank
    flops_spent: u64,
    flops_full: u64,
    /// Projected-device-latency ledger (ms): what the served requests'
    /// backend kernel charges project to on the attached profile, vs
    /// the full-rank counterfactual of the same requests. Live — folded
    /// into every `report()`, not printed once at process exit.
    projected_spent_ms: f64,
    projected_full_ms: f64,
    /// Name of the `DeviceProfile` the projection is priced on.
    projection_profile: Option<&'static str>,
    requests: u64,
    rejected: u64,
    /// Tickets cancelled by the client and reaped at drain time (their
    /// requests never reached the pipeline's plan stage).
    cancelled: u64,
    /// Requests dropped because their deadline expired before they ran.
    expired: u64,
    /// Requests rejected by submit-time validation (never queued).
    invalid: u64,
    /// Extra same-layer attention requests drained past `max_batch`
    /// (the batcher's over-drain extension — deeper co-batches).
    over_drained: u64,
    safety_masked: u64,
    // Cross-request attention-pipeline accounting (one record per
    // drained batch, not per request).
    attn_batches: u64,
    attn_co_batched: u64,
    probes: u64,
    probe_dispatches: u64,
    shard_locks: u64,
}

impl Inner {
    fn mean_co_batch(&self) -> f64 {
        if self.attn_batches == 0 {
            0.0
        } else {
            self.attn_co_batched as f64 / self.attn_batches as f64
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the serving backend's shared op counters so they surface
    /// in [`Metrics::report`].
    pub fn attach_backend_ops(&self, ops: Arc<OpCounters>) {
        *self.backend_ops.lock_unpoisoned() = Some(ops);
    }

    /// The attached backend op counters, if any.
    pub fn backend_ops(&self) -> Option<Arc<OpCounters>> {
        self.backend_ops.lock_unpoisoned().clone()
    }

    pub fn record_request(&self, queued_ms: f64, compute_ms: f64, batch_size: usize) {
        let mut g = self.inner.lock_unpoisoned();
        g.queued.record(queued_ms);
        g.compute.record(compute_ms);
        g.e2e.record(queued_ms + compute_ms);
        if g.batch_sizes.len() <= batch_size {
            g.batch_sizes.resize(batch_size + 1, 0);
        }
        g.batch_sizes[batch_size] += 1;
        g.requests += 1;
    }

    pub fn record_rank(&self, rank: usize) {
        let mut g = self.inner.lock_unpoisoned();
        if g.rank_counts.len() <= rank {
            g.rank_counts.resize(rank + 1, 0);
        }
        g.rank_counts[rank] += 1;
    }

    pub fn record_flops(&self, spent: u64, full: u64) {
        let mut g = self.inner.lock_unpoisoned();
        g.flops_spent += spent;
        g.flops_full += full;
    }

    /// Attach the device profile the projected-latency ledger prices on
    /// (the engine sets it at start when one is in scope).
    pub fn set_projection_profile(&self, name: &'static str) {
        self.inner.lock_unpoisoned().projection_profile = Some(name);
    }

    pub fn projection_profile(&self) -> Option<&'static str> {
        self.inner.lock_unpoisoned().projection_profile
    }

    /// Fold one request's (or one generate chunk's) projected device
    /// latency into the ledger: `spent_ms` mirrors the backend kernel
    /// charges it drove, `full_ms` the full-rank counterfactual.
    pub fn record_projected(&self, spent_ms: f64, full_ms: f64) {
        let mut g = self.inner.lock_unpoisoned();
        g.projected_spent_ms += spent_ms;
        g.projected_full_ms += full_ms;
    }

    /// Total projected device latency spent (ms). On a sim backend this
    /// matches the backend's own ledger to float-sum precision.
    pub fn projected_spent_ms(&self) -> f64 {
        self.inner.lock_unpoisoned().projected_spent_ms
    }

    /// Full-rank counterfactual projection (ms) of the same requests.
    pub fn projected_full_ms(&self) -> f64 {
        self.inner.lock_unpoisoned().projected_full_ms
    }

    /// 1 − spent/full on the projected-latency ledger.
    pub fn projected_saving(&self) -> f64 {
        let g = self.inner.lock_unpoisoned();
        if g.projected_full_ms == 0.0 {
            0.0
        } else {
            1.0 - g.projected_spent_ms / g.projected_full_ms
        }
    }

    /// One drained attention batch went through the staged pipeline:
    /// `co_batched` requests shared `probe_dispatches` pooled SVD waves
    /// (covering `probes` per-head decompositions) and `shard_locks`
    /// layer-lock round-trips. The per-request path records
    /// co_batched=1, one dispatch per probing request and two lock
    /// round-trips per request; the pipeline's whole point is that these
    /// grow with layers touched, not with requests.
    pub fn record_attention_batch(
        &self,
        co_batched: u64,
        probes: u64,
        probe_dispatches: u64,
        shard_locks: u64,
    ) {
        let mut g = self.inner.lock_unpoisoned();
        g.attn_batches += 1;
        g.attn_co_batched += co_batched;
        g.probes += probes;
        g.probe_dispatches += probe_dispatches;
        g.shard_locks += shard_locks;
    }

    pub fn attention_batches(&self) -> u64 {
        self.inner.lock_unpoisoned().attn_batches
    }

    /// Per-head probe decompositions run by the pipeline.
    pub fn probes(&self) -> u64 {
        self.inner.lock_unpoisoned().probes
    }

    /// Pooled probe waves dispatched (≤ one per drained batch).
    pub fn probe_dispatches(&self) -> u64 {
        self.inner.lock_unpoisoned().probe_dispatches
    }

    /// Layer-shard lock round-trips taken by the attention pipeline.
    pub fn shard_locks(&self) -> u64 {
        self.inner.lock_unpoisoned().shard_locks
    }

    /// Mean number of attention requests co-batched per drained batch.
    pub fn mean_co_batch(&self) -> f64 {
        self.inner.lock_unpoisoned().mean_co_batch()
    }

    pub fn record_rejection(&self) {
        self.inner.lock_unpoisoned().rejected += 1;
    }

    /// A cancelled ticket's request was reaped before running.
    pub fn record_cancelled(&self) {
        self.inner.lock_unpoisoned().cancelled += 1;
    }

    /// A request was dropped because its deadline expired before it ran.
    pub fn record_expired(&self) {
        self.inner.lock_unpoisoned().expired += 1;
    }

    /// A request failed submit-time validation.
    pub fn record_invalid(&self) {
        self.inner.lock_unpoisoned().invalid += 1;
    }

    /// `extra` same-key requests were drained past `max_batch`.
    pub fn record_over_drain(&self, extra: u64) {
        self.inner.lock_unpoisoned().over_drained += extra;
    }

    pub fn cancelled(&self) -> u64 {
        self.inner.lock_unpoisoned().cancelled
    }

    pub fn expired(&self) -> u64 {
        self.inner.lock_unpoisoned().expired
    }

    pub fn invalid(&self) -> u64 {
        self.inner.lock_unpoisoned().invalid
    }

    pub fn over_drained(&self) -> u64 {
        self.inner.lock_unpoisoned().over_drained
    }

    pub fn record_safety_mask(&self) {
        self.inner.lock_unpoisoned().safety_masked += 1;
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock_unpoisoned().requests
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock_unpoisoned().rejected
    }

    pub fn safety_masked(&self) -> u64 {
        self.inner.lock_unpoisoned().safety_masked
    }

    /// 1 − spent/full: the served FLOPs saving.
    pub fn flops_saving(&self) -> f64 {
        let g = self.inner.lock_unpoisoned();
        if g.flops_full == 0 {
            0.0
        } else {
            1.0 - g.flops_spent as f64 / g.flops_full as f64
        }
    }

    /// Mean selected rank.
    pub fn mean_rank(&self) -> f64 {
        let g = self.inner.lock_unpoisoned();
        let total: u64 = g.rank_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        g.rank_counts
            .iter()
            .enumerate()
            .map(|(r, &c)| r as f64 * c as f64)
            .sum::<f64>()
            / total as f64
    }

    /// Text report for examples/benches.
    pub fn report(&self) -> String {
        let g = self.inner.lock_unpoisoned();
        let mean_batch = {
            let total: u64 = g.batch_sizes.iter().sum();
            if total == 0 {
                0.0
            } else {
                g.batch_sizes
                    .iter()
                    .enumerate()
                    .map(|(s, &c)| s as f64 * c as f64)
                    .sum::<f64>()
                    / total as f64
            }
        };
        let saving = if g.flops_full == 0 {
            0.0
        } else {
            1.0 - g.flops_spent as f64 / g.flops_full as f64
        };
        let mean_co_batch = g.mean_co_batch();
        let mut out = format!(
            "requests={} rejected={} invalid={} cancelled={} expired={} safety_masked={}\n\
             queue  : {}\n\
             compute: {}\n\
             e2e    : {}\n\
             attn   : batches={} mean_co_batch={:.2} probes={} probe_waves={} shard_locks={} \
             over_drained={}\n\
             mean_batch={:.2} flops_saving={:.1}%",
            g.requests,
            g.rejected,
            g.invalid,
            g.cancelled,
            g.expired,
            g.safety_masked,
            g.queued.summary(),
            g.compute.summary(),
            g.e2e.summary(),
            g.attn_batches,
            mean_co_batch,
            g.probes,
            g.probe_dispatches,
            g.shard_locks,
            g.over_drained,
            mean_batch,
            saving * 1e2,
        );
        if let Some(profile) = g.projection_profile {
            let psave = if g.projected_full_ms == 0.0 {
                0.0
            } else {
                1.0 - g.projected_spent_ms / g.projected_full_ms
            };
            out.push_str(&format!(
                "\nprojected[{profile}]: spent={:.4}ms full_rank={:.4}ms saving={:.1}%",
                g.projected_spent_ms,
                g.projected_full_ms,
                psave * 1e2,
            ));
        }
        drop(g);
        if let Some(ops) = self.backend_ops() {
            // Counters live on the backend, which engines may share — so
            // this line is backend-wide, not per-engine.
            out.push_str(&format!("\nbackend ops (backend-wide): {}", ops.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_requests() {
        let m = Metrics::new();
        m.record_request(1.0, 2.0, 4);
        m.record_request(3.0, 4.0, 8);
        assert_eq!(m.requests(), 2);
        let rep = m.report();
        assert!(rep.contains("requests=2"), "{rep}");
    }

    #[test]
    fn flops_saving_math() {
        let m = Metrics::new();
        m.record_flops(60, 100);
        m.record_flops(0, 100);
        assert!((m.flops_saving() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn mean_rank_weighted() {
        let m = Metrics::new();
        m.record_rank(16);
        m.record_rank(16);
        m.record_rank(64);
        assert!((m.mean_rank() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.flops_saving(), 0.0);
        assert_eq!(m.mean_rank(), 0.0);
        assert_eq!(m.mean_co_batch(), 0.0);
    }

    #[test]
    fn lifecycle_counters() {
        let m = Metrics::new();
        m.record_cancelled();
        m.record_cancelled();
        m.record_expired();
        m.record_invalid();
        m.record_over_drain(3);
        assert_eq!(m.cancelled(), 2);
        assert_eq!(m.expired(), 1);
        assert_eq!(m.invalid(), 1);
        assert_eq!(m.over_drained(), 3);
        let rep = m.report();
        assert!(rep.contains("cancelled=2"), "{rep}");
        assert!(rep.contains("expired=1"), "{rep}");
        assert!(rep.contains("over_drained=3"), "{rep}");
    }

    #[test]
    fn report_folds_in_attached_backend_ops() {
        use crate::runtime::Op;
        let m = Metrics::new();
        assert!(!m.report().contains("backend ops"), "no ops line before attach");
        let ops = Arc::new(OpCounters::default());
        ops.record(Op::LowRankAttention);
        ops.record_lm_cache(true);
        m.attach_backend_ops(Arc::clone(&ops));
        let rep = m.report();
        assert!(rep.contains("backend ops (backend-wide): "), "{rep}");
        assert!(rep.contains("lowrank_attention=1"), "{rep}");
        assert!(rep.contains("lm_cache=1/0"), "{rep}");
        // The counters stay shared: later backend activity shows up.
        ops.record(Op::LowRankAttention);
        assert!(m.report().contains("lowrank_attention=2"));
    }

    #[test]
    fn projected_ledger_accumulates_and_reports_per_profile() {
        let m = Metrics::new();
        // No profile attached → no projected section.
        m.record_projected(1.0, 2.0);
        assert!(!m.report().contains("projected["), "{}", m.report());
        m.set_projection_profile("a100-sim");
        m.record_projected(0.5, 2.0);
        assert_eq!(m.projection_profile(), Some("a100-sim"));
        assert!((m.projected_spent_ms() - 1.5).abs() < 1e-12);
        assert!((m.projected_full_ms() - 4.0).abs() < 1e-12);
        assert!((m.projected_saving() - 0.625).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("projected[a100-sim]:"), "{rep}");
        assert!(rep.contains("saving=62.5%"), "{rep}");
    }

    #[test]
    fn empty_projected_ledger_is_zero_saving() {
        let m = Metrics::new();
        m.set_projection_profile("cpu");
        assert_eq!(m.projected_saving(), 0.0);
        assert!(m.report().contains("projected[cpu]: spent=0.0000ms"), "{}", m.report());
    }

    #[test]
    fn attention_batch_accounting() {
        let m = Metrics::new();
        // One co-batch of 6 requests: a single probe wave covering 12
        // head-probes and two lock round-trips; then a singleton batch.
        m.record_attention_batch(6, 12, 1, 2);
        m.record_attention_batch(1, 2, 1, 2);
        assert_eq!(m.attention_batches(), 2);
        assert_eq!(m.probes(), 14);
        assert_eq!(m.probe_dispatches(), 2);
        assert_eq!(m.shard_locks(), 4);
        assert!((m.mean_co_batch() - 3.5).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("probe_waves=2"), "{rep}");
    }
}
