//! Cross-request staged execution pipeline for attention segments.
//!
//! A worker that drains K attention requests no longer serves them one
//! by one (K shard-lock round-trips, K probe dispatches); it runs the
//! whole set through four stages:
//!
//! 1. **plan** — validate + project heads for every request, fanned out
//!    over the global pool, entirely outside any lock; then group the
//!    requests by layer in drained (arrival) order.
//! 2. **probe** — take each touched layer's shard lock once, briefly, to
//!    advance per-stream segment counters (`RankController::plan_steps`);
//!    then run the attention probe + truncated SVD for
//!    *every refreshing head of every request across all layers* in a
//!    single pooled dispatch — one batched SVD wave per drained batch.
//! 3. **decide** — take each layer's shard lock once more and replay the
//!    rank decisions serially in (request-arrival, head) order. Because
//!    stream state advances in exactly the order a per-request engine
//!    would apply it, the pipeline's outputs are bit-identical to
//!    submitting the same requests one at a time.
//! 4. **apply** — fan the masked factor applies (or dense kernels for a
//!    full-rank source) out in a second pooled dispatch, merge heads and
//!    reply, recording real queue delay and batch-level pipeline stats.
//!
//! Lock footprint: 2 × layers-touched round-trips per drained batch
//! instead of one round-trip per request, and the locks are held only
//! for bookkeeping/decisions — never across a probe or an apply (stream
//! factors are shared `Arc<Svd>` handles, so even the bookkeeping holds
//! no large copies under the lock).
//!
//! Concurrency note: when batches from *different* workers interleave on
//! one layer, each stream's decisions serialize in decide order — a
//! step's factors (Snapshot steps re-read the stream under the decide
//! lock) and its previous-rank chain are read together under that lock,
//! so every decision pairs a consistent (factors, prev_rank) state.
//! Segment positions are the one plan-time quantity: an interleaved
//! batch keeps the boundary phase it reserved when it drained. With a
//! single worker, or distinct layers, the result is exactly the
//! sequential one — the equality tests pin this bit-for-bit.

use super::completion::AttnReply;
use super::engine::{reap_error, record_reap, DecideEvent, EngineShared};
use super::rank_controller::{
    full_rank_decision, probe_head, resolve_probes, DecideCtx, Decision, PolicySource,
    ProbeSource, StepPlan,
};
use super::request::{AttentionRequest, AttentionResponse, EngineError, ErrorKind};
use crate::attention::{merge_heads, project_heads, AttnInputs};
use crate::linalg::{Mat, Svd};
use crate::util::{global_pool, LockExt, Stopwatch};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// One queued attention request with its arrival envelope and completion
/// slot, as regrouped by the worker from the drained batch.
pub(crate) struct AttnJob {
    pub arrived: Instant,
    pub req: AttentionRequest,
    pub reply: AttnReply,
}

/// Stage-1 output for one request: the layer input and projected heads.
struct Planned {
    x: Mat,
    heads: Vec<AttnInputs>,
}

/// Per-request execution state threaded through the stages.
struct JobState {
    queued_ms: f64,
    plan: Option<Planned>,
    error: Option<String>,
    /// Cancel/deadline reap landed at a stage boundary: the reply has
    /// already been posted and every later stage skips this job.
    reaped: bool,
    decisions: Vec<Option<Decision>>,
}

impl JobState {
    /// True when a later stage should still run work for this job.
    fn live(&self) -> bool {
        self.error.is_none() && !self.reaped
    }
}

/// Per-layer slice of the batch: the replay-ordered steps plus their
/// resolved decompositions (shared handles — filled by `resolve_probes`
/// after the probe wave, possibly re-read at decide time).
struct LayerWork {
    layer: usize,
    /// step index → (job index, head).
    owner: Vec<(usize, usize)>,
    steps: Vec<StepPlan>,
    svds: Vec<Arc<Svd>>,
}

/// What one apply-wave slot computes.
enum ApplyTask {
    /// Masked factor apply for layer-work `lw`, step `si`.
    Factor { lw: usize, si: usize },
    /// Dense full-rank kernel for job `j`, head `h`.
    Dense { j: usize, h: usize },
}

/// Cooperative cancellation at a stage boundary: re-check every still
/// live job's cancel/deadline state so an in-flight request stops
/// burning SVD waves and factor applies the moment its ticket dies.
/// Reaped jobs reply immediately (first post wins, so a client-side
/// `cancel()` that already posted makes this a no-op) and are skipped
/// by every later stage; their plan-stage stream bookkeeping — like a
/// failed request's — has already advanced, which is exactly the
/// sequential-serving behavior for a cancel landing mid-request.
fn reap_boundary(
    shared: &EngineShared,
    states: &mut [JobState],
    replies: &[AttnReply],
    reqs: &[AttentionRequest],
) {
    let now = Instant::now();
    for (j, state) in states.iter_mut().enumerate() {
        if !state.live() {
            continue;
        }
        if let Some(kind) = replies[j].reap_kind(now) {
            record_reap(&shared.metrics, kind);
            replies[j].fulfill(Err(reap_error(reqs[j].id, kind)));
            state.reaped = true;
        }
    }
}

fn plan_job(shared: &EngineShared, req: &AttentionRequest) -> Result<Planned> {
    anyhow::ensure!(req.layer < shared.layers.len(), "layer {} out of range", req.layer);
    let w = &shared.layers[req.layer];
    anyhow::ensure!(req.d_model == w.d_model(), "d_model mismatch");
    anyhow::ensure!(
        req.x.len() == req.n * req.d_model,
        "input length {} != n*d_model = {}",
        req.x.len(),
        req.n * req.d_model
    );
    let x = Mat::from_vec(req.n, req.d_model, req.x.clone());
    // Projection is stateless — it runs outside every lock.
    let heads = project_heads(&x, w, true);
    Ok(Planned { x, heads })
}

/// Serve one drained batch of attention requests through the staged
/// pipeline. Every job receives exactly one completion.
///
/// Jobs whose ticket was cancelled or whose deadline expired while
/// queued are reaped here — before the plan stage — so they never cost
/// a head projection, a probe, or a lock take. Cancellation stays
/// *cooperative inside* the pipeline too: the cancel/deadline state is
/// re-checked at every stage boundary (after plan, after the probe
/// wave, before the apply wave), so a ticket that dies mid-flight stops
/// burning SVD waves and runs no apply work.
pub(crate) fn run_attention_batch(shared: &EngineShared, jobs: Vec<AttnJob>) {
    let now = Instant::now();
    let mut live = Vec::with_capacity(jobs.len());
    for job in jobs {
        match job.reply.reap_kind(now) {
            Some(kind) => {
                record_reap(&shared.metrics, kind);
                job.reply.fulfill(Err(reap_error(job.req.id, kind)));
            }
            None => live.push(job),
        }
    }
    let jobs = live;
    if jobs.is_empty() {
        return;
    }
    let sw = Stopwatch::start();
    let co_batched = jobs.len();

    // Completion slots stay out of the per-stage state so no pool
    // closure ever captures them; posting happens only at the end.
    let mut reqs = Vec::with_capacity(jobs.len());
    let mut replies = Vec::with_capacity(jobs.len());
    let mut states: Vec<JobState> = Vec::with_capacity(jobs.len());
    for job in jobs {
        states.push(JobState {
            queued_ms: job.arrived.elapsed().as_secs_f64() * 1e3,
            plan: None,
            error: None,
            reaped: false,
            decisions: Vec::new(),
        });
        reqs.push(job.req);
        replies.push(job.reply);
    }

    // ---- Stage 1: plan (no locks) ----
    let planned = {
        let reqs_ref = &reqs;
        global_pool().scoped_map(reqs_ref.len(), |i| plan_job(shared, &reqs_ref[i]))
    };
    for (state, plan) in states.iter_mut().zip(planned) {
        match plan {
            Ok(p) => {
                state.decisions = (0..p.heads.len()).map(|_| None).collect();
                state.plan = Some(p);
            }
            Err(e) => state.error = Some(format!("{e:#}")),
        }
    }

    // Stage boundary: a ticket cancelled (or expired) while its heads
    // were being projected drops out before any controller bookkeeping.
    reap_boundary(shared, &mut states, &replies, &reqs);

    let full_rank = matches!(shared.source.as_ref(), PolicySource::FullRank);

    // Group plannable jobs by layer, preserving drained (arrival) order.
    // The full-rank source touches no controller state and skips
    // straight to the apply wave.
    let mut by_layer: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    if !full_rank {
        for (j, state) in states.iter().enumerate() {
            if state.plan.is_some() && !state.reaped {
                by_layer.entry(reqs[j].layer).or_default().push(j);
            }
        }
    }

    // ---- Stage 2a: per-stream bookkeeping — one short lock take per
    // touched layer. ----
    let mut shard_locks = 0u64;
    let mut works: Vec<LayerWork> = Vec::with_capacity(by_layer.len());
    for (&layer, job_idxs) in &by_layer {
        let n_heads = shared.layers[layer].n_heads;
        let mut owner = Vec::with_capacity(job_idxs.len() * n_heads);
        let mut head_seq = Vec::with_capacity(job_idxs.len() * n_heads);
        for &j in job_idxs {
            for h in 0..n_heads {
                owner.push((j, h));
                head_seq.push(h);
            }
        }
        let steps = {
            let mut controller = shared.shards[layer].lock_unpoisoned();
            shard_locks += 1;
            controller.plan_steps(layer, &head_seq)
        };
        works.push(LayerWork { layer, owner, steps, svds: Vec::new() });
    }

    // ---- Stage 2b: probe — one pooled SVD wave across all layers. ----
    let r_max = *shared
        .controller_cfg
        .rank_grid
        .iter()
        .max()
        .expect("non-empty rank grid");
    // Bucket rounding lives in ONE place (KernelShape::rank_bucket, via
    // the registry) — probe planning must agree with the apply wave's
    // bucket or the masked factor apply would see a short spectrum.
    let bucket_max = shared.reg.rank_bucket(r_max);
    // Per-work refresh step indices; the global task list concatenates
    // them in work order, so the wave's results split back by length.
    let refreshes: Vec<Vec<usize>> = works
        .iter()
        .map(|work| {
            work.steps
                .iter()
                .enumerate()
                .filter(|(_, s)| matches!(s.probe, ProbeSource::Refresh { .. }))
                .map(|(si, _)| si)
                .collect()
        })
        .collect();
    let probe_tasks: Vec<(usize, usize)> = refreshes
        .iter()
        .enumerate()
        .flat_map(|(lw, idxs)| idxs.iter().map(move |&si| (lw, si)))
        .collect();
    let probed = {
        let works_ref = &works;
        let states_ref = &states;
        let tasks_ref = &probe_tasks;
        global_pool().scoped_map(tasks_ref.len(), |t| {
            let (lw, si) = tasks_ref[t];
            let (j, h) = works_ref[lw].owner[si];
            // by_layer groups only jobs with plan.is_some(), so the probe
            // wave cannot see an unplanned job. lint:allow(panic-in-worker)
            let inp = &states_ref[j].plan.as_ref().expect("grouped jobs are planned").heads[h];
            match &works_ref[lw].steps[si].probe {
                ProbeSource::Refresh { cache_seed } => probe_head(inp, *cache_seed, bucket_max),
                _ => unreachable!("probe task targets a refresh step"),
            }
        })
    };
    let n_probes = probe_tasks.len() as u64;
    let probe_dispatches = u64::from(!probe_tasks.is_empty());
    let mut probed_it = probed.into_iter();
    for (lw, work) in works.iter_mut().enumerate() {
        let chunk: Vec<Arc<Svd>> = probed_it.by_ref().take(refreshes[lw].len()).collect();
        work.svds = resolve_probes(&work.steps, &refreshes[lw], chunk);
    }

    // Stage boundary: a cancel that landed while the probe wave ran
    // stops the request here — its decisions are never replayed and no
    // apply work is dispatched for it (the probes it contributed stay
    // published, exactly like an errored request's).
    if let Some(hook) = &shared.hooks.after_probe {
        hook();
    }
    reap_boundary(shared, &mut states, &replies, &reqs);

    // ---- Stage 3: decide — one lock take per layer, serial replay in
    // (request-arrival, head) order. ----
    for work in works.iter_mut() {
        let layer = work.layer;
        let weights = &shared.layers[layer];
        let mut controller = shared.shards[layer].lock_unpoisoned();
        shard_locks += 1;
        for si in 0..work.steps.len() {
            let (j, h) = work.owner[si];
            // Commit a fresh probe at its own replay position — never
            // earlier (a Snapshot step at a lower call must not re-read
            // a later same-batch refresh) and even when its job already
            // errored (a decision error must not un-publish factors
            // later steps were planned against; the per-request path
            // publishes probes of aborted requests too). O(1): the
            // handle is shared, not copied.
            if matches!(work.steps[si].probe, ProbeSource::Refresh { .. }) {
                controller.commit_probe(layer, work.steps[si].head, Arc::clone(&work.svds[si]));
            }
            if !states[j].live() {
                // A failed or boundary-reaped request replays no further
                // decisions (its calls counters already advanced, as on
                // the per-request path).
                continue;
            }
            // Snapshot steps re-read the stream under the decide lock:
            // commits from batches decided since this batch's plan are
            // honored in decide order, pairing fresh factors with the
            // prev_rank chain read below (see module doc).
            if matches!(work.steps[si].probe, ProbeSource::Snapshot(_)) {
                if let Some(p) = controller.stream_probe(layer, work.steps[si].head) {
                    work.svds[si] = p;
                }
            }
            let plan = states[j].plan.as_ref().expect("grouped jobs are planned");
            let ctx = DecideCtx {
                reg: &shared.reg,
                x_layer: &plan.x,
                w: weights,
                layer,
                n_layers: shared.layers.len(),
            };
            let inp = &plan.heads[h];
            match controller.decide_step(
                &ctx,
                &work.steps[si],
                &work.svds[si],
                inp.seq_len(),
                inp.head_dim(),
            ) {
                Ok(dec) => {
                    // Emitted under the shard lock: observers see the
                    // exact serialized decide order.
                    if let Some(observe) = &shared.hooks.on_decide {
                        observe(DecideEvent {
                            layer,
                            head: work.steps[si].head,
                            request: reqs[j].id,
                            step: si,
                            rank: dec.rank,
                            prev_rank: dec.prev_rank,
                            fresh: dec.fresh_decision,
                        });
                    }
                    states[j].decisions[h] = Some(dec);
                }
                Err(e) => states[j].error = Some(format!("{e:#}")),
            }
        }
    }

    // Stage boundary: last chance to drop a dead request before paying
    // for its factor applies.
    reap_boundary(shared, &mut states, &replies, &reqs);

    // ---- Stage 4: apply — one pooled dispatch across all layers. ----
    let mut apply_tasks: Vec<ApplyTask> = Vec::new();
    if full_rank {
        for (j, state) in states.iter().enumerate() {
            if !state.live() {
                continue;
            }
            if let Some(plan) = &state.plan {
                for h in 0..plan.heads.len() {
                    apply_tasks.push(ApplyTask::Dense { j, h });
                }
            }
        }
    } else {
        for (lw, work) in works.iter().enumerate() {
            for si in 0..work.steps.len() {
                let (j, _) = work.owner[si];
                if states[j].live() {
                    apply_tasks.push(ApplyTask::Factor { lw, si });
                }
            }
        }
    }
    let projection = shared.projection_profile();
    let applied = {
        let works_ref = &works;
        let states_ref = &states;
        let tasks_ref = &apply_tasks;
        let reg = &shared.reg;
        global_pool().scoped_map(tasks_ref.len(), |t| match tasks_ref[t] {
            ApplyTask::Factor { lw, si } => {
                let (j, h) = works_ref[lw].owner[si];
                // Factor tasks exist only for live planned+decided jobs
                // (filtered above). lint:allow(panic-in-worker)
                let plan = states_ref[j].plan.as_ref().expect("grouped jobs are planned");
                // Same filter covers decisions. lint:allow(panic-in-worker)
                let rank = states_ref[j].decisions[h].expect("decided").rank;
                reg.lowrank_attention(&works_ref[lw].svds[si], rank, &plan.heads[h].v)
            }
            ApplyTask::Dense { j, h } => {
                // Dense tasks are pushed per planned head only.
                // lint:allow(panic-in-worker)
                let inp = &states_ref[j].plan.as_ref().expect("planned").heads[h];
                reg.full_attention(&inp.q, &inp.k, &inp.v)
            }
        })
    };

    // Route outputs (and full-rank decisions) back to per-job slots.
    let mut outs: Vec<Vec<Option<Mat>>> = states
        .iter()
        .map(|s| {
            let n = s.plan.as_ref().map(|p| p.heads.len()).unwrap_or(0);
            (0..n).map(|_| None).collect()
        })
        .collect();
    for (task, y) in apply_tasks.iter().zip(applied) {
        let (j, h) = match *task {
            ApplyTask::Factor { lw, si } => works[lw].owner[si],
            ApplyTask::Dense { j, h } => (j, h),
        };
        match y {
            Ok(m) => outs[j][h] = Some(m),
            Err(e) => {
                if states[j].error.is_none() {
                    states[j].error = Some(format!("{e:#}"));
                }
            }
        }
        if full_rank && states[j].live() {
            let inp = &states[j].plan.as_ref().expect("planned").heads[h];
            states[j].decisions[h] =
                Some(full_rank_decision(inp.seq_len(), inp.head_dim(), projection.as_ref()));
        }
    }

    // ---- Finish: merge heads, metrics, replies. ----
    let compute_ms = sw.elapsed_ms();
    shared
        .metrics
        .record_attention_batch(co_batched as u64, n_probes, probe_dispatches, shard_locks);
    for (j, state) in states.iter().enumerate() {
        let reply = &replies[j];
        if state.reaped {
            // Boundary reap already posted the cancel/deadline error.
            continue;
        }
        if let Some(msg) = &state.error {
            crate::log_warn!("attention req {} failed: {msg}", reqs[j].id);
            reply.fulfill(Err(EngineError::new(reqs[j].id, ErrorKind::Internal, msg.clone())));
            continue;
        }
        let plan = state.plan.as_ref().expect("successful jobs are planned");
        let w = &shared.layers[reqs[j].layer];
        let mut head_outs = Vec::with_capacity(plan.heads.len());
        let mut ranks = Vec::with_capacity(plan.heads.len());
        let (mut spent, mut full) = (0u64, 0u64);
        let (mut proj_spent, mut proj_full) = (0.0f64, 0.0f64);
        for h in 0..plan.heads.len() {
            let y = outs[j][h].take().expect("apply produced every head");
            let dec = state.decisions[h].expect("decision recorded");
            shared.metrics.record_rank(dec.rank);
            if dec.masked_by_safety {
                shared.metrics.record_safety_mask();
            }
            spent += dec.flops_spent;
            full += dec.flops_full;
            proj_spent += dec.projected_ms.unwrap_or(0.0);
            proj_full += dec.projected_full_ms.unwrap_or(0.0);
            ranks.push(dec.rank);
            head_outs.push(y);
        }
        shared.metrics.record_flops(spent, full);
        let projected_ms = projection.is_some().then_some(proj_spent);
        if projection.is_some() {
            shared.metrics.record_projected(proj_spent, proj_full);
        }
        let merged = merge_heads(&head_outs, w);
        shared.metrics.record_request(state.queued_ms, compute_ms, co_batched);
        reply.fulfill(Ok(AttentionResponse {
            id: reqs[j].id,
            y: merged.into_vec(),
            ranks,
            flops_spent: spent,
            flops_full: full,
            queued_ms: state.queued_ms,
            compute_ms,
            batch_size: co_batched,
            projected_ms,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::MhsaWeights;
    use crate::coordinator::completion::{Slot, Ticket};
    use crate::coordinator::engine::PipelineHooks;
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::rank_controller::{ControllerConfig, RankController};
    use crate::coordinator::request::{AttentionResponse, ErrorKind, SubmitOptions};
    use crate::runtime::{ArtifactRegistry, Op};
    use crate::util::Pcg32;
    use std::sync::atomic::AtomicBool;
    use std::sync::Mutex;
    use std::time::Duration;

    fn shared_with_hooks(hooks: PipelineHooks) -> EngineShared {
        let reg = Arc::new(ArtifactRegistry::open_host(64, 16));
        let mut rng = Pcg32::seeded(7);
        let layers = vec![MhsaWeights::init(16, 1, &mut rng)];
        let cfg = ControllerConfig::default();
        let source = Arc::new(PolicySource::Fixed(32));
        let shards = vec![Mutex::new(RankController::with_shared_source(
            cfg.clone(),
            Arc::clone(&source),
        ))];
        let lm_params = Arc::new(vec![0f32; reg.manifest.lm.param_count]);
        EngineShared {
            reg,
            lm_params,
            layers,
            shards,
            source,
            controller_cfg: cfg,
            metrics: Arc::new(Metrics::new()),
            stopped: AtomicBool::new(false),
            hooks,
        }
    }

    fn job_and_ticket(opts: &SubmitOptions) -> (AttnJob, Ticket<AttentionResponse>) {
        let mut rng = Pcg32::seeded(11);
        let x = crate::linalg::Mat::randn(64, 16, 1.0, &mut rng);
        let slot = Slot::new(1, opts.deadline);
        let ticket = Ticket::new(Arc::clone(&slot));
        let job = AttnJob {
            arrived: Instant::now(),
            req: AttentionRequest { id: 1, x: x.into_vec(), n: 64, d_model: 16, layer: 0 },
            reply: AttnReply::new(slot),
        };
        (job, ticket)
    }

    #[test]
    fn cancel_landing_mid_probe_runs_no_apply_work() {
        // The cancel lands *after* the probe wave has already run (the
        // hook fires between the probe and decide stages) — cooperative
        // cancellation must stop the request at the boundary: no
        // decisions, no factor applies, an explicit Cancelled error.
        let mut shared = shared_with_hooks(PipelineHooks::default());
        let (job, ticket) = job_and_ticket(&SubmitOptions::default());
        let token = ticket.cancel_token();
        shared.hooks.after_probe = Some(Arc::new(move || token.cancel()));
        run_attention_batch(&shared, vec![job]);

        let err = ticket.wait().expect_err("cancelled mid-probe");
        assert_eq!(err.kind, ErrorKind::Cancelled);
        assert_eq!(shared.metrics.cancelled(), 1);
        assert_eq!(shared.metrics.probes(), 1, "the probe wave did run");
        let ops = shared.reg.ops();
        assert_eq!(ops.get(Op::LowRankAttention), 0, "no apply work after the cancel");
        assert_eq!(ops.get(Op::FullAttention), 0);
        assert_eq!(shared.metrics.requests(), 0, "no completed-request record");
    }

    #[test]
    fn deadline_expiring_mid_probe_stops_the_request() {
        let mut shared = shared_with_hooks(PipelineHooks::default());
        // Alive at drain time, dead by the post-probe boundary.
        let opts = SubmitOptions::deadline_in(Duration::from_millis(250));
        let (job, ticket) = job_and_ticket(&opts);
        shared.hooks.after_probe =
            Some(Arc::new(|| std::thread::sleep(Duration::from_millis(600))));
        run_attention_batch(&shared, vec![job]);

        let err = ticket.wait().expect_err("expired mid-probe");
        assert_eq!(err.kind, ErrorKind::DeadlineExceeded);
        assert_eq!(shared.metrics.expired(), 1);
        assert_eq!(shared.reg.ops().get(Op::LowRankAttention), 0);
    }

    #[test]
    fn live_tickets_flow_through_boundaries_untouched() {
        // The boundary checks must not disturb a live request.
        let shared = shared_with_hooks(PipelineHooks::default());
        let (job, ticket) = job_and_ticket(&SubmitOptions::default());
        run_attention_batch(&shared, vec![job]);
        let resp = ticket.wait().expect("served");
        assert_eq!(resp.ranks.len(), 1);
        assert!(shared.reg.ops().get(Op::LowRankAttention) > 0);
    }

    #[test]
    fn on_decide_observes_the_serialized_decide_order() {
        let mut shared = shared_with_hooks(PipelineHooks::default());
        let events: Arc<Mutex<Vec<DecideEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&events);
        shared.hooks.on_decide =
            Some(Arc::new(move |e| sink.lock_unpoisoned().push(e)));
        let (job, ticket) = job_and_ticket(&SubmitOptions::default());
        run_attention_batch(&shared, vec![job]);
        let resp = ticket.wait().expect("served");
        let trace = events.lock_unpoisoned();
        assert_eq!(trace.len(), 1, "one head, one decision");
        assert_eq!(trace[0].layer, 0);
        assert_eq!(trace[0].request, 1);
        assert_eq!(trace[0].step, 0);
        assert!(trace[0].fresh, "first call on a stream is a boundary");
        assert_eq!(trace[0].rank, resp.ranks[0], "event matches the response");
    }
}
