//! Tickets and completion queues — the client side of the submission
//! surface.
//!
//! `submit_*` returns a typed [`Ticket`]: the request id plus a shared
//! completion slot the engine posts into. A ticket supports non-blocking
//! [`Ticket::poll`], blocking [`Ticket::wait`] / [`Ticket::wait_timeout`]
//! (the mechanical migration from the old `mpsc::Receiver::recv` style),
//! and [`Ticket::cancel`]. Moving tickets into a [`CompletionQueue`] lets
//! one client thread drain completions for any number of in-flight
//! requests — across both request types and across every engine the
//! tickets came from — in arrival-of-completion order.
//!
//! Exactly one result is ever posted per ticket (first post wins); a
//! ticket is either waited on directly or added to a queue, never both,
//! so there is a single consumer for every completion.

use super::request::{
    AttentionResponse, EngineError, EngineResult, ErrorKind, GenerateDelta, GenerateResponse,
    RequestId,
};
use crate::util::{CondvarExt, LockExt};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A completed request of either type, as drained from a
/// [`CompletionQueue`].
#[derive(Debug)]
pub enum Completion {
    Generate(EngineResult<GenerateResponse>),
    Attention(EngineResult<AttentionResponse>),
}

impl Completion {
    /// Id of the request this completion belongs to.
    pub fn id(&self) -> RequestId {
        match self {
            Completion::Generate(Ok(r)) => r.id,
            Completion::Attention(Ok(r)) => r.id,
            Completion::Generate(Err(e)) | Completion::Attention(Err(e)) => e.id,
        }
    }

    /// True when the request completed successfully.
    pub fn is_ok(&self) -> bool {
        matches!(self, Completion::Generate(Ok(_)) | Completion::Attention(Ok(_)))
    }

    /// The error, when the request failed.
    pub fn err(&self) -> Option<&EngineError> {
        match self {
            Completion::Generate(Err(e)) | Completion::Attention(Err(e)) => Some(e),
            _ => None,
        }
    }

    /// Unwrap an attention completion (`None` for generate completions).
    pub fn into_attention(self) -> Option<EngineResult<AttentionResponse>> {
        match self {
            Completion::Attention(r) => Some(r),
            Completion::Generate(_) => None,
        }
    }

    /// Unwrap a generate completion (`None` for attention completions).
    pub fn into_generate(self) -> Option<EngineResult<GenerateResponse>> {
        match self {
            Completion::Generate(r) => Some(r),
            Completion::Attention(_) => None,
        }
    }
}

/// Response types the engine can complete a ticket with. Sealed in
/// practice: implemented for [`GenerateResponse`] and
/// [`AttentionResponse`] only.
pub trait CompletionPayload: Send + Sized + 'static {
    /// Wrap a typed result into the type-erased queue completion.
    fn into_completion(result: EngineResult<Self>) -> Completion;
}

impl CompletionPayload for GenerateResponse {
    fn into_completion(result: EngineResult<Self>) -> Completion {
        Completion::Generate(result)
    }
}

impl CompletionPayload for AttentionResponse {
    fn into_completion(result: EngineResult<Self>) -> Completion {
        Completion::Attention(result)
    }
}

// ───────────────────────── completion slot ─────────────────────────

struct SlotState<T> {
    /// The posted result, until consumed by `poll`/`wait` or forwarded
    /// into an attached queue.
    result: Option<EngineResult<T>>,
    /// A result was posted (even if already moved out). Later posts are
    /// dropped: first post wins.
    fulfilled: bool,
    /// The result was handed to a consumer (ticket method or queue).
    taken: bool,
    /// Completion queue this slot forwards into, once attached.
    queue: Option<Arc<CqShared>>,
}

/// Shared completion slot: the engine holds one end (posting), the
/// [`Ticket`] the other (consuming).
pub(crate) struct Slot<T: CompletionPayload> {
    id: RequestId,
    deadline: Option<Instant>,
    cancelled: AtomicBool,
    state: Mutex<SlotState<T>>,
    cv: Condvar,
}

impl<T: CompletionPayload> Slot<T> {
    pub(crate) fn new(id: RequestId, deadline: Option<Instant>) -> Arc<Self> {
        Arc::new(Slot {
            id,
            deadline,
            cancelled: AtomicBool::new(false),
            state: Mutex::new(SlotState {
                result: None,
                fulfilled: false,
                taken: false,
                queue: None,
            }),
            cv: Condvar::new(),
        })
    }

    /// Post the result. First post wins; later posts are dropped. If the
    /// slot is attached to a completion queue the result is forwarded
    /// there, otherwise it is parked for `poll`/`wait`.
    pub(crate) fn fulfill(&self, result: EngineResult<T>) {
        let queue = {
            let mut g = self.state.lock_unpoisoned();
            if g.fulfilled {
                return;
            }
            g.fulfilled = true;
            match g.queue.take() {
                Some(q) => {
                    g.taken = true;
                    Some(q)
                }
                None => {
                    g.result = Some(result);
                    self.cv.notify_all();
                    return;
                }
            }
        };
        // Push outside the slot lock (the queue has its own lock).
        if let Some(q) = queue {
            q.push(T::into_completion(result));
        }
    }

    /// Mark cancelled and post the `Cancelled` error (no-op if a result
    /// was already posted). The engine additionally checks the flag at
    /// drain time so cancelled work is dropped before any compute.
    fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
        self.fulfill(Err(EngineError::cancelled(self.id)));
    }

    /// If this request should not run (cancelled, or deadline passed),
    /// the error kind to report; `None` when it is live.
    pub(crate) fn reap_kind(&self, now: Instant) -> Option<ErrorKind> {
        if self.cancelled.load(Ordering::SeqCst) {
            return Some(ErrorKind::Cancelled);
        }
        match self.deadline {
            Some(d) if now >= d => Some(ErrorKind::DeadlineExceeded),
            _ => None,
        }
    }

    fn take_result(&self) -> Option<EngineResult<T>> {
        let mut g = self.state.lock_unpoisoned();
        let r = g.result.take();
        if r.is_some() {
            g.taken = true;
        }
        r
    }

    /// Post a fallback `Internal` error if nothing was posted yet — a
    /// no-op otherwise (first post wins). Reply-handle `Drop` impls call
    /// this so a panicking worker can never strand a ticket: the old
    /// mpsc receivers surfaced sender-drop as a disconnect, and this is
    /// the equivalent safety net.
    pub(crate) fn abandon(&self) {
        self.fulfill(Err(EngineError::new(
            self.id,
            ErrorKind::Internal,
            "reply handle dropped without a result",
        )));
    }

    /// Attach to a completion queue. Returns `false` when no completion
    /// will ever reach the queue (the result was already consumed).
    fn attach(&self, queue: &Arc<CqShared>) -> bool {
        let forward = {
            let mut g = self.state.lock_unpoisoned();
            if !g.fulfilled {
                g.queue = Some(Arc::clone(queue));
                return true;
            }
            match g.result.take() {
                Some(r) => {
                    g.taken = true;
                    r
                }
                None => return false, // already consumed elsewhere
            }
        };
        queue.push(T::into_completion(forward));
        true
    }
}

// ───────────────────────────── tickets ─────────────────────────────

/// Handle to one in-flight request: the request id plus its shared
/// completion slot.
///
/// Consume the result with [`Ticket::poll`] (non-blocking),
/// [`Ticket::wait`] / [`Ticket::wait_timeout`] (blocking — the drop-in
/// replacement for the old receiver's `recv`/`recv_timeout`), or move
/// the ticket into a [`CompletionQueue`] to multiplex many tickets on
/// one thread. Exactly one of these ever yields the result.
pub struct Ticket<T: CompletionPayload> {
    slot: Arc<Slot<T>>,
}

impl<T: CompletionPayload> Ticket<T> {
    pub(crate) fn new(slot: Arc<Slot<T>>) -> Self {
        Ticket { slot }
    }

    pub fn id(&self) -> RequestId {
        self.slot.id
    }

    /// The deadline this request was submitted with, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.slot.deadline
    }

    /// Non-blocking: the result if it is ready and not yet consumed.
    pub fn poll(&self) -> Option<EngineResult<T>> {
        self.slot.take_result()
    }

    /// Block until the result arrives. Equivalent to the old blocking
    /// `Receiver::recv` style: every submitted request is guaranteed a
    /// completion (success, typed error, or shutdown error), so this
    /// does not hang on engine shutdown.
    pub fn wait(self) -> EngineResult<T> {
        let mut g = self.slot.state.lock_unpoisoned();
        loop {
            if let Some(r) = g.result.take() {
                g.taken = true;
                return r;
            }
            if g.taken {
                // poll() raced the result away before this wait.
                return Err(EngineError::new(
                    self.slot.id,
                    ErrorKind::Internal,
                    "result already consumed",
                ));
            }
            g = self.slot.cv.wait_unpoisoned(g);
        }
    }

    /// Block up to `timeout` for the result; `None` if it is not ready
    /// in time (the ticket stays valid and can be waited on again).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<EngineResult<T>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.slot.state.lock_unpoisoned();
        loop {
            if let Some(r) = g.result.take() {
                g.taken = true;
                return Some(r);
            }
            if g.taken {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self.slot.cv.wait_timeout_unpoisoned(g, deadline - now);
            g = ng;
        }
    }

    /// Cancel the request. The `Cancelled` error is posted immediately
    /// (if no result arrived yet) and the engine drops the queued work
    /// at drain time, before spending any probe/SVD compute on it.
    /// Work already inside the attention pipeline is cancelled
    /// cooperatively: the engine re-checks this flag at every stage
    /// boundary (after plan, after the probe wave, before apply), so a
    /// mid-flight request stops before its next stage; only the stage
    /// currently executing runs to completion, and its late result is
    /// dropped.
    pub fn cancel(&self) {
        self.slot.cancel();
    }

    /// A cheap cloneable handle that can cancel this request after the
    /// ticket itself has been moved into a [`CompletionQueue`].
    pub fn cancel_token(&self) -> CancelToken<T> {
        CancelToken { slot: Arc::clone(&self.slot) }
    }
}

/// Cancellation handle detached from the ticket's result-consuming side.
pub struct CancelToken<T: CompletionPayload> {
    slot: Arc<Slot<T>>,
}

impl<T: CompletionPayload> Clone for CancelToken<T> {
    fn clone(&self) -> Self {
        CancelToken { slot: Arc::clone(&self.slot) }
    }
}

impl<T: CompletionPayload> CancelToken<T> {
    pub fn id(&self) -> RequestId {
        self.slot.id
    }

    pub fn cancel(&self) {
        self.slot.cancel();
    }
}

// ───────────────────────── completion queue ─────────────────────────

struct CqState {
    ready: VecDeque<Completion>,
    /// Tickets attached but not yet completed.
    outstanding: usize,
}

pub(crate) struct CqShared {
    state: Mutex<CqState>,
    cv: Condvar,
    /// Wakers of in-progress [`CompletionQueue::select`] calls watching
    /// this queue (weak: a waker dies with its select call and is pruned
    /// on the next wake pass).
    watchers: Mutex<Vec<std::sync::Weak<SelectWaker>>>,
}

impl CqShared {
    fn push(&self, c: Completion) {
        let mut g = self.state.lock_unpoisoned();
        g.ready.push_back(c);
        g.outstanding = g.outstanding.saturating_sub(1);
        drop(g);
        self.cv.notify_all();
        self.wake_watchers();
    }

    fn add_watcher(&self, w: std::sync::Weak<SelectWaker>) {
        let mut g = self.watchers.lock_unpoisoned();
        // Prune here as well as on wake: a queue that never receives a
        // push must not accumulate one dead watcher per past select call.
        g.retain(|w| w.strong_count() > 0);
        g.push(w);
    }

    fn wake_watchers(&self) {
        let mut g = self.watchers.lock_unpoisoned();
        g.retain(|w| match w.upgrade() {
            Some(waker) => {
                waker.wake();
                true
            }
            None => false,
        });
    }
}

/// Epoch-counting waker shared between one `select` call and every queue
/// it watches. The epoch is read *before* the scan and waited on after:
/// any push in between bumps it, so the wakeup cannot be missed.
#[derive(Default)]
struct SelectWaker {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl SelectWaker {
    fn epoch(&self) -> u64 {
        *self.epoch.lock_unpoisoned()
    }

    fn wake(&self) {
        *self.epoch.lock_unpoisoned() += 1;
        self.cv.notify_all();
    }

    fn wait_past(&self, seen: u64) {
        let mut g = self.epoch.lock_unpoisoned();
        while *g == seen {
            g = self.cv.wait_unpoisoned(g);
        }
    }
}

/// Multiplexes completions for any number of tickets onto one consumer
/// thread, in arrival-of-completion order.
///
/// Tickets from different engines (e.g. all replicas behind a `Router`)
/// and of different request types share one queue. [`CompletionQueue::next`]
/// blocks only while completions are still owed: once every added ticket
/// has completed and been drained it returns `None`, so drain loops
/// terminate without bookkeeping.
pub struct CompletionQueue {
    shared: Arc<CqShared>,
}

impl Default for CompletionQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl CompletionQueue {
    pub fn new() -> Self {
        CompletionQueue {
            shared: Arc::new(CqShared {
                state: Mutex::new(CqState { ready: VecDeque::new(), outstanding: 0 }),
                cv: Condvar::new(),
                watchers: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Block until *any* of `queues` yields a completion; returns the
    /// queue's index alongside it. Returns `None` once every queue is
    /// fully drained (no ready completions, nothing outstanding) — the
    /// multi-queue analogue of [`CompletionQueue::next`], letting one
    /// client thread multiplex e.g. several routers' queues without
    /// dedicating a thread per queue.
    pub fn select(queues: &[&CompletionQueue]) -> Option<(usize, Completion)> {
        let waker = Arc::new(SelectWaker::default());
        for q in queues {
            q.shared.add_watcher(Arc::downgrade(&waker));
        }
        loop {
            // Read the epoch before scanning: a completion pushed after
            // the scan started bumps it and `wait_past` returns at once.
            let seen = waker.epoch();
            let mut live = false;
            for (i, q) in queues.iter().enumerate() {
                // One lock take per queue: popping and reading the
                // outstanding count must be atomic, or a push landing
                // between the two reads could make a queue look drained
                // while a completion sits in it.
                let (ready, outstanding) = q.pop_with_outstanding();
                if let Some(c) = ready {
                    return Some((i, c));
                }
                if outstanding > 0 {
                    live = true;
                }
            }
            if !live {
                return None;
            }
            waker.wait_past(seen);
        }
    }

    /// Atomically pop the next ready completion (if any) and read the
    /// outstanding-ticket count.
    fn pop_with_outstanding(&self) -> (Option<Completion>, usize) {
        let mut g = self.shared.state.lock_unpoisoned();
        (g.ready.pop_front(), g.outstanding)
    }

    /// Move a ticket into the queue; its completion (including one that
    /// already arrived) will surface via `next`. Returns the request id,
    /// the key for matching completions back to submissions. Cancel via
    /// a [`CancelToken`] taken before the move.
    pub fn add<T: CompletionPayload>(&self, ticket: Ticket<T>) -> RequestId {
        let id = ticket.id();
        {
            let mut g = self.shared.state.lock_unpoisoned();
            g.outstanding += 1;
        }
        if !ticket.slot.attach(&self.shared) {
            // Result was already consumed through the ticket: nothing
            // will ever arrive for it. Wake consumers so a drain loop
            // blocked on the transient outstanding count re-checks.
            let mut g = self.shared.state.lock_unpoisoned();
            g.outstanding = g.outstanding.saturating_sub(1);
            drop(g);
            self.shared.cv.notify_all();
            self.shared.wake_watchers();
        }
        id
    }

    /// Completions not yet drained.
    pub fn len(&self) -> usize {
        self.shared.state.lock_unpoisoned().ready.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tickets added but not yet completed.
    pub fn outstanding(&self) -> usize {
        self.shared.state.lock_unpoisoned().outstanding
    }

    /// Non-blocking: the next completion if one is ready.
    pub fn try_next(&self) -> Option<Completion> {
        self.shared.state.lock_unpoisoned().ready.pop_front()
    }

    /// Block for the next completion. Returns `None` once every added
    /// ticket has completed and been drained (never hangs on an empty
    /// queue).
    pub fn next(&self) -> Option<Completion> {
        let mut g = self.shared.state.lock_unpoisoned();
        loop {
            if let Some(c) = g.ready.pop_front() {
                return Some(c);
            }
            if g.outstanding == 0 {
                return None;
            }
            g = self.shared.cv.wait_unpoisoned(g);
        }
    }

    /// Block up to `timeout` for the next completion; `None` on timeout
    /// or when nothing is outstanding.
    pub fn next_timeout(&self, timeout: Duration) -> Option<Completion> {
        let deadline = Instant::now() + timeout;
        let mut g = self.shared.state.lock_unpoisoned();
        loop {
            if let Some(c) = g.ready.pop_front() {
                return Some(c);
            }
            if g.outstanding == 0 {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self.shared.cv.wait_timeout_unpoisoned(g, deadline - now);
            g = ng;
        }
    }
}

// ───────────────────────────── streaming ─────────────────────────────

/// Token-delta channel backing a [`StreamingTicket`].
pub(crate) struct DeltaStream {
    state: Mutex<(VecDeque<GenerateDelta>, bool)>,
    cv: Condvar,
}

impl DeltaStream {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(DeltaStream { state: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() })
    }

    pub(crate) fn push(&self, delta: GenerateDelta) {
        let mut g = self.state.lock_unpoisoned();
        if g.1 {
            return; // closed: late deltas are dropped
        }
        g.0.push_back(delta);
        drop(g);
        self.cv.notify_all();
    }

    /// Close the stream (the final result was posted). Pending deltas
    /// stay drainable.
    pub(crate) fn close(&self) {
        self.state.lock_unpoisoned().1 = true;
        self.cv.notify_all();
    }

    fn next(&self) -> Option<GenerateDelta> {
        let mut g = self.state.lock_unpoisoned();
        loop {
            if let Some(d) = g.0.pop_front() {
                return Some(d);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait_unpoisoned(g);
        }
    }

    fn try_next(&self) -> Option<GenerateDelta> {
        self.state.lock_unpoisoned().0.pop_front()
    }
}

/// A generation ticket that additionally surfaces per-token deltas as
/// the decode steps that produce them complete — ahead of the final
/// [`GenerateResponse`].
pub struct StreamingTicket {
    ticket: Ticket<GenerateResponse>,
    stream: Arc<DeltaStream>,
}

impl StreamingTicket {
    pub(crate) fn new(ticket: Ticket<GenerateResponse>, stream: Arc<DeltaStream>) -> Self {
        StreamingTicket { ticket, stream }
    }

    pub fn id(&self) -> RequestId {
        self.ticket.id()
    }

    /// Block for the next token delta. `None` once generation finished
    /// (or failed — inspect the final result via [`StreamingTicket::finish`])
    /// and all deltas are drained.
    pub fn next_delta(&self) -> Option<GenerateDelta> {
        self.stream.next()
    }

    /// Non-blocking delta poll.
    pub fn try_next_delta(&self) -> Option<GenerateDelta> {
        self.stream.try_next()
    }

    /// Cancel the request (see [`Ticket::cancel`]).
    pub fn cancel(&self) {
        self.ticket.cancel();
    }

    /// Block for the final response (undelivered deltas are dropped).
    pub fn finish(self) -> EngineResult<GenerateResponse> {
        self.ticket.wait()
    }

    /// Downgrade to a plain ticket (e.g. to move it into a
    /// [`CompletionQueue`]); the delta stream is detached and dropped.
    pub fn into_ticket(self) -> Ticket<GenerateResponse> {
        self.ticket
    }
}

// ───────────────────── engine-side reply handles ─────────────────────

/// Engine-side posting handle for an attention request. Dropping it
/// without posting (worker panic, dropped queue) posts an `Internal`
/// error, so tickets and completion queues can never hang.
pub(crate) struct AttnReply(Arc<Slot<AttentionResponse>>);

impl AttnReply {
    pub(crate) fn new(slot: Arc<Slot<AttentionResponse>>) -> Self {
        AttnReply(slot)
    }
}

impl std::ops::Deref for AttnReply {
    type Target = Slot<AttentionResponse>;

    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

impl Drop for AttnReply {
    fn drop(&mut self) {
        self.0.abandon();
    }
}

/// Engine-side posting handle for a generation request: the completion
/// slot plus the optional delta stream of a streaming ticket. `post`
/// closes the stream so `next_delta` loops terminate on every path
/// (success, error, cancel, shutdown) — and `Drop` backstops both the
/// slot and the stream against a worker that never posted.
pub(crate) struct GenReply {
    pub(crate) slot: Arc<Slot<GenerateResponse>>,
    pub(crate) stream: Option<Arc<DeltaStream>>,
}

impl GenReply {
    pub(crate) fn post(&self, result: EngineResult<GenerateResponse>) {
        self.slot.fulfill(result);
        if let Some(s) = &self.stream {
            s.close();
        }
    }

    pub(crate) fn push_delta(&self, delta: GenerateDelta) {
        if let Some(s) = &self.stream {
            s.push(delta);
        }
    }
}

impl Drop for GenReply {
    fn drop(&mut self) {
        self.slot.abandon();
        if let Some(s) = &self.stream {
            s.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attn_ok(id: RequestId) -> EngineResult<AttentionResponse> {
        Ok(AttentionResponse {
            id,
            y: vec![1.0, 2.0],
            ranks: vec![4],
            flops_spent: 1,
            flops_full: 2,
            queued_ms: 0.0,
            compute_ms: 0.0,
            batch_size: 1,
            projected_ms: None,
        })
    }

    #[test]
    fn poll_then_fulfill_then_poll() {
        let slot = Slot::<AttentionResponse>::new(7, None);
        let ticket = Ticket::new(Arc::clone(&slot));
        assert!(ticket.poll().is_none());
        slot.fulfill(attn_ok(7));
        let r = ticket.poll().expect("ready").expect("ok");
        assert_eq!(r.id, 7);
        // Consumed: subsequent polls see nothing.
        assert!(ticket.poll().is_none());
    }

    #[test]
    fn wait_blocks_until_fulfilled() {
        let slot = Slot::<AttentionResponse>::new(1, None);
        let ticket = Ticket::new(Arc::clone(&slot));
        let poster = {
            let slot = Arc::clone(&slot);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                slot.fulfill(attn_ok(1));
            })
        };
        let r = ticket.wait().expect("ok");
        assert_eq!(r.id, 1);
        poster.join().unwrap();
    }

    #[test]
    fn wait_timeout_returns_none_then_result() {
        let slot = Slot::<AttentionResponse>::new(2, None);
        let ticket = Ticket::new(Arc::clone(&slot));
        assert!(ticket.wait_timeout(Duration::from_millis(10)).is_none());
        slot.fulfill(attn_ok(2));
        assert!(ticket.wait_timeout(Duration::from_millis(10)).is_some());
    }

    #[test]
    fn first_post_wins() {
        let slot = Slot::<AttentionResponse>::new(3, None);
        let ticket = Ticket::new(Arc::clone(&slot));
        slot.fulfill(attn_ok(3));
        slot.fulfill(Err(EngineError::new(3, ErrorKind::Internal, "late")));
        assert!(ticket.wait().is_ok(), "late error must not replace the result");
    }

    #[test]
    fn cancel_posts_cancelled_error_and_sets_flag() {
        let slot = Slot::<AttentionResponse>::new(4, None);
        let ticket = Ticket::new(Arc::clone(&slot));
        let token = ticket.cancel_token();
        token.cancel();
        assert_eq!(slot.reap_kind(Instant::now()), Some(ErrorKind::Cancelled));
        let err = ticket.wait().expect_err("cancelled");
        assert_eq!(err.kind, ErrorKind::Cancelled);
    }

    #[test]
    fn deadline_reaps_after_expiry() {
        let deadline = Instant::now() + Duration::from_millis(5);
        let slot = Slot::<AttentionResponse>::new(5, Some(deadline));
        assert_eq!(slot.reap_kind(Instant::now()), None);
        assert_eq!(
            slot.reap_kind(deadline + Duration::from_millis(1)),
            Some(ErrorKind::DeadlineExceeded)
        );
    }

    #[test]
    fn dropped_reply_handle_posts_internal_error() {
        // A worker that panics (or a queue torn down with work still in
        // it) drops the reply handle without posting — the ticket must
        // resolve with an Internal error instead of hanging.
        let slot = Slot::<AttentionResponse>::new(20, None);
        let ticket = Ticket::new(Arc::clone(&slot));
        drop(AttnReply::new(slot));
        let err = ticket.wait().expect_err("abandoned ticket must error");
        assert_eq!(err.kind, ErrorKind::Internal);
    }

    #[test]
    fn queue_drains_in_completion_order_and_terminates() {
        let cq = CompletionQueue::new();
        let slot_a = Slot::<AttentionResponse>::new(10, None);
        let slot_b = Slot::<AttentionResponse>::new(11, None);
        cq.add(Ticket::new(Arc::clone(&slot_a)));
        cq.add(Ticket::new(Arc::clone(&slot_b)));
        assert_eq!(cq.outstanding(), 2);
        // b completes first: completion order, not submission order.
        slot_b.fulfill(attn_ok(11));
        slot_a.fulfill(attn_ok(10));
        assert_eq!(cq.next().expect("first").id(), 11);
        assert_eq!(cq.next().expect("second").id(), 10);
        assert!(cq.next().is_none(), "drained queue must terminate");
    }

    #[test]
    fn queue_add_after_completion_still_delivers() {
        let cq = CompletionQueue::new();
        let slot = Slot::<AttentionResponse>::new(12, None);
        slot.fulfill(attn_ok(12));
        cq.add(Ticket::new(Arc::clone(&slot)));
        assert_eq!(cq.next().expect("delivered").id(), 12);
        assert!(cq.next().is_none());
    }

    #[test]
    fn queue_next_timeout_times_out() {
        let cq = CompletionQueue::new();
        let slot = Slot::<AttentionResponse>::new(13, None);
        cq.add(Ticket::new(Arc::clone(&slot)));
        assert!(cq.next_timeout(Duration::from_millis(10)).is_none());
        slot.fulfill(attn_ok(13));
        assert!(cq.next_timeout(Duration::from_millis(100)).is_some());
    }

    #[test]
    fn select_returns_ready_queue_and_terminates_when_all_drained() {
        let a = CompletionQueue::new();
        let b = CompletionQueue::new();
        let slot_a = Slot::<AttentionResponse>::new(30, None);
        let slot_b = Slot::<AttentionResponse>::new(31, None);
        a.add(Ticket::new(Arc::clone(&slot_a)));
        b.add(Ticket::new(Arc::clone(&slot_b)));
        // b completes first: select must surface queue index 1.
        slot_b.fulfill(attn_ok(31));
        let (qi, c) = CompletionQueue::select(&[&a, &b]).expect("one ready");
        assert_eq!((qi, c.id()), (1, 31));
        slot_a.fulfill(attn_ok(30));
        let (qi, c) = CompletionQueue::select(&[&a, &b]).expect("second ready");
        assert_eq!((qi, c.id()), (0, 30));
        assert!(CompletionQueue::select(&[&a, &b]).is_none(), "drained select terminates");
    }

    #[test]
    fn select_blocks_until_a_late_completion_arrives() {
        let a = CompletionQueue::new();
        let b = CompletionQueue::new();
        let slot = Slot::<AttentionResponse>::new(40, None);
        b.add(Ticket::new(Arc::clone(&slot)));
        let poster = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            slot.fulfill(attn_ok(40));
        });
        let (qi, c) = CompletionQueue::select(&[&a, &b]).expect("late completion");
        assert_eq!((qi, c.id()), (1, 40));
        poster.join().unwrap();
        assert!(CompletionQueue::select(&[&a, &b]).is_none());
    }

    #[test]
    fn select_on_empty_queues_returns_none_immediately() {
        let a = CompletionQueue::new();
        let b = CompletionQueue::new();
        assert!(CompletionQueue::select(&[&a, &b]).is_none());
        assert!(CompletionQueue::select(&[]).is_none());
    }

    #[test]
    fn delta_stream_drains_then_closes() {
        let s = DeltaStream::new();
        s.push(GenerateDelta { id: 1, index: 0, token: 42 });
        s.push(GenerateDelta { id: 1, index: 1, token: 43 });
        s.close();
        assert_eq!(s.next().expect("first").token, 42);
        assert_eq!(s.next().expect("second").token, 43);
        assert!(s.next().is_none());
    }
}
