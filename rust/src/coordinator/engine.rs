//! The serving engine: N worker threads drain the shared dynamic batcher
//! and execute batched LM generation plus DR-RL adaptive attention
//! segments against the artifact registry.
//!
//! Sharding model: rank-controller state is sharded **per layer** (one
//! `Mutex<RankController>` per layer, all sharing one `PolicySource`), so
//! same-layer decisions stay coherent and serialized while requests to
//! different layers — and the generate path — proceed in parallel.
//! Within one attention request the per-head probe/SVD and factor-apply
//! dispatches fan out over the global thread pool (see
//! `RankController::attention_heads_batched`), so a multi-head segment
//! costs roughly one head of wall-clock.

use super::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use super::metrics::Metrics;
use super::rank_controller::{ControllerConfig, PolicySource, RankController};
use super::request::*;
use crate::attention::{merge_heads, project_heads, AttnInputs, MhsaWeights};
use crate::linalg::Mat;
use crate::runtime::ArtifactRegistry;
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

enum Work {
    Generate(GenerateRequest, Sender<EngineResult<GenerateResponse>>),
    Attention(AttentionRequest, Sender<EngineResult<AttentionResponse>>),
}

/// Engine tuning knobs beyond the batching policy.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads draining the batcher. ≥ 2 by default so attention
    /// segments and generation batches overlap.
    pub n_workers: usize,
    pub batch_policy: BatchPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { n_workers: 2, batch_policy: BatchPolicy::default() }
    }
}

/// Shared state every worker operates on.
struct EngineShared {
    reg: Arc<ArtifactRegistry>,
    lm_params: Arc<Vec<f32>>,
    layers: Vec<MhsaWeights>,
    /// One controller shard per layer; index = layer.
    shards: Vec<Mutex<RankController>>,
    metrics: Arc<Metrics>,
    /// Prompt-shutdown flag: once set, workers stop computing queued
    /// work and reply with explicit errors instead.
    stopped: AtomicBool,
}

/// Engine handle. Submit from any thread.
pub struct ServingEngine {
    batcher: Arc<DynamicBatcher<Work>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shared: Arc<EngineShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServingEngine {
    /// Start an engine with the default worker count (N = 2). The engine
    /// owns a frozen attention layer stack (for the adaptive-attention
    /// service) and the trained LM params (for generation).
    pub fn start(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        batch_policy: BatchPolicy,
    ) -> ServingEngine {
        Self::start_with_config(
            reg,
            lm_params,
            layers,
            controller_cfg,
            source,
            EngineConfig { batch_policy, ..EngineConfig::default() },
        )
    }

    /// Start an engine with an explicit worker count.
    pub fn start_with_config(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        config: EngineConfig,
    ) -> ServingEngine {
        let batcher = Arc::new(DynamicBatcher::new(config.batch_policy));
        let metrics = Arc::new(Metrics::new());
        let source = Arc::new(source);
        let shards: Vec<Mutex<RankController>> = (0..layers.len().max(1))
            .map(|_| {
                Mutex::new(RankController::with_shared_source(
                    controller_cfg.clone(),
                    Arc::clone(&source),
                ))
            })
            .collect();
        let shared = Arc::new(EngineShared {
            reg,
            lm_params,
            layers,
            shards,
            metrics: Arc::clone(&metrics),
            stopped: AtomicBool::new(false),
        });
        let n_workers = config.n_workers.max(1);
        let workers = (0..n_workers)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("drrl-engine-{i}"))
                    .spawn(move || worker_loop(&shared, &batcher))
                    .expect("spawn engine worker")
            })
            .collect();
        ServingEngine {
            batcher,
            metrics,
            next_id: AtomicU64::new(1),
            shared,
            workers,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn submit(&self, work: Work) -> Result<(), SubmitError> {
        let r = self.batcher.submit(work);
        if r.is_err() {
            self.metrics.record_rejection();
        }
        r
    }

    /// Queue a generation request; returns (id, receiver).
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(RequestId, std::sync::mpsc::Receiver<EngineResult<GenerateResponse>>), SubmitError>
    {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Work::Generate(GenerateRequest { id, prompt, max_new_tokens }, tx))?;
        Ok((id, rx))
    }

    /// Queue an adaptive-attention segment; returns (id, receiver).
    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<(RequestId, std::sync::mpsc::Receiver<EngineResult<AttentionResponse>>), SubmitError>
    {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Work::Attention(AttentionRequest { id, x, n, d_model, layer }, tx))?;
        Ok((id, rx))
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }

    /// Prompt shutdown: stop computing queued work (remaining requests
    /// get explicit `EngineError` replies), then join the workers.
    /// In-flight work finishes normally.
    pub fn shutdown(mut self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        // Graceful: drain the queue fully, then join.
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &EngineShared, batcher: &DynamicBatcher<Work>) {
    while let Some(batch) = batcher.next_batch() {
        if shared.stopped.load(Ordering::SeqCst) {
            // Prompt shutdown: reply Closed-style errors instead of
            // computing (the batcher is already closed to submitters).
            for p in batch {
                match p.inner {
                    Work::Generate(req, tx) => {
                        let _ = tx.send(Err(EngineError {
                            id: req.id,
                            message: "engine stopped before request ran".into(),
                        }));
                    }
                    Work::Attention(req, tx) => {
                        let _ = tx.send(Err(EngineError {
                            id: req.id,
                            message: "engine stopped before request ran".into(),
                        }));
                    }
                }
            }
            continue;
        }
        let batch_size = batch.len();
        // Split by type, preserving arrival envelopes.
        let mut gens: Vec<(Pending<()>, GenerateRequest, Sender<EngineResult<GenerateResponse>>)> =
            Vec::new();
        let mut attns = Vec::new();
        for p in batch {
            let arrived = p.arrived;
            match p.inner {
                Work::Generate(req, tx) => {
                    gens.push((Pending { inner: (), arrived }, req, tx))
                }
                Work::Attention(req, tx) => attns.push((arrived, req, tx)),
            }
        }
        if !gens.is_empty() {
            // serve_generate_batch replies to every request itself (Ok per
            // chunk, or explicit errors for the failing chunk onward).
            if let Err(e) = serve_generate_batch(shared, &mut gens, batch_size) {
                crate::log_warn!("generate batch failed: {e:#}");
            }
        }
        for (arrived, req, tx) in attns {
            let queued_ms = arrived.elapsed().as_secs_f64() * 1e3;
            match serve_attention(shared, &req) {
                Ok(mut resp) => {
                    resp.queued_ms = queued_ms;
                    let _ = tx.send(Ok(resp));
                }
                Err(e) => {
                    crate::log_warn!("attention req {} failed: {e:#}", req.id);
                    let _ = tx.send(Err(EngineError {
                        id: req.id,
                        message: format!("{e:#}"),
                    }));
                }
            }
        }
    }
}

/// Batched greedy generation over the whole drained batch. Every request
/// receives exactly one reply: `Ok` when its chunk completes, or an
/// explicit `EngineError` for the failing chunk and all chunks after it
/// (already-replied chunks are left alone).
fn serve_generate_batch(
    shared: &EngineShared,
    gens: &mut [(Pending<()>, GenerateRequest, Sender<EngineResult<GenerateResponse>>)],
    batch_size: usize,
) -> Result<()> {
    let chunk_size = shared.reg.manifest.lm.batch.max(1);
    let n = gens.len();
    for lo in (0..n).step_by(chunk_size) {
        let hi = (lo + chunk_size).min(n);
        if let Err(e) = serve_generate_chunk(shared, &mut gens[lo..hi], batch_size) {
            for (_, req, tx) in &gens[lo..] {
                let _ = tx.send(Err(EngineError {
                    id: req.id,
                    message: format!("generate batch failed: {e:#}"),
                }));
            }
            return Err(e);
        }
    }
    Ok(())
}

/// One chunk (≤ the artifact batch dim) of greedy generation: packs the
/// prompts into the fixed-shape logits artifact and decodes all rows in
/// lock-step.
fn serve_generate_chunk(
    shared: &EngineShared,
    chunk: &mut [(Pending<()>, GenerateRequest, Sender<EngineResult<GenerateResponse>>)],
    batch_size: usize,
) -> Result<()> {
    let reg = &shared.reg;
    let lm = &reg.manifest.lm;
    // The stopwatch is scoped per chunk so later chunks don't report the
    // cumulative elapsed time (which used to inflate compute_ms and the
    // latency histograms).
    {
        let sw = Stopwatch::start();
        let max_steps = chunk.iter().map(|(_, r, _)| r.max_new_tokens).max().unwrap_or(0);
        let mut contexts: Vec<Vec<i32>> =
            chunk.iter().map(|(_, r, _)| r.prompt.clone()).collect();
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); chunk.len()];
        for _step in 0..max_steps {
            let mut tokens = vec![b' ' as i32; lm.batch * lm.seq_len];
            for (row, ctx) in contexts.iter().enumerate() {
                let take = ctx.len().min(lm.seq_len);
                let dst = row * lm.seq_len + (lm.seq_len - take);
                tokens[dst..dst + take].copy_from_slice(&ctx[ctx.len() - take..]);
            }
            let logits = reg.lm_logits(&shared.lm_params, &tokens)?;
            for (row, ctx) in contexts.iter_mut().enumerate() {
                if outputs[row].len() >= chunk[row].1.max_new_tokens {
                    continue;
                }
                let off = (row * lm.seq_len + lm.seq_len - 1) * lm.vocab;
                let next = logits[off..off + lm.vocab]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap();
                ctx.push(next);
                outputs[row].push(next);
            }
        }
        let compute_ms = sw.elapsed_ms();
        for (i, (pend, req, tx)) in chunk.iter_mut().enumerate() {
            let queued_ms = pend.queued_ms();
            shared.metrics.record_request(queued_ms, compute_ms, batch_size);
            let _ = tx.send(Ok(GenerateResponse {
                id: req.id,
                tokens: std::mem::take(&mut outputs[i]),
                queued_ms,
                compute_ms,
                batch_size,
            }));
        }
    }
    Ok(())
}

/// One adaptive-attention segment: project heads, then run the batched
/// controller step for the request's layer shard.
fn serve_attention(shared: &EngineShared, req: &AttentionRequest) -> Result<AttentionResponse> {
    let sw = Stopwatch::start();
    anyhow::ensure!(req.layer < shared.layers.len(), "layer {} out of range", req.layer);
    let w = &shared.layers[req.layer];
    anyhow::ensure!(req.d_model == w.d_model(), "d_model mismatch");
    let x = Mat::from_vec(req.n, req.d_model, req.x.clone());
    // Projection is stateless — run it outside the shard lock.
    let heads = project_heads(&x, w, true);
    let head_refs: Vec<(usize, &AttnInputs)> = heads.iter().enumerate().collect();
    let served = {
        let mut controller = shared.shards[req.layer].lock().unwrap();
        controller.attention_heads_batched(
            &shared.reg,
            &x,
            w,
            &head_refs,
            req.layer,
            shared.layers.len(),
        )?
    };
    let mut outs = Vec::with_capacity(served.len());
    let mut ranks = Vec::with_capacity(served.len());
    let mut spent = 0u64;
    let mut full = 0u64;
    for (y, dec) in served {
        shared.metrics.record_rank(dec.rank);
        if dec.masked_by_safety {
            shared.metrics.record_safety_mask();
        }
        spent += dec.flops_spent;
        full += dec.flops_full;
        ranks.push(dec.rank);
        outs.push(y);
    }
    shared.metrics.record_flops(spent, full);
    let merged = merge_heads(&outs, w);
    let compute_ms = sw.elapsed_ms();
    shared.metrics.record_request(0.0, compute_ms, 1);
    Ok(AttentionResponse {
        id: req.id,
        y: merged.into_vec(),
        ranks,
        flops_spent: spent,
        flops_full: full,
        queued_ms: 0.0,
        compute_ms,
    })
}

#[cfg(test)]
mod tests {
    // Engine integration tests live in rust/tests/serving.rs (artifact-
    // backed) and rust/tests/engine_concurrency.rs (host-backed, no
    // artifacts needed); unit coverage of batching/metrics lives in their
    // own modules.
}
