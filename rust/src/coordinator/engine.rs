//! The serving engine: N worker threads drain the shared dynamic batcher
//! and execute batched LM generation plus DR-RL adaptive attention
//! segments against the artifact registry.
//!
//! ## Execution model
//!
//! Generation requests pack into fixed-shape logits chunks
//! (`serve_generate_batch`). Attention requests run through the staged
//! cross-request pipeline (`pipeline::run_attention_batch`):
//! **plan** (validate + project heads, lock-free, pooled) →
//! **probe** (one global SVD wave for every refreshing head of every
//! co-batched request across all layers) →
//! **decide** (each touched layer's shard lock taken once per drained
//! batch; decisions replay serially in request-arrival, head order) →
//! **apply** (one pooled wave of masked factor applies). A drained
//! batch therefore costs O(layers-touched) lock round-trips and SVD
//! dispatches instead of O(requests).
//!
//! ## Sharding and the decision-ordering invariant
//!
//! Rank-controller state is sharded **per layer** (one
//! `Mutex<RankController>` per layer, all sharing one `PolicySource`),
//! so same-layer decisions stay coherent and serialized while requests
//! to different layers — and the generate path — proceed in parallel.
//! Within a drained batch the pipeline replays each layer's decisions in
//! the order the requests arrived, which makes its results bit-identical
//! to submitting the same requests one at a time to a single-worker
//! engine (see `rust/tests/engine_concurrency.rs`).

use super::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use super::metrics::Metrics;
use super::pipeline::{self, AttnJob};
use super::rank_controller::{ControllerConfig, PolicySource, RankController};
use super::request::*;
use crate::attention::MhsaWeights;
use crate::runtime::ArtifactRegistry;
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

enum Work {
    Generate(GenerateRequest, Sender<EngineResult<GenerateResponse>>),
    Attention(AttentionRequest, Sender<EngineResult<AttentionResponse>>),
}

/// A generation request mid-flight: arrival envelope, request, reply.
type GenJob = (Pending<()>, GenerateRequest, Sender<EngineResult<GenerateResponse>>);

/// Engine tuning knobs beyond the batching policy.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads draining the batcher. ≥ 2 by default so attention
    /// segments and generation batches overlap.
    pub n_workers: usize,
    pub batch_policy: BatchPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { n_workers: 2, batch_policy: BatchPolicy::default() }
    }
}

/// Shared state every worker operates on.
pub(crate) struct EngineShared {
    pub(crate) reg: Arc<ArtifactRegistry>,
    pub(crate) lm_params: Arc<Vec<f32>>,
    pub(crate) layers: Vec<MhsaWeights>,
    /// One controller shard per layer; index = layer.
    pub(crate) shards: Vec<Mutex<RankController>>,
    /// The shared policy source (also held by every shard); the pipeline
    /// reads it to short-circuit the full-rank dense path.
    pub(crate) source: Arc<PolicySource>,
    /// Controller config the shards were built with (the pipeline needs
    /// the rank grid to size the probe bucket).
    pub(crate) controller_cfg: ControllerConfig,
    pub(crate) metrics: Arc<Metrics>,
    /// Prompt-shutdown flag: once set, workers stop computing queued
    /// work and reply with explicit errors instead.
    pub(crate) stopped: AtomicBool,
}

/// Engine handle. Submit from any thread.
pub struct ServingEngine {
    batcher: Arc<DynamicBatcher<Work>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shared: Arc<EngineShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServingEngine {
    /// Start an engine with the default worker count (N = 2). The engine
    /// owns a frozen attention layer stack (for the adaptive-attention
    /// service) and the trained LM params (for generation).
    pub fn start(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        batch_policy: BatchPolicy,
    ) -> ServingEngine {
        Self::start_with_config(
            reg,
            lm_params,
            layers,
            controller_cfg,
            source,
            EngineConfig { batch_policy, ..EngineConfig::default() },
        )
    }

    /// Start an engine with an explicit worker count.
    pub fn start_with_config(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        config: EngineConfig,
    ) -> ServingEngine {
        let batcher = Arc::new(DynamicBatcher::new(config.batch_policy));
        let metrics = Arc::new(Metrics::new());
        let source = Arc::new(source);
        let shards: Vec<Mutex<RankController>> = (0..layers.len().max(1))
            .map(|_| {
                Mutex::new(RankController::with_shared_source(
                    controller_cfg.clone(),
                    Arc::clone(&source),
                ))
            })
            .collect();
        let shared = Arc::new(EngineShared {
            reg,
            lm_params,
            layers,
            shards,
            source,
            controller_cfg,
            metrics: Arc::clone(&metrics),
            stopped: AtomicBool::new(false),
        });
        let n_workers = config.n_workers.max(1);
        let workers = (0..n_workers)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("drrl-engine-{i}"))
                    .spawn(move || worker_loop(&shared, &batcher))
                    .expect("spawn engine worker")
            })
            .collect();
        ServingEngine {
            batcher,
            metrics,
            next_id: AtomicU64::new(1),
            shared,
            workers,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn submit(&self, work: Work) -> Result<(), SubmitError> {
        let r = self.batcher.submit(work);
        if r.is_err() {
            self.metrics.record_rejection();
        }
        r
    }

    /// Queue a generation request; returns (id, receiver).
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(RequestId, ResponseReceiver<GenerateResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Work::Generate(GenerateRequest { id, prompt, max_new_tokens }, tx))?;
        Ok((id, rx))
    }

    /// Queue an adaptive-attention segment; returns (id, receiver).
    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<(RequestId, ResponseReceiver<AttentionResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Work::Attention(AttentionRequest { id, x, n, d_model, layer }, tx))?;
        Ok((id, rx))
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }

    /// Prompt shutdown: stop computing queued work (remaining requests
    /// get explicit `EngineError` replies), then join the workers.
    /// In-flight work finishes normally.
    pub fn shutdown(mut self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        // Graceful: drain the queue fully, then join.
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &EngineShared, batcher: &DynamicBatcher<Work>) {
    while let Some(batch) = batcher.next_batch() {
        if shared.stopped.load(Ordering::SeqCst) {
            // Prompt shutdown: reply Closed-style errors instead of
            // computing (the batcher is already closed to submitters).
            for p in batch {
                match p.inner {
                    Work::Generate(req, tx) => {
                        let _ = tx.send(Err(EngineError {
                            id: req.id,
                            message: "engine stopped before request ran".into(),
                        }));
                    }
                    Work::Attention(req, tx) => {
                        let _ = tx.send(Err(EngineError {
                            id: req.id,
                            message: "engine stopped before request ran".into(),
                        }));
                    }
                }
            }
            continue;
        }
        // Regroup the drained batch by type, preserving the arrival
        // envelopes and FIFO order (the pipeline's replay order).
        let mut gens: Vec<GenJob> = Vec::new();
        let mut attns: Vec<AttnJob> = Vec::new();
        for p in batch {
            let arrived = p.arrived;
            match p.inner {
                Work::Generate(req, tx) => {
                    gens.push((Pending { inner: (), arrived }, req, tx))
                }
                Work::Attention(req, tx) => attns.push(AttnJob { arrived, req, tx }),
            }
        }
        if !gens.is_empty() {
            // serve_generate_batch replies to every request itself (Ok per
            // chunk, or explicit errors for the failing chunk onward).
            // batch_size counts co-batched *generation* requests, matching
            // the attention pipeline's same-type co-batch convention.
            let gen_count = gens.len();
            if let Err(e) = serve_generate_batch(shared, &mut gens, gen_count) {
                crate::log_warn!("generate batch failed: {e:#}");
            }
        }
        // The staged cross-request pipeline replies to every attention
        // job itself.
        pipeline::run_attention_batch(shared, attns);
    }
}

/// Batched greedy generation over the whole drained batch. Every request
/// receives exactly one reply: `Ok` when its chunk completes, or an
/// explicit `EngineError` for the failing chunk and all chunks after it
/// (already-replied chunks are left alone).
fn serve_generate_batch(
    shared: &EngineShared,
    gens: &mut [GenJob],
    batch_size: usize,
) -> Result<()> {
    let chunk_size = shared.reg.manifest.lm.batch.max(1);
    let n = gens.len();
    for lo in (0..n).step_by(chunk_size) {
        let hi = (lo + chunk_size).min(n);
        if let Err(e) = serve_generate_chunk(shared, &mut gens[lo..hi], batch_size) {
            for (_, req, tx) in &gens[lo..] {
                let _ = tx.send(Err(EngineError {
                    id: req.id,
                    message: format!("generate batch failed: {e:#}"),
                }));
            }
            return Err(e);
        }
    }
    Ok(())
}

/// One chunk (≤ the artifact batch dim) of greedy generation: packs the
/// prompts into the fixed-shape logits artifact and decodes all rows in
/// lock-step.
fn serve_generate_chunk(
    shared: &EngineShared,
    chunk: &mut [GenJob],
    batch_size: usize,
) -> Result<()> {
    let reg = &shared.reg;
    let lm = &reg.manifest.lm;
    // The stopwatch covers exactly one chunk (the caller loops over
    // chunks), so compute_ms and the latency histograms never accumulate
    // cross-chunk time.
    let sw = Stopwatch::start();
    let max_steps = chunk.iter().map(|(_, r, _)| r.max_new_tokens).max().unwrap_or(0);
    let mut contexts: Vec<Vec<i32>> =
        chunk.iter().map(|(_, r, _)| r.prompt.clone()).collect();
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); chunk.len()];
    for _step in 0..max_steps {
        let mut tokens = vec![b' ' as i32; lm.batch * lm.seq_len];
        for (row, ctx) in contexts.iter().enumerate() {
            let take = ctx.len().min(lm.seq_len);
            let dst = row * lm.seq_len + (lm.seq_len - take);
            tokens[dst..dst + take].copy_from_slice(&ctx[ctx.len() - take..]);
        }
        let logits = reg.lm_logits(&shared.lm_params, &tokens)?;
        for (row, ctx) in contexts.iter_mut().enumerate() {
            if outputs[row].len() >= chunk[row].1.max_new_tokens {
                continue;
            }
            let off = (row * lm.seq_len + lm.seq_len - 1) * lm.vocab;
            let next = logits[off..off + lm.vocab]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap();
            ctx.push(next);
            outputs[row].push(next);
        }
    }
    let compute_ms = sw.elapsed_ms();
    for (i, (pend, req, tx)) in chunk.iter_mut().enumerate() {
        let queued_ms = pend.queued_ms();
        shared.metrics.record_request(queued_ms, compute_ms, batch_size);
        let _ = tx.send(Ok(GenerateResponse {
            id: req.id,
            tokens: std::mem::take(&mut outputs[i]),
            queued_ms,
            compute_ms,
            batch_size,
        }));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    // Engine integration tests live in rust/tests/serving.rs (artifact-
    // backed) and rust/tests/engine_concurrency.rs (host-backed, no
    // artifacts needed — including the cross-request pipeline equality
    // tests); unit coverage of batching/metrics lives in their own
    // modules.
}
