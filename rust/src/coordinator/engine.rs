//! The serving engine: a worker thread that drains the dynamic batcher
//! and executes batched LM generation plus DR-RL adaptive attention
//! segments against the AOT artifacts.

use super::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use super::metrics::Metrics;
use super::rank_controller::{ControllerConfig, PolicySource, RankController};
use super::request::*;
use crate::attention::{project_heads, MhsaWeights};
use crate::linalg::Mat;
use crate::runtime::ArtifactRegistry;
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

enum Work {
    Generate(GenerateRequest, Sender<GenerateResponse>),
    Attention(AttentionRequest, Sender<AttentionResponse>),
}

/// Engine handle. Cloneable; submit from any thread.
pub struct ServingEngine {
    batcher: Arc<DynamicBatcher<Work>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    stopped: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ServingEngine {
    /// Start an engine over an artifact registry. The engine owns a
    /// frozen attention layer stack (for the adaptive-attention service)
    /// and the trained LM params (for generation), both supplied here.
    pub fn start(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        batch_policy: BatchPolicy,
    ) -> ServingEngine {
        let batcher = Arc::new(DynamicBatcher::new(batch_policy));
        let metrics = Arc::new(Metrics::new());
        let stopped = Arc::new(AtomicBool::new(false));
        let worker = {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("drrl-engine".into())
                .spawn(move || {
                    let mut controller = RankController::new(controller_cfg, source);
                    worker_loop(&reg, &lm_params, &layers, &mut controller, &batcher, &metrics);
                })
                .expect("spawn engine worker")
        };
        ServingEngine {
            batcher,
            metrics,
            next_id: AtomicU64::new(1),
            stopped,
            worker: Some(worker),
        }
    }

    fn submit(&self, work: Work) -> Result<(), SubmitError> {
        let r = self.batcher.submit(work);
        if r.is_err() {
            self.metrics.record_rejection();
        }
        r
    }

    /// Queue a generation request; returns (id, receiver).
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<(RequestId, std::sync::mpsc::Receiver<GenerateResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Work::Generate(GenerateRequest { id, prompt, max_new_tokens }, tx))?;
        Ok((id, rx))
    }

    /// Queue an adaptive-attention segment; returns (id, receiver).
    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<(RequestId, std::sync::mpsc::Receiver<AttentionResponse>), SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Work::Attention(AttentionRequest { id, x, n, d_model, layer }, tx))?;
        Ok((id, rx))
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }

    /// Graceful shutdown: drain, then join the worker.
    pub fn shutdown(mut self) {
        self.stopped.store(true, Ordering::Relaxed);
        self.batcher.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    reg: &ArtifactRegistry,
    lm_params: &[f32],
    layers: &[MhsaWeights],
    controller: &mut RankController,
    batcher: &DynamicBatcher<Work>,
    metrics: &Metrics,
) {
    while let Some(batch) = batcher.next_batch() {
        let batch_size = batch.len();
        // Split by type, preserving arrival envelopes.
        let mut gens: Vec<(Pending<()>, GenerateRequest, Sender<GenerateResponse>)> = Vec::new();
        let mut attns = Vec::new();
        for p in batch {
            let arrived = p.arrived;
            match p.inner {
                Work::Generate(req, tx) => {
                    gens.push((Pending { inner: (), arrived }, req, tx))
                }
                Work::Attention(req, tx) => attns.push((arrived, req, tx)),
            }
        }
        if !gens.is_empty() {
            if let Err(e) = serve_generate_batch(reg, lm_params, &mut gens, metrics, batch_size) {
                crate::log_warn!("generate batch failed: {e:#}");
            }
        }
        for (arrived, req, tx) in attns {
            let queued_ms = arrived.elapsed().as_secs_f64() * 1e3;
            match serve_attention(reg, layers, controller, &req, metrics) {
                Ok(mut resp) => {
                    resp.queued_ms = queued_ms;
                    let _ = tx.send(resp);
                }
                Err(e) => crate::log_warn!("attention req {} failed: {e:#}", req.id),
            }
        }
    }
}

/// Batched greedy generation: packs up to `lm.batch` prompts into the
/// fixed-shape logits artifact and decodes all rows in lock-step.
fn serve_generate_batch(
    reg: &ArtifactRegistry,
    lm_params: &[f32],
    gens: &mut [(Pending<()>, GenerateRequest, Sender<GenerateResponse>)],
    metrics: &Metrics,
    batch_size: usize,
) -> Result<()> {
    let lm = &reg.manifest.lm;
    let sw = Stopwatch::start();
    // Process in chunks of the artifact batch dim.
    for chunk in gens.chunks_mut(lm.batch) {
        let max_steps = chunk.iter().map(|(_, r, _)| r.max_new_tokens).max().unwrap_or(0);
        let mut contexts: Vec<Vec<i32>> =
            chunk.iter().map(|(_, r, _)| r.prompt.clone()).collect();
        let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); chunk.len()];
        for _step in 0..max_steps {
            let mut tokens = vec![b' ' as i32; lm.batch * lm.seq_len];
            for (row, ctx) in contexts.iter().enumerate() {
                let take = ctx.len().min(lm.seq_len);
                let dst = row * lm.seq_len + (lm.seq_len - take);
                tokens[dst..dst + take].copy_from_slice(&ctx[ctx.len() - take..]);
            }
            let logits = reg.lm_logits(lm_params, &tokens)?;
            for (row, ctx) in contexts.iter_mut().enumerate() {
                if outputs[row].len() >= chunk[row].1.max_new_tokens {
                    continue;
                }
                let off = (row * lm.seq_len + lm.seq_len - 1) * lm.vocab;
                let next = logits[off..off + lm.vocab]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap();
                ctx.push(next);
                outputs[row].push(next);
            }
        }
        let compute_ms = sw.elapsed_ms();
        for (i, (pend, req, tx)) in chunk.iter_mut().enumerate() {
            let queued_ms = pend.queued_ms();
            metrics.record_request(queued_ms, compute_ms, batch_size);
            let _ = tx.send(GenerateResponse {
                id: req.id,
                tokens: std::mem::take(&mut outputs[i]),
                queued_ms,
                compute_ms,
                batch_size,
            });
        }
    }
    Ok(())
}

/// One adaptive-attention segment through the controller.
fn serve_attention(
    reg: &ArtifactRegistry,
    layers: &[MhsaWeights],
    controller: &mut RankController,
    req: &AttentionRequest,
    metrics: &Metrics,
) -> Result<AttentionResponse> {
    let sw = Stopwatch::start();
    anyhow::ensure!(req.layer < layers.len(), "layer {} out of range", req.layer);
    let w = &layers[req.layer];
    anyhow::ensure!(req.d_model == w.d_model(), "d_model mismatch");
    let x = Mat::from_vec(req.n, req.d_model, req.x.clone());
    let heads = project_heads(&x, w, true);
    let mut outs = Vec::with_capacity(heads.len());
    let mut ranks = Vec::with_capacity(heads.len());
    let mut spent = 0u64;
    let mut full = 0u64;
    for (h, inp) in heads.iter().enumerate() {
        let (y, dec) =
            controller.attention(reg, &x, w, inp, req.layer, h, layers.len())?;
        metrics.record_rank(dec.rank);
        if dec.masked_by_safety {
            metrics.record_safety_mask();
        }
        spent += dec.flops_spent;
        full += dec.flops_full;
        ranks.push(dec.rank);
        outs.push(y);
    }
    metrics.record_flops(spent, full);
    let merged = crate::attention::merge_heads(&outs, w);
    let compute_ms = sw.elapsed_ms();
    metrics.record_request(0.0, compute_ms, 1);
    Ok(AttentionResponse {
        id: req.id,
        y: merged.into_vec(),
        ranks,
        flops_spent: spent,
        flops_full: full,
        queued_ms: 0.0,
        compute_ms,
    })
}

#[cfg(test)]
mod tests {
    // Engine integration tests (device-backed) live in rust/tests/serving.rs;
    // unit coverage of batching/metrics lives in their own modules.
}
