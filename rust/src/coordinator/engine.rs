//! The serving engine: N worker threads drain the shared dynamic batcher
//! and execute batched LM generation plus DR-RL adaptive attention
//! segments against the artifact registry.
//!
//! ## Client surface
//!
//! `submit_generate` / `submit_attention` queue the request and hand
//! back a typed [`Ticket`] (non-blocking `poll`, blocking
//! `wait`/`wait_timeout`, `cancel`); attention requests are
//! shape/layer-validated before queueing; `submit_*_opts` adds per-request
//! [`SubmitOptions`] (deadline, blocking backpressure) and
//! `submit_generate_streaming` returns a [`StreamingTicket`] that
//! surfaces per-token deltas as decode steps complete. Tickets can be
//! moved into a [`super::CompletionQueue`] so one client thread drains
//! completions for hundreds of in-flight requests. Work whose ticket was
//! cancelled or whose deadline expired while queued is dropped at drain
//! time — before any probe/SVD compute — with an explicit
//! [`EngineError`] of kind `Cancelled`/`DeadlineExceeded`.
//!
//! ## Execution model
//!
//! Generation requests pack into fixed-shape logits chunks
//! (`serve_generate_batch`). Attention requests run through the staged
//! cross-request pipeline (`pipeline::run_attention_batch`):
//! **plan** (validate + project heads, lock-free, pooled) →
//! **probe** (one global SVD wave for every refreshing head of every
//! co-batched request across all layers) →
//! **decide** (each touched layer's shard lock taken once per drained
//! batch; decisions replay serially in request-arrival, head order) →
//! **apply** (one pooled wave of masked factor applies). A drained
//! batch therefore costs O(layers-touched) lock round-trips and SVD
//! dispatches instead of O(requests). The batcher keys attention
//! requests by layer, so it may over-drain past `max_batch` while the
//! queue front targets the batch head's layer (deeper co-batches →
//! fewer probe waves; counted by the `over_drained` metric).
//!
//! ## Sharding and the decision-ordering invariant
//!
//! Rank-controller state is sharded **per layer** (one
//! `Mutex<RankController>` per layer, all sharing one `PolicySource`),
//! so same-layer decisions stay coherent and serialized while requests
//! to different layers — and the generate path — proceed in parallel.
//! Within a drained batch the pipeline replays each layer's decisions in
//! the order the requests arrived, which makes its results bit-identical
//! to submitting the same requests one at a time to a single-worker
//! engine (see `rust/tests/engine_concurrency.rs`).

use super::batcher::{BatchPolicy, DynamicBatcher, SubmitError};
use super::completion::{AttnReply, DeltaStream, GenReply, Slot, StreamingTicket, Ticket};
use super::metrics::Metrics;
use super::pipeline::{self, AttnJob};
use super::rank_controller::{ControllerConfig, PolicySource, RankController};
use super::request::*;
use crate::attention::MhsaWeights;
use crate::runtime::ArtifactRegistry;
use crate::util::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

enum Work {
    Generate(GenerateRequest, GenReply),
    Attention(AttentionRequest, AttnReply),
}

/// Over-drain affinity: attention requests key by layer so a same-layer
/// backlog co-batches deeper; generation requests never extend a batch.
fn work_key(w: &Work) -> Option<usize> {
    match w {
        Work::Attention(req, _) => Some(req.layer),
        Work::Generate(..) => None,
    }
}

/// A generation request mid-flight: arrival envelope, request, reply.
type GenJob = (Pending<()>, GenerateRequest, GenReply);

/// Engine tuning knobs beyond the batching policy.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads draining the batcher. ≥ 2 by default so attention
    /// segments and generation batches overlap.
    pub n_workers: usize,
    pub batch_policy: BatchPolicy,
    /// Pipeline observation hooks (conformance harnesses, adversarial
    /// schedule tests). Empty by default: the pipeline checks each slot
    /// with a branch and calls nothing.
    pub hooks: PipelineHooks,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            n_workers: 2,
            batch_policy: BatchPolicy::default(),
            hooks: PipelineHooks::default(),
        }
    }
}

/// One replayed rank decision, as observed by
/// [`PipelineHooks::on_decide`] *under the layer's shard lock* — the
/// emission order is therefore exactly the serialized decide order the
/// bit-identity invariants are defined over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecideEvent {
    pub layer: usize,
    pub head: usize,
    /// The request this decision belongs to.
    pub request: RequestId,
    /// Replay position within the layer's step sequence for this batch.
    pub step: usize,
    pub rank: usize,
    pub prev_rank: usize,
    /// False when the segment reused the previous decision (non-boundary
    /// call).
    pub fresh: bool,
}

/// Observation hooks into the staged attention pipeline.
///
/// `after_probe` fires between the probe wave and the decide stage —
/// conformance and regression tests use it to land cancels/deadline
/// expiries deterministically mid-flight, or to jitter worker timing so
/// batches from different workers interleave on one layer.
/// `on_decide` fires for every replayed decision while the shard lock is
/// held, giving an exact serialization of the decide order (the
/// schedule-perturbation harness records and replays these traces).
///
/// Hooks run on engine worker threads: keep them short, never submit to
/// the same engine from inside one, and never take a shard lock.
#[derive(Clone, Default)]
pub struct PipelineHooks {
    pub after_probe: Option<Arc<dyn Fn() + Send + Sync>>,
    pub on_decide: Option<Arc<dyn Fn(DecideEvent) + Send + Sync>>,
}

impl std::fmt::Debug for PipelineHooks {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PipelineHooks")
            .field("after_probe", &self.after_probe.is_some())
            .field("on_decide", &self.on_decide.is_some())
            .finish()
    }
}

/// Shared state every worker operates on.
pub(crate) struct EngineShared {
    pub(crate) reg: Arc<ArtifactRegistry>,
    pub(crate) lm_params: Arc<Vec<f32>>,
    pub(crate) layers: Vec<MhsaWeights>,
    /// One controller shard per layer; index = layer.
    pub(crate) shards: Vec<Mutex<RankController>>,
    /// The shared policy source (also held by every shard); the pipeline
    /// reads it to short-circuit the full-rank dense path.
    pub(crate) source: Arc<PolicySource>,
    /// Controller config the shards were built with (the pipeline needs
    /// the rank grid to size the probe bucket).
    pub(crate) controller_cfg: ControllerConfig,
    pub(crate) metrics: Arc<Metrics>,
    /// Prompt-shutdown flag: once set, workers stop computing queued
    /// work and post explicit errors instead.
    pub(crate) stopped: AtomicBool,
    /// Pipeline observation hooks (always compiled — conformance
    /// harnesses in `rust/tests/` and the `conformance` module install
    /// them through `EngineConfig::hooks`).
    pub(crate) hooks: PipelineHooks,
}

impl EngineShared {
    /// The device profile serving projects latency onto — the registry's
    /// single precedence rule applied to this engine's configuration.
    pub(crate) fn projection_profile(&self) -> Option<crate::sim::DeviceProfile> {
        self.reg.projection_profile(self.controller_cfg.reward_profile)
    }
}

/// Engine handle. Submit from any thread.
pub struct ServingEngine {
    batcher: Arc<DynamicBatcher<Work>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shared: Arc<EngineShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServingEngine {
    /// Start an engine with the default worker count (N = 2). The engine
    /// owns a frozen attention layer stack (for the adaptive-attention
    /// service) and the trained LM params (for generation).
    pub fn start(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        batch_policy: BatchPolicy,
    ) -> ServingEngine {
        Self::start_with_config(
            reg,
            lm_params,
            layers,
            controller_cfg,
            source,
            EngineConfig { batch_policy, ..EngineConfig::default() },
        )
    }

    /// Start an engine with an explicit worker count.
    pub fn start_with_config(
        reg: Arc<ArtifactRegistry>,
        lm_params: Arc<Vec<f32>>,
        layers: Vec<MhsaWeights>,
        controller_cfg: ControllerConfig,
        source: PolicySource,
        config: EngineConfig,
    ) -> ServingEngine {
        let batcher = Arc::new(DynamicBatcher::with_key(config.batch_policy, work_key));
        let metrics = Arc::new(Metrics::new());
        // Fold the backend's typed per-op counters into Metrics::report().
        metrics.attach_backend_ops(reg.ops());
        let source = Arc::new(source);
        let shards: Vec<Mutex<RankController>> = (0..layers.len().max(1))
            .map(|_| {
                Mutex::new(RankController::with_shared_source(
                    controller_cfg.clone(),
                    Arc::clone(&source),
                ))
            })
            .collect();
        let shared = Arc::new(EngineShared {
            reg,
            lm_params,
            layers,
            shards,
            source,
            controller_cfg,
            metrics: Arc::clone(&metrics),
            stopped: AtomicBool::new(false),
            hooks: config.hooks,
        });
        // Surface the projected-latency ledger in Metrics::report() when
        // a projection profile is in scope (sim backend or configured
        // reward profile) — live reporting, not an exit-time print.
        if let Some(p) = shared.projection_profile() {
            metrics.set_projection_profile(p.name);
        }
        let n_workers = config.n_workers.max(1);
        let workers = (0..n_workers)
            .map(|i| {
                let batcher = Arc::clone(&batcher);
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("drrl-engine-{i}"))
                    .spawn(move || worker_loop(&shared, &batcher))
                    .expect("spawn engine worker")
            })
            .collect();
        ServingEngine {
            batcher,
            metrics,
            next_id: AtomicU64::new(1),
            shared,
            workers,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn submit_work(
        &self,
        id: RequestId,
        work: Work,
        opts: &SubmitOptions,
    ) -> Result<(), EngineError> {
        match self.batcher.submit_opts(work, opts.deadline, opts.blocking) {
            Ok(()) => Ok(()),
            Err(SubmitError::Full) => {
                self.metrics.record_rejection();
                Err(EngineError::new(id, ErrorKind::Rejected, "submit queue full"))
            }
            Err(SubmitError::Expired) => {
                self.metrics.record_expired();
                Err(EngineError::deadline_exceeded(id))
            }
            Err(SubmitError::Closed) => {
                Err(EngineError::new(id, ErrorKind::Shutdown, "engine stopped"))
            }
        }
    }

    fn next_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Queue a generation request with default options.
    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
    ) -> Result<Ticket<GenerateResponse>, EngineError> {
        self.submit_generate_opts(prompt, max_new_tokens, SubmitOptions::default())
    }

    /// Queue a generation request with explicit submit options.
    pub fn submit_generate_opts(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        opts: SubmitOptions,
    ) -> Result<Ticket<GenerateResponse>, EngineError> {
        let (ticket, _) = self.submit_generate_inner(prompt, max_new_tokens, opts, false)?;
        Ok(ticket)
    }

    /// Queue a generation request whose per-token deltas stream back as
    /// decode steps complete, ahead of the final response.
    pub fn submit_generate_streaming(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        opts: SubmitOptions,
    ) -> Result<StreamingTicket, EngineError> {
        let (ticket, stream) = self.submit_generate_inner(prompt, max_new_tokens, opts, true)?;
        Ok(StreamingTicket::new(ticket, stream.expect("streaming submit carries a stream")))
    }

    fn submit_generate_inner(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        opts: SubmitOptions,
        streaming: bool,
    ) -> Result<(Ticket<GenerateResponse>, Option<Arc<DeltaStream>>), EngineError> {
        let id = self.next_id();
        self.check_deadline(id, &opts)?;
        let slot = Slot::new(id, opts.deadline);
        let stream = streaming.then(DeltaStream::new);
        let reply = GenReply { slot: Arc::clone(&slot), stream: stream.clone() };
        let req = GenerateRequest { id, prompt, max_new_tokens };
        self.submit_work(id, Work::Generate(req, reply), &opts)?;
        Ok((Ticket::new(slot), stream))
    }

    /// Queue an adaptive-attention segment with default options.
    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<Ticket<AttentionResponse>, EngineError> {
        self.submit_attention_opts(x, n, d_model, layer, SubmitOptions::default())
    }

    /// Queue an adaptive-attention segment with explicit submit options.
    /// Shape/layer validation happens here, before the request is
    /// queued, so malformed requests fail fast with
    /// [`ErrorKind::Invalid`] instead of inside a worker.
    pub fn submit_attention_opts(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
        opts: SubmitOptions,
    ) -> Result<Ticket<AttentionResponse>, EngineError> {
        let id = self.next_id();
        self.validate_attention(id, &x, n, d_model, layer)?;
        self.check_deadline(id, &opts)?;
        let slot = Slot::new(id, opts.deadline);
        let req = AttentionRequest { id, x, n, d_model, layer };
        self.submit_work(id, Work::Attention(req, AttnReply::new(Arc::clone(&slot))), &opts)?;
        Ok(Ticket::new(slot))
    }

    fn validate_attention(
        &self,
        id: RequestId,
        x: &[f64],
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<(), EngineError> {
        let fail = |msg: String| {
            self.metrics.record_invalid();
            Err(EngineError::new(id, ErrorKind::Invalid, msg))
        };
        if n == 0 {
            return fail("n must be > 0".into());
        }
        if layer >= self.shared.layers.len() {
            return fail(format!(
                "layer {layer} out of range (engine has {} layers)",
                self.shared.layers.len()
            ));
        }
        let want_d = self.shared.layers[layer].d_model();
        if d_model != want_d {
            return fail(format!("d_model {d_model} != layer d_model {want_d}"));
        }
        if x.len() != n * d_model {
            return fail(format!("input length {} != n*d_model = {}", x.len(), n * d_model));
        }
        Ok(())
    }

    /// A deadline already in the past never enters the queue.
    fn check_deadline(&self, id: RequestId, opts: &SubmitOptions) -> Result<(), EngineError> {
        match opts.deadline {
            Some(d) if Instant::now() >= d => {
                self.metrics.record_expired();
                Err(EngineError::deadline_exceeded(id))
            }
            _ => Ok(()),
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }

    /// Prompt shutdown: stop computing queued work (remaining requests'
    /// tickets get explicit `EngineError` completions of kind
    /// `Shutdown`), then join the workers. In-flight work finishes
    /// normally, so every outstanding ticket resolves.
    pub fn shutdown(mut self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        // Graceful: drain the queue fully, then join.
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &EngineShared, batcher: &DynamicBatcher<Work>) {
    let max_batch = batcher.policy().max_batch;
    while let Some(batch) = batcher.next_batch() {
        if batch.len() > max_batch {
            shared.metrics.record_over_drain((batch.len() - max_batch) as u64);
        }
        if shared.stopped.load(Ordering::SeqCst) {
            // Prompt shutdown: post Shutdown errors instead of computing
            // (the batcher is already closed to submitters).
            for p in batch {
                match p.inner {
                    Work::Generate(req, reply) => reply.post(Err(EngineError::new(
                        req.id,
                        ErrorKind::Shutdown,
                        "engine stopped before request ran",
                    ))),
                    Work::Attention(req, reply) => reply.fulfill(Err(EngineError::new(
                        req.id,
                        ErrorKind::Shutdown,
                        "engine stopped before request ran",
                    ))),
                }
            }
            continue;
        }
        // Regroup the drained batch by type, preserving the arrival
        // envelopes and FIFO order (the pipeline's replay order), and
        // reap generation jobs whose ticket was cancelled or whose
        // deadline expired while queued (attention jobs are reaped at
        // the pipeline's entry, before its plan stage).
        let now = Instant::now();
        let mut gens: Vec<GenJob> = Vec::new();
        let mut attns: Vec<AttnJob> = Vec::new();
        for p in batch {
            let arrived = p.arrived;
            match p.inner {
                Work::Generate(req, reply) => match reply.slot.reap_kind(now) {
                    Some(kind) => {
                        record_reap(&shared.metrics, kind);
                        reply.post(Err(reap_error(req.id, kind)));
                    }
                    None => gens.push((
                        Pending { inner: (), arrived, deadline: None },
                        req,
                        reply,
                    )),
                },
                Work::Attention(req, reply) => {
                    attns.push(AttnJob { arrived, req, reply })
                }
            }
        }
        if !gens.is_empty() {
            // serve_generate_batch replies to every request itself (Ok per
            // chunk, or explicit errors for the failing chunk onward).
            // batch_size counts co-batched *generation* requests, matching
            // the attention pipeline's same-type co-batch convention.
            let gen_count = gens.len();
            if let Err(e) = serve_generate_batch(shared, &mut gens, gen_count) {
                crate::log_warn!("generate batch failed: {e:#}");
            }
        }
        // The staged cross-request pipeline posts every attention job's
        // completion itself (including reaped jobs).
        pipeline::run_attention_batch(shared, attns);
    }
}

/// Metrics bookkeeping for a drain-time reap.
pub(crate) fn record_reap(metrics: &Metrics, kind: ErrorKind) {
    match kind {
        ErrorKind::Cancelled => metrics.record_cancelled(),
        ErrorKind::DeadlineExceeded => metrics.record_expired(),
        _ => {}
    }
}

/// The error a drain-time reap posts — routed through the shared
/// `EngineError` constructors so the client-visible text matches the
/// cancel/expiry errors posted from every other path.
pub(crate) fn reap_error(id: RequestId, kind: ErrorKind) -> EngineError {
    match kind {
        ErrorKind::Cancelled => EngineError::cancelled(id),
        ErrorKind::DeadlineExceeded => EngineError::deadline_exceeded(id),
        other => EngineError::new(id, other, "request dropped before it ran"),
    }
}

/// Batched greedy generation over the whole drained batch. Every request
/// receives exactly one completion: `Ok` when its chunk completes, or an
/// explicit `EngineError` for the failing chunk and all chunks after it
/// (already-completed chunks are left alone).
fn serve_generate_batch(
    shared: &EngineShared,
    gens: &mut [GenJob],
    batch_size: usize,
) -> Result<()> {
    let chunk_size = shared.reg.manifest.lm.batch.max(1);
    let n = gens.len();
    for lo in (0..n).step_by(chunk_size) {
        let hi = (lo + chunk_size).min(n);
        if let Err(e) = serve_generate_chunk(shared, &mut gens[lo..hi], batch_size) {
            for (_, req, reply) in &gens[lo..] {
                reply.post(Err(EngineError::new(
                    req.id,
                    ErrorKind::Internal,
                    format!("generate batch failed: {e:#}"),
                )));
            }
            return Err(e);
        }
    }
    Ok(())
}

/// One chunk (≤ the artifact batch dim) of greedy generation: packs the
/// prompts into the fixed-shape logits artifact and decodes all rows in
/// lock-step, streaming each newly decoded token to streaming tickets as
/// its step completes.
fn serve_generate_chunk(
    shared: &EngineShared,
    chunk: &mut [GenJob],
    batch_size: usize,
) -> Result<()> {
    let reg = &shared.reg;
    let lm = &reg.manifest.lm;
    // The stopwatch covers exactly one chunk (the caller loops over
    // chunks), so compute_ms and the latency histograms never accumulate
    // cross-chunk time.
    let sw = Stopwatch::start();
    let max_steps = chunk.iter().map(|(_, r, _)| r.max_new_tokens).max().unwrap_or(0);
    let mut contexts: Vec<Vec<i32>> =
        chunk.iter().map(|(_, r, _)| r.prompt.clone()).collect();
    let mut outputs: Vec<Vec<i32>> = vec![Vec::new(); chunk.len()];
    for _step in 0..max_steps {
        let mut tokens = vec![b' ' as i32; lm.batch * lm.seq_len];
        for (row, ctx) in contexts.iter().enumerate() {
            let take = ctx.len().min(lm.seq_len);
            let dst = row * lm.seq_len + (lm.seq_len - take);
            tokens[dst..dst + take].copy_from_slice(&ctx[ctx.len() - take..]);
        }
        let logits = reg.lm_logits(&shared.lm_params, &tokens)?;
        for (row, ctx) in contexts.iter_mut().enumerate() {
            if outputs[row].len() >= chunk[row].1.max_new_tokens {
                continue;
            }
            let off = (row * lm.seq_len + lm.seq_len - 1) * lm.vocab;
            let next = logits[off..off + lm.vocab]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap();
            ctx.push(next);
            chunk[row].2.push_delta(GenerateDelta {
                id: chunk[row].1.id,
                index: outputs[row].len(),
                token: next,
            });
            outputs[row].push(next);
        }
    }
    let compute_ms = sw.elapsed_ms();
    // Projected device latency of this chunk: one fixed-shape lm_logits
    // dispatch per decode step — exactly the charge the sim backend's
    // roofline ledger records per call, so the metrics ledger matches
    // it. The LM path has no rank adaptation, so the counterfactual
    // equals the spend.
    let projected_ms = shared.projection_profile().map(|p| {
        max_steps as f64
            * crate::sim::project_latency_ms(reg.manifest.lm.batch_forward_flops(), &p)
    });
    if let Some(ms) = projected_ms {
        shared.metrics.record_projected(ms, ms);
    }
    for (i, (pend, req, reply)) in chunk.iter_mut().enumerate() {
        let queued_ms = pend.queued_ms();
        shared.metrics.record_request(queued_ms, compute_ms, batch_size);
        reply.post(Ok(GenerateResponse {
            id: req.id,
            tokens: std::mem::take(&mut outputs[i]),
            queued_ms,
            compute_ms,
            batch_size,
            projected_ms,
        }));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    // Engine integration tests live in rust/tests/serving.rs (artifact-
    // backed), rust/tests/engine_concurrency.rs (host-backed, no
    // artifacts needed — including the cross-request pipeline equality
    // tests) and rust/tests/completion_queue.rs (ticket/queue semantics:
    // cancellation, deadlines, streaming, shutdown); unit coverage of
    // batching/metrics/completion primitives lives in their own modules.
}
