//! Request router: spreads load across engine replicas (leader side of
//! the leader/worker topology). Strategies: round-robin, least-loaded
//! (per-engine queue depth, re-read at every submit), and layer-affinity
//! — attention segments for the same layer land on the same replica, so
//! its cross-request pipeline can co-batch them into one probe wave and
//! one decision replay instead of spreading the layer's stream state
//! across replicas.
//!
//! The router hands back the same [`Ticket`]s the engines do, so a
//! single [`super::CompletionQueue`] drains completions across *all*
//! replicas: submit through the router, move every ticket into one
//! queue, and consume in arrival-of-completion order regardless of
//! which engine served what.

use super::completion::Ticket;
use super::engine::ServingEngine;
use super::request::{
    AttentionResponse, EngineError, GenerateResponse, SubmitOptions,
};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Routing strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteStrategy {
    RoundRobin,
    /// Every submit goes to the replica with the smallest queue depth at
    /// that instant (ties break toward the lowest index).
    LeastLoaded,
    /// Attention requests route by `layer % n_engines` (maximizing
    /// same-layer co-batching in each engine's pipeline); generation
    /// requests fall back to round-robin.
    LayerAffinity,
}

/// Router over engine replicas.
pub struct Router {
    engines: Vec<ServingEngine>,
    strategy: RouteStrategy,
    next: AtomicUsize,
}

impl Router {
    pub fn new(engines: Vec<ServingEngine>, strategy: RouteStrategy) -> Self {
        assert!(!engines.is_empty(), "router needs ≥1 engine");
        Router { engines, strategy, next: AtomicUsize::new(0) }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn engines(&self) -> &[ServingEngine] {
        &self.engines
    }

    /// Total queued work across all replicas (the load signal the
    /// `LeastLoaded` strategy balances per-engine).
    pub fn queue_depth(&self) -> usize {
        self.engines.iter().map(|e| e.queue_depth()).sum()
    }

    fn round_robin(&self) -> &ServingEngine {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.engines.len();
        &self.engines[i]
    }

    fn pick(&self, layer: Option<usize>) -> &ServingEngine {
        match self.strategy {
            RouteStrategy::RoundRobin => self.round_robin(),
            RouteStrategy::LeastLoaded => self
                .engines
                .iter()
                .min_by_key(|e| e.queue_depth())
                .expect("non-empty"),
            RouteStrategy::LayerAffinity => match layer {
                Some(l) => &self.engines[l % self.engines.len()],
                None => self.round_robin(),
            },
        }
    }

    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<Ticket<GenerateResponse>, EngineError> {
        self.pick(None).submit_generate(prompt, max_new)
    }

    pub fn submit_generate_opts(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        opts: SubmitOptions,
    ) -> Result<Ticket<GenerateResponse>, EngineError> {
        self.pick(None).submit_generate_opts(prompt, max_new, opts)
    }

    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<Ticket<AttentionResponse>, EngineError> {
        self.pick(Some(layer)).submit_attention(x, n, d_model, layer)
    }

    pub fn submit_attention_opts(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
        opts: SubmitOptions,
    ) -> Result<Ticket<AttentionResponse>, EngineError> {
        self.pick(Some(layer)).submit_attention_opts(x, n, d_model, layer, opts)
    }

    /// Aggregate metric report across replicas.
    pub fn report(&self) -> String {
        self.engines
            .iter()
            .enumerate()
            .map(|(i, e)| format!("── engine {i} ──\n{}", e.metrics.report()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}
