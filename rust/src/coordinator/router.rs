//! Request router: spreads load across engine replicas (leader side of
//! the leader/worker topology). Strategies: round-robin, least-loaded
//! (queue depth), and layer-affinity — attention segments for the same
//! layer land on the same replica, so its cross-request pipeline can
//! co-batch them into one probe wave and one decision replay instead of
//! spreading the layer's stream state across replicas.

use super::engine::ServingEngine;
use super::request::{
    AttentionResponse, EngineResult, GenerateResponse, RequestId, ResponseReceiver,
};
use crate::coordinator::batcher::SubmitError;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Routing strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteStrategy {
    RoundRobin,
    LeastLoaded,
    /// Attention requests route by `layer % n_engines` (maximizing
    /// same-layer co-batching in each engine's pipeline); generation
    /// requests fall back to round-robin.
    LayerAffinity,
}

/// Router over engine replicas.
pub struct Router {
    engines: Vec<ServingEngine>,
    strategy: RouteStrategy,
    next: AtomicUsize,
}

impl Router {
    pub fn new(engines: Vec<ServingEngine>, strategy: RouteStrategy) -> Self {
        assert!(!engines.is_empty(), "router needs ≥1 engine");
        Router { engines, strategy, next: AtomicUsize::new(0) }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn engines(&self) -> &[ServingEngine] {
        &self.engines
    }

    fn round_robin(&self) -> &ServingEngine {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.engines.len();
        &self.engines[i]
    }

    fn pick(&self, layer: Option<usize>) -> &ServingEngine {
        match self.strategy {
            RouteStrategy::RoundRobin => self.round_robin(),
            RouteStrategy::LeastLoaded => self
                .engines
                .iter()
                .min_by_key(|e| e.queue_depth())
                .expect("non-empty"),
            RouteStrategy::LayerAffinity => match layer {
                Some(l) => &self.engines[l % self.engines.len()],
                None => self.round_robin(),
            },
        }
    }

    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<(RequestId, ResponseReceiver<GenerateResponse>), SubmitError> {
        self.pick(None).submit_generate(prompt, max_new)
    }

    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<(RequestId, ResponseReceiver<AttentionResponse>), SubmitError> {
        self.pick(Some(layer)).submit_attention(x, n, d_model, layer)
    }

    /// Aggregate metric report across replicas.
    pub fn report(&self) -> String {
        self.engines
            .iter()
            .enumerate()
            .map(|(i, e)| format!("── engine {i} ──\n{}", e.metrics.report()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}
