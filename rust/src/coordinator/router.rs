//! Request router: spreads load across engine replicas (leader side of
//! the leader/worker topology). Strategies: round-robin and
//! least-loaded (queue depth).

use super::engine::ServingEngine;
use super::request::{AttentionResponse, EngineResult, GenerateResponse, RequestId};
use crate::coordinator::batcher::SubmitError;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Routing strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteStrategy {
    RoundRobin,
    LeastLoaded,
}

/// Router over engine replicas.
pub struct Router {
    engines: Vec<ServingEngine>,
    strategy: RouteStrategy,
    next: AtomicUsize,
}

impl Router {
    pub fn new(engines: Vec<ServingEngine>, strategy: RouteStrategy) -> Self {
        assert!(!engines.is_empty(), "router needs ≥1 engine");
        Router { engines, strategy, next: AtomicUsize::new(0) }
    }

    pub fn n_engines(&self) -> usize {
        self.engines.len()
    }

    pub fn engines(&self) -> &[ServingEngine] {
        &self.engines
    }

    fn pick(&self) -> &ServingEngine {
        match self.strategy {
            RouteStrategy::RoundRobin => {
                let i = self.next.fetch_add(1, Ordering::Relaxed) % self.engines.len();
                &self.engines[i]
            }
            RouteStrategy::LeastLoaded => self
                .engines
                .iter()
                .min_by_key(|e| e.queue_depth())
                .expect("non-empty"),
        }
    }

    pub fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<(RequestId, std::sync::mpsc::Receiver<EngineResult<GenerateResponse>>), SubmitError>
    {
        self.pick().submit_generate(prompt, max_new)
    }

    pub fn submit_attention(
        &self,
        x: Vec<f64>,
        n: usize,
        d_model: usize,
        layer: usize,
    ) -> Result<(RequestId, std::sync::mpsc::Receiver<EngineResult<AttentionResponse>>), SubmitError>
    {
        self.pick().submit_attention(x, n, d_model, layer)
    }

    /// Aggregate metric report across replicas.
    pub fn report(&self) -> String {
        self.engines
            .iter()
            .enumerate()
            .map(|(i, e)| format!("── engine {i} ──\n{}", e.metrics.report()))
            .collect::<Vec<_>>()
            .join("\n")
    }
}
