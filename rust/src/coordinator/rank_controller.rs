//! Segment-level rank controller — the serving-time DR-RL loop (§4.3,
//! §4.5.2): featurize → policy → trust-region safety mask → incremental
//! SVD → dispatch the masked factor-attention kernel to the device.
//!
//! One controller instance manages every (layer, head) stream of an
//! engine; per-stream state (previous rank, incremental factor cache)
//! is keyed by stream id.

use crate::attention::{attention_matrix, AttnInputs, MhsaWeights};
use crate::flops;
use crate::linalg::{IncrementalCache, Mat, Svd};
use crate::rl::{featurize, ActorCritic, ConvFeaturizer, RankState};
use crate::runtime::ArtifactRegistry;
use crate::spectral::{assess_transition, TrustRegion};
use crate::util::threadpool::SendPtr;
use crate::util::{global_pool, Pcg32};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Where rank decisions come from.
pub enum PolicySource {
    /// AOT transformer policy (artifact `policy_net`).
    Hlo,
    /// Rust-trained actor (PPO/BC product).
    Actor(ActorCritic),
    /// Baselines for A/B serving experiments.
    Fixed(usize),
    AdaptiveEnergy(f64),
    Random,
    /// Full rank (upper bound; disables the low-rank path).
    FullRank,
}

impl PolicySource {
    pub fn name(&self) -> &'static str {
        match self {
            PolicySource::Hlo => "hlo-policy",
            PolicySource::Actor(_) => "actor-policy",
            PolicySource::Fixed(_) => "fixed",
            PolicySource::AdaptiveEnergy(_) => "adaptive-energy",
            PolicySource::Random => "random",
            PolicySource::FullRank => "full-rank",
        }
    }
}

/// Controller configuration.
#[derive(Clone)]
pub struct ControllerConfig {
    pub rank_grid: Vec<usize>,
    pub use_trust_region: bool,
    pub epsilon0: f64,
    pub lambda: f64,
    /// Re-decide every `segment_len` calls per stream (§4.5.2); between
    /// decisions the previous rank is reused and only the factor apply
    /// runs.
    pub segment_len: usize,
    pub seed: u64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            rank_grid: vec![16, 24, 32, 40, 48, 56, 64],
            use_trust_region: true,
            epsilon0: 0.7,
            lambda: 5e-5,
            segment_len: 16,
            seed: 0xC011,
        }
    }
}

#[derive(Default)]
struct StreamState {
    prev_rank: Option<usize>,
    cache: Option<IncrementalCache>,
    calls: u64,
}

/// One decision's outcome (consumed by metrics / Fig 3 / Fig 5).
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub rank: usize,
    pub prev_rank: usize,
    pub masked_by_safety: bool,
    pub perturbation: f64,
    pub flops_spent: u64,
    pub flops_full: u64,
    /// True when this call re-ran the policy (segment boundary).
    pub fresh_decision: bool,
}

/// The controller.
///
/// Multi-worker engines shard controllers per layer (one instance behind
/// a `Mutex` per layer) and share one `PolicySource` through the `Arc`,
/// so rank decisions stay coherent while different layers decide in
/// parallel. Stream keys include the layer, so a sharded deployment sees
/// exactly the same per-stream seeds and state a single controller would.
pub struct RankController {
    pub cfg: ControllerConfig,
    pub source: Arc<PolicySource>,
    pub trust: TrustRegion,
    conv: ConvFeaturizer,
    streams: BTreeMap<u64, StreamState>,
    rng: Pcg32,
    /// Rank trace per layer (Fig 3): (layer, segment_index, rank).
    pub rank_trace: Vec<(usize, u64, usize)>,
    /// Transition counts over the grid (Fig 5 overlay).
    pub transition_counts: Vec<Vec<u64>>,
}

impl RankController {
    pub fn new(cfg: ControllerConfig, source: PolicySource) -> Self {
        Self::with_shared_source(cfg, Arc::new(source))
    }

    /// Controller sharing a `PolicySource` with sibling shards (the
    /// multi-worker engine builds one controller per layer this way).
    pub fn with_shared_source(cfg: ControllerConfig, source: Arc<PolicySource>) -> Self {
        let n = cfg.rank_grid.len();
        RankController {
            trust: TrustRegion::new(cfg.epsilon0, cfg.lambda),
            conv: ConvFeaturizer::new(cfg.seed ^ 0xC0117),
            streams: BTreeMap::new(),
            rng: Pcg32::seeded(cfg.seed),
            rank_trace: Vec::new(),
            transition_counts: vec![vec![0; n]; n],
            cfg,
            source,
        }
    }

    fn stream_key(layer: usize, head: usize) -> u64 {
        ((layer as u64) << 16) | head as u64
    }

    /// Pick a rank for the state/spectrum under the safety mask.
    fn pick_rank(
        &mut self,
        state: &RankState,
        spectrum: &[f64],
        prev_rank: usize,
        reg: &ArtifactRegistry,
    ) -> Result<(usize, bool)> {
        let grid = self.cfg.rank_grid.clone();
        // Safety mask (Eq. 9/11): assess every candidate transition.
        let mask: Vec<bool> = if self.cfg.use_trust_region {
            let assessments: Vec<_> = grid
                .iter()
                .map(|&r| assess_transition(spectrum, prev_rank, r, 1.0))
                .collect();
            let mut m = self.trust.mask_actions(prev_rank, &assessments);
            if !m.iter().any(|&b| b) {
                let closest = grid
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &r)| r.abs_diff(prev_rank))
                    .map(|(i, _)| i)
                    .unwrap();
                m[closest] = true;
            }
            m
        } else {
            vec![true; grid.len()]
        };
        self.trust.tick();
        let any_masked = mask.iter().any(|&b| !b);

        let idx = match self.source.as_ref() {
            PolicySource::Hlo => {
                let logits = reg.policy_logits(&state.features)?;
                argmax_masked(&logits, &mask)
            }
            PolicySource::Actor(ac) => {
                let dist = ac.distribution(&state.features, Some(&mask));
                dist.argmax()
            }
            PolicySource::Fixed(r) => nearest_open(&grid, *r, &mask),
            PolicySource::AdaptiveEnergy(th) => {
                let wanted = crate::spectral::rank_for_energy(spectrum, *th);
                nearest_open(&grid, wanted, &mask)
            }
            PolicySource::Random => {
                let open: Vec<usize> =
                    mask.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect();
                open[self.rng.range(0, open.len())]
            }
            PolicySource::FullRank => grid.len() - 1,
        };
        Ok((grid[idx], any_masked && !mask[idx]))
    }

    /// Serve one head's attention for a segment step. Returns the output
    /// and the decision record. `x_layer` is the layer input (for h_t).
    /// Thin wrapper over [`Self::attention_heads_batched`] so the single-
    /// head path (benches, oracle) and the engine's batched path cannot
    /// drift.
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        &mut self,
        reg: &ArtifactRegistry,
        x_layer: &Mat,
        w: &MhsaWeights,
        inp: &AttnInputs,
        layer: usize,
        head: usize,
        n_layers: usize,
    ) -> Result<(Mat, Decision)> {
        let mut out =
            self.attention_heads_batched(reg, x_layer, w, &[(head, inp)], layer, n_layers)?;
        Ok(out.remove(0))
    }

    /// Serve one segment step for several heads of a layer at once.
    ///
    /// The heavy per-head work — the attention probe + truncated SVD at
    /// segment boundaries and the masked factor apply — fans out over the
    /// global thread pool in one batched dispatch per phase (the CPU
    /// analogue of the paper's batched cuSOLVER SVD), so an 8-head
    /// segment costs roughly one head of wall-clock. Decision state
    /// (trust-region ticks, policy RNG, traces) is advanced serially in
    /// head order, preserving bit-identical results to the serial path.
    ///
    /// `heads` pairs each head index with its projected Q/K/V inputs.
    pub fn attention_heads_batched(
        &mut self,
        reg: &ArtifactRegistry,
        x_layer: &Mat,
        w: &MhsaWeights,
        heads: &[(usize, &AttnInputs)],
        layer: usize,
        n_layers: usize,
    ) -> Result<Vec<(Mat, Decision)>> {
        if heads.is_empty() {
            return Ok(Vec::new());
        }
        let r_max = *self.cfg.rank_grid.iter().max().unwrap();
        let bucket_max = reg.rank_bucket(r_max);

        // FULL-RANK short-circuit: dense kernel per head, fanned out.
        if matches!(self.source.as_ref(), PolicySource::FullRank) {
            let mut outs: Vec<Option<Result<Mat>>> = (0..heads.len()).map(|_| None).collect();
            let ptr = SendPtr::new(&mut outs);
            global_pool().scoped_for(heads.len(), |i| {
                // SAFETY: each index writes a distinct slot.
                let slot = &mut unsafe { ptr.get() }[i];
                let inp = heads[i].1;
                *slot = Some(reg.full_attention(&inp.q, &inp.k, &inp.v));
            });
            let mut result = Vec::with_capacity(heads.len());
            for (o, &(_, inp)) in outs.into_iter().zip(heads) {
                let y = o.expect("slot filled")?;
                let full = flops::full_attention_flops(inp.seq_len(), inp.head_dim());
                result.push((
                    y,
                    Decision {
                        rank: inp.seq_len(),
                        prev_rank: inp.seq_len(),
                        masked_by_safety: false,
                        perturbation: 0.0,
                        flops_spent: full,
                        flops_full: full,
                        fresh_decision: true,
                    },
                ));
            }
            return Ok(result);
        }

        // Phase 1 — per-stream bookkeeping (cheap): segment position,
        // previous rank, whether the factor cache needs a refresh.
        struct HeadStep {
            head: usize,
            calls: u64,
            boundary: bool,
            prev_rank: usize,
            refresh: Option<IncrementalCache>,
            svd: Option<Svd>,
        }
        let seg = self.cfg.segment_len as u64;
        let default_rank = self.cfg.rank_grid[self.cfg.rank_grid.len() / 2];
        let mut steps: Vec<HeadStep> = Vec::with_capacity(heads.len());
        for &(h, _) in heads {
            let key = Self::stream_key(layer, h);
            let entry = self.streams.entry(key).or_default();
            let calls = entry.calls;
            entry.calls += 1;
            let boundary = if seg == 0 { calls == 0 } else { calls % seg == 0 };
            let prev_rank = entry.prev_rank.unwrap_or(default_rank);
            // §Perf iteration 1: the probe/decomposition refreshes only at
            // segment boundaries; between them the cached factors serve.
            let (refresh, svd) = if entry.cache.is_none() || boundary {
                (Some(IncrementalCache::new(self.cfg.seed ^ key)), None)
            } else {
                let svd = entry
                    .cache
                    .as_ref()
                    .and_then(|c| c.current())
                    .expect("cache holds a decomposition between boundaries")
                    .clone();
                (None, Some(svd))
            };
            steps.push(HeadStep { head: h, calls, boundary, prev_rank, refresh, svd });
        }

        // Phase 2 — batched probe + truncated SVD for every head that
        // needs one: one parallel dispatch over the stacked per-head
        // score matrices.
        let refresh_idx: Vec<usize> = steps
            .iter()
            .enumerate()
            .filter(|(_, s)| s.refresh.is_some())
            .map(|(i, _)| i)
            .collect();
        if !refresh_idx.is_empty() {
            let ptr = SendPtr::new(&mut steps);
            let idx = &refresh_idx;
            global_pool().scoped_for(idx.len(), |j| {
                // SAFETY: distinct j map to distinct step slots.
                let step = &mut unsafe { ptr.get() }[idx[j]];
                let a = attention_matrix(heads[idx[j]].1);
                let cache = step.refresh.as_mut().expect("refresh slot");
                step.svd = Some(cache.decompose(&a, bucket_max).clone());
            });
        }
        for step in steps.iter_mut() {
            if let Some(cache) = step.refresh.take() {
                self.streams
                    .get_mut(&Self::stream_key(layer, step.head))
                    .expect("stream exists")
                    .cache = Some(cache);
            }
        }

        // Phase 3 — decisions, serial in head order so the trust-region
        // tick and policy RNG sequences match the serial controller.
        let mut decisions: Vec<Decision> = Vec::with_capacity(steps.len());
        for (pos, step) in steps.iter().enumerate() {
            let svd = step.svd.as_ref().expect("svd available");
            let (rank, masked, fresh) = if step.boundary {
                let state = featurize(
                    &self.conv,
                    x_layer,
                    w,
                    &svd.s,
                    step.prev_rank,
                    r_max,
                    layer,
                    n_layers,
                );
                let (r, m) = self.pick_rank(&state, &svd.s, step.prev_rank, reg)?;
                (r, m, true)
            } else {
                (step.prev_rank, false, false)
            };

            // Perturbation of the executed transition (Eq. 4).
            let perturbation =
                crate::spectral::rank_transition_perturbation(&svd.s, step.prev_rank, rank);

            if fresh {
                let grid = &self.cfg.rank_grid;
                if let (Some(fi), Some(ti)) = (
                    grid.iter().position(|&g| g == step.prev_rank),
                    grid.iter().position(|&g| g == rank),
                ) {
                    self.transition_counts[fi][ti] += 1;
                }
                self.rank_trace.push((layer, step.calls / seg.max(1), rank));
            }

            let (n, d) = (heads[pos].1.seq_len(), heads[pos].1.head_dim());
            // FLOPs ledger: the probe amortizes over the segment.
            let spent = flops::lowrank_attention_flops(n, d, rank, false)
                + flops::partial_svd_flops(n, n, bucket_max)
                    / self.cfg.segment_len.max(1) as u64;
            decisions.push(Decision {
                rank,
                prev_rank: step.prev_rank,
                masked_by_safety: masked,
                perturbation,
                flops_spent: spent,
                flops_full: flops::full_attention_flops(n, d),
                fresh_decision: fresh,
            });
            self.streams
                .get_mut(&Self::stream_key(layer, step.head))
                .expect("stream exists")
                .prev_rank = Some(rank);
        }

        // Phase 4 — device dispatch: masked factor apply at the bucket ≥
        // rank, fanned out per head.
        let mut outs: Vec<Option<Result<Mat>>> = (0..steps.len()).map(|_| None).collect();
        {
            let ptr = SendPtr::new(&mut outs);
            let steps_ref = &steps;
            let dec_ref = &decisions;
            global_pool().scoped_for(steps_ref.len(), |i| {
                // SAFETY: each index writes a distinct slot.
                let slot = &mut unsafe { ptr.get() }[i];
                let svd = steps_ref[i].svd.as_ref().expect("svd available");
                *slot = Some(reg.lowrank_attention(svd, dec_ref[i].rank, &heads[i].1.v));
            });
        }
        let mut result = Vec::with_capacity(steps.len());
        for (o, dec) in outs.into_iter().zip(decisions) {
            result.push((o.expect("slot filled")?, dec));
        }
        Ok(result)
    }
}

fn argmax_masked(logits: &[f64], mask: &[bool]) -> usize {
    logits
        .iter()
        .enumerate()
        .filter(|(i, _)| mask[*i])
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .expect("at least one open action")
}

fn nearest_open(grid: &[usize], target: usize, mask: &[bool]) -> usize {
    grid.iter()
        .enumerate()
        .filter(|(i, _)| mask[*i])
        .min_by_key(|(_, &r)| r.abs_diff(target))
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_argmax_skips_closed() {
        let logits = [5.0, 1.0, 3.0];
        assert_eq!(argmax_masked(&logits, &[false, true, true]), 2);
        assert_eq!(argmax_masked(&logits, &[true, true, true]), 0);
    }

    #[test]
    fn nearest_open_prefers_close_rank() {
        let grid = [16, 32, 64];
        assert_eq!(nearest_open(&grid, 30, &[true, true, true]), 1);
        assert_eq!(nearest_open(&grid, 30, &[true, false, true]), 0);
    }

    #[test]
    fn policy_source_names() {
        assert_eq!(PolicySource::Hlo.name(), "hlo-policy");
        assert_eq!(PolicySource::Fixed(32).name(), "fixed");
    }

    // Device-backed integration tests live in rust/tests/serving.rs.
}
