//! Segment-level rank controller — the serving-time DR-RL loop (§4.3,
//! §4.5.2): featurize → policy → trust-region safety mask → incremental
//! SVD → dispatch the masked factor-attention op to the engine's typed
//! backend (host, PJRT device, or hardware simulator) through the
//! `ArtifactRegistry` adapter.
//!
//! One controller instance manages every (layer, head) stream of an
//! engine; per-stream state (previous rank, incremental factor cache)
//! is keyed by stream id.
//!
//! ## Staged API (the engine's cross-request pipeline)
//!
//! The controller is split along the lock boundary of the serving
//! engine's plan → probe → decide → apply pipeline:
//!
//! * [`RankController::plan_steps`] — **lock-held, cheap**: advance the
//!   per-stream segment counters for a replay-ordered sequence of head
//!   occurrences and emit one [`StepPlan`] per occurrence saying where
//!   its decomposition comes from (fresh probe, the stream's cached
//!   factors, or an earlier refresh in the same plan).
//! * [`probe_head`] — **stateless, lock-free**: the attention-score
//!   probe + truncated SVD for one refresh step. The engine fans every
//!   refresh of a drained batch — all heads, all requests, all layers —
//!   into a single global-thread-pool dispatch (the CPU analogue of the
//!   paper's batched cuSOLVER SVD).
//! * [`RankController::decide_step`] — **lock-held, serial**: replay one
//!   occurrence's rank decision (featurize → policy → trust region) and
//!   advance stream state. Replaying in (request-arrival, head) order
//!   makes the pipeline bit-identical to serving the same requests one
//!   at a time.
//! * apply — **stateless, lock-free**: `ArtifactRegistry::
//!   lowrank_attention` with the decided rank, fanned out by the caller.
//!
//! [`RankController::attention_heads_batched`] (and its one-head wrapper
//! [`RankController::attention`]) drive the same four stages for a
//! single request, so the standalone path and the engine pipeline cannot
//! drift.

use crate::attention::{attention_matrix, AttnInputs, MhsaWeights};
use crate::flops;
use crate::linalg::{IncrementalCache, Mat, Svd};
use crate::rl::{featurize, ActorCritic, ConvFeaturizer, RankState};
use crate::runtime::ArtifactRegistry;
use crate::sim::{project_latency_ms, DeviceProfile};
use crate::spectral::{assess_transition, TrustRegion};
use crate::util::{global_pool, Pcg32};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Where rank decisions come from.
pub enum PolicySource {
    /// AOT transformer policy (artifact `policy_net`).
    Hlo,
    /// Rust-trained actor (PPO/BC product).
    Actor(ActorCritic),
    /// Baselines for A/B serving experiments.
    Fixed(usize),
    AdaptiveEnergy(f64),
    /// Soft-thresholding rule (SoftLMs, arXiv:2411.10543): keep the
    /// singular values surviving `σ_i − τ·σ_0 > 0`, rounded to the grid.
    SoftThreshold(f64),
    Random,
    /// Full rank (upper bound; disables the low-rank path).
    FullRank,
}

impl PolicySource {
    pub fn name(&self) -> &'static str {
        match self {
            PolicySource::Hlo => "hlo-policy",
            PolicySource::Actor(_) => "actor-policy",
            PolicySource::Fixed(_) => "fixed",
            PolicySource::AdaptiveEnergy(_) => "adaptive-energy",
            PolicySource::SoftThreshold(_) => "soft-threshold",
            PolicySource::Random => "random",
            PolicySource::FullRank => "full-rank",
        }
    }
}

/// Controller configuration.
#[derive(Clone)]
pub struct ControllerConfig {
    pub rank_grid: Vec<usize>,
    pub use_trust_region: bool,
    pub epsilon0: f64,
    pub lambda: f64,
    /// Re-decide every `segment_len` calls per stream (§4.5.2); between
    /// decisions the previous rank is reused and only the factor apply
    /// runs.
    pub segment_len: usize,
    pub seed: u64,
    /// Deployment profile to project per-decision latency onto when the
    /// backend has no latency model of its own. A backend that *does*
    /// model latency (the sim backend) always wins, so the serving
    /// ledger in `Metrics` matches the backend's charge-for-charge.
    /// `None` (default) on a host backend disables projection entirely —
    /// bit-identical pre-latency behavior.
    pub reward_profile: Option<DeviceProfile>,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            rank_grid: vec![16, 24, 32, 40, 48, 56, 64],
            use_trust_region: true,
            epsilon0: 0.7,
            lambda: 5e-5,
            segment_len: 16,
            seed: 0xC011,
            reward_profile: None,
        }
    }
}

#[derive(Default)]
struct StreamState {
    prev_rank: Option<usize>,
    /// Latest committed probe decomposition. Shared and immutable —
    /// snapshots and re-reads are O(1) handle clones, never factor
    /// copies, so the shard lock is held only for bookkeeping.
    probe: Option<Arc<Svd>>,
    calls: u64,
}

/// One decision's outcome (consumed by metrics / Fig 3 / Fig 5).
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    pub rank: usize,
    pub prev_rank: usize,
    pub masked_by_safety: bool,
    pub perturbation: f64,
    /// Analytic FLOPs of the step at the *executed* kernel widths: the
    /// factor apply at the rank's compiled bucket (what the backend
    /// actually runs — `KernelShape::rank_bucket`, not the requested
    /// rank) plus the segment-amortized probe.
    pub flops_spent: u64,
    pub flops_full: u64,
    /// Projected device latency attributable to this step's *backend*
    /// kernel charges (factor apply at the bucket, plus the policy op at
    /// boundaries on the `Hlo` source), when a projection profile is in
    /// scope — mirrors the sim backend's roofline ledger charge for
    /// charge. `None` when neither the backend nor the controller
    /// config carries a profile.
    pub projected_ms: Option<f64>,
    /// Full-rank counterfactual projection of the same step.
    pub projected_full_ms: Option<f64>,
    /// True when this call re-ran the policy (segment boundary).
    pub fresh_decision: bool,
}

/// Decision record for the dense full-rank path (no controller state).
pub fn full_rank_decision(n: usize, d: usize, profile: Option<&DeviceProfile>) -> Decision {
    let full = flops::full_attention_flops(n, d);
    let projected = profile.map(|p| project_latency_ms(full, p));
    Decision {
        rank: n,
        prev_rank: n,
        masked_by_safety: false,
        perturbation: 0.0,
        flops_spent: full,
        flops_full: full,
        projected_ms: projected,
        projected_full_ms: projected,
        fresh_decision: true,
    }
}

/// Where a planned step's decomposition comes from.
pub enum ProbeSource {
    /// Segment boundary (or cold stream): run a fresh probe + truncated
    /// SVD with this cache seed during the probe wave.
    Refresh { cache_seed: u64 },
    /// Reuse the stream's committed factors (an O(1) shared handle; the
    /// decide stage re-reads the stream under its lock, so commits from
    /// batches decided in between are honored in decide order).
    Snapshot(Arc<Svd>),
    /// Reuse the probe of an earlier step (index into the same plan) —
    /// a later co-batched request riding on a refresh that an earlier
    /// request in the same drained batch will compute.
    Earlier(usize),
}

/// Per-stream bookkeeping for one head occurrence of a plan, captured
/// under the shard lock before the lock-free probe wave.
pub struct StepPlan {
    pub head: usize,
    /// Stream call counter at this occurrence (pre-increment value).
    pub calls: u64,
    /// True when this occurrence re-runs the policy.
    pub boundary: bool,
    pub probe: ProbeSource,
}

/// Stateless probe stage for one refresh step: the attention-score
/// matrix and its truncated SVD at `bucket_max`, computed exactly as a
/// boundary refresh always has (a fresh incremental cache seeded with
/// `cache_seed` → the same randomized sketch). The shared handle both
/// resolves the step and commits into the stream.
pub fn probe_head(inp: &AttnInputs, cache_seed: u64, bucket_max: usize) -> Arc<Svd> {
    let a = attention_matrix(inp);
    let mut cache = IncrementalCache::new(cache_seed);
    Arc::new(cache.decompose(&a, bucket_max).clone())
}

/// Resolve every planned step to its decomposition: refresh steps take
/// their probe-wave results (`probed`, aligned with `refresh_idx`),
/// snapshots and `Earlier` shares are O(1) handle clones. Shared by the
/// engine pipeline and [`RankController::attention_heads_batched`] so
/// the two paths cannot drift.
pub fn resolve_probes(
    steps: &[StepPlan],
    refresh_idx: &[usize],
    probed: Vec<Arc<Svd>>,
) -> Vec<Arc<Svd>> {
    let mut svds: Vec<Option<Arc<Svd>>> = (0..steps.len()).map(|_| None).collect();
    for (&i, svd) in refresh_idx.iter().zip(probed) {
        svds[i] = Some(svd);
    }
    for (i, step) in steps.iter().enumerate() {
        match &step.probe {
            ProbeSource::Refresh { .. } => {}
            ProbeSource::Snapshot(svd) => svds[i] = Some(Arc::clone(svd)),
            ProbeSource::Earlier(j) => {
                let svd = Arc::clone(svds[*j].as_ref().expect("earlier refresh resolved"));
                svds[i] = Some(svd);
            }
        }
    }
    svds.into_iter().map(|s| s.expect("every step resolved")).collect()
}

/// Lock-held inputs shared by the decide stage of one request.
pub struct DecideCtx<'a> {
    pub reg: &'a ArtifactRegistry,
    /// Layer input activations of the request being replayed (for h_t).
    pub x_layer: &'a Mat,
    pub w: &'a MhsaWeights,
    pub layer: usize,
    pub n_layers: usize,
}

/// The controller.
///
/// Multi-worker engines shard controllers per layer (one instance behind
/// a `Mutex` per layer) and share one `PolicySource` through the `Arc`,
/// so rank decisions stay coherent while different layers decide in
/// parallel. Stream keys include the layer, so a sharded deployment sees
/// exactly the same per-stream seeds and state a single controller would.
pub struct RankController {
    pub cfg: ControllerConfig,
    pub source: Arc<PolicySource>,
    pub trust: TrustRegion,
    conv: ConvFeaturizer,
    streams: BTreeMap<u64, StreamState>,
    rng: Pcg32,
    /// Rank trace per layer (Fig 3): (layer, segment_index, rank).
    pub rank_trace: Vec<(usize, u64, usize)>,
    /// Transition counts over the grid (Fig 5 overlay).
    pub transition_counts: Vec<Vec<u64>>,
}

impl RankController {
    pub fn new(cfg: ControllerConfig, source: PolicySource) -> Self {
        Self::with_shared_source(cfg, Arc::new(source))
    }

    /// Controller sharing a `PolicySource` with sibling shards (the
    /// multi-worker engine builds one controller per layer this way).
    pub fn with_shared_source(cfg: ControllerConfig, source: Arc<PolicySource>) -> Self {
        let n = cfg.rank_grid.len();
        RankController {
            trust: TrustRegion::new(cfg.epsilon0, cfg.lambda),
            conv: ConvFeaturizer::new(cfg.seed ^ 0xC0117),
            streams: BTreeMap::new(),
            rng: Pcg32::seeded(cfg.seed),
            rank_trace: Vec::new(),
            transition_counts: vec![vec![0; n]; n],
            cfg,
            source,
        }
    }

    fn stream_key(layer: usize, head: usize) -> u64 {
        ((layer as u64) << 16) | head as u64
    }

    /// Largest grid rank (the probe decomposes to its bucket).
    pub fn r_max(&self) -> usize {
        *self.cfg.rank_grid.iter().max().expect("non-empty rank grid")
    }

    /// The profile decisions project latency onto — the registry's
    /// single precedence rule applied to this controller's config.
    pub fn projection_profile(&self, reg: &ArtifactRegistry) -> Option<DeviceProfile> {
        reg.projection_profile(self.cfg.reward_profile)
    }

    /// Pick a rank for the state/spectrum under the safety mask.
    fn pick_rank(
        &mut self,
        state: &RankState,
        spectrum: &[f64],
        prev_rank: usize,
        reg: &ArtifactRegistry,
    ) -> Result<(usize, bool)> {
        let grid = self.cfg.rank_grid.clone();
        // Safety mask (Eq. 9/11): assess every candidate transition.
        let mask: Vec<bool> = if self.cfg.use_trust_region {
            let assessments: Vec<_> = grid
                .iter()
                .map(|&r| assess_transition(spectrum, prev_rank, r, 1.0))
                .collect();
            let mut m = self.trust.mask_actions(prev_rank, &assessments);
            if !m.iter().any(|&b| b) {
                let closest = grid
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, &r)| r.abs_diff(prev_rank))
                    .map(|(i, _)| i)
                    .unwrap();
                m[closest] = true;
            }
            m
        } else {
            vec![true; grid.len()]
        };
        self.trust.tick();
        let any_masked = mask.iter().any(|&b| !b);

        let idx = match self.source.as_ref() {
            PolicySource::Hlo => {
                let logits = reg.policy_logits(&state.features)?;
                argmax_masked(&logits, &mask)
            }
            PolicySource::Actor(ac) => {
                let dist = ac.distribution(&state.features, Some(&mask));
                dist.argmax()
            }
            PolicySource::Fixed(r) => nearest_open(&grid, *r, &mask),
            PolicySource::AdaptiveEnergy(th) => {
                let wanted = crate::spectral::rank_for_energy(spectrum, *th);
                nearest_open(&grid, wanted, &mask)
            }
            PolicySource::SoftThreshold(tau) => {
                let wanted = crate::spectral::soft_threshold_rank(spectrum, *tau);
                nearest_open(&grid, wanted, &mask)
            }
            PolicySource::Random => {
                let open: Vec<usize> =
                    mask.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect();
                open[self.rng.range(0, open.len())]
            }
            PolicySource::FullRank => grid.len() - 1,
        };
        Ok((grid[idx], any_masked && !mask[idx]))
    }

    /// Plan stage: advance per-stream segment counters for a sequence of
    /// head occurrences (the replay order — for the engine pipeline,
    /// request-arrival-major, head-minor) and record where each
    /// occurrence's decomposition will come from. Must run under the
    /// same shard lock discipline as `decide_step`; it is the only other
    /// controller entry point that touches stream state.
    pub fn plan_steps(&mut self, layer: usize, heads: &[usize]) -> Vec<StepPlan> {
        let seg = self.cfg.segment_len as u64;
        // Latest in-plan refresh per stream: later non-boundary
        // occurrences of the same stream ride on it (the cross-request
        // analogue of "the cached factors serve between boundaries").
        let mut pending: BTreeMap<u64, usize> = BTreeMap::new();
        let mut steps = Vec::with_capacity(heads.len());
        for (i, &h) in heads.iter().enumerate() {
            let key = Self::stream_key(layer, h);
            let seed = self.cfg.seed ^ key;
            let entry = self.streams.entry(key).or_default();
            let calls = entry.calls;
            entry.calls += 1;
            let boundary = if seg == 0 { calls == 0 } else { calls % seg == 0 };
            let probe = if boundary || (entry.probe.is_none() && !pending.contains_key(&key)) {
                pending.insert(key, i);
                ProbeSource::Refresh { cache_seed: seed }
            } else if let Some(&j) = pending.get(&key) {
                ProbeSource::Earlier(j)
            } else {
                let svd = Arc::clone(
                    entry
                        .probe
                        .as_ref()
                        .expect("stream holds a decomposition between boundaries"),
                );
                ProbeSource::Snapshot(svd)
            };
            steps.push(StepPlan { head: h, calls, boundary, probe });
        }
        steps
    }

    /// Commit a probe-wave decomposition into its stream. Callers run
    /// this for *every* refresh step of a replay group before replaying
    /// any of the group's decisions: a decision error must not
    /// un-publish factors that later co-batched steps were planned
    /// against, or the pipeline would diverge from sequential serving on
    /// error paths.
    pub fn commit_probe(&mut self, layer: usize, head: usize, probe: Arc<Svd>) {
        self.streams
            .get_mut(&Self::stream_key(layer, head))
            .expect("stream planned before commit")
            .probe = Some(probe);
    }

    /// The stream's latest committed decomposition (O(1) shared handle).
    /// The decide stage re-reads `Snapshot` steps through this under the
    /// shard lock so factors and previous-rank chains stay consistent in
    /// decide order.
    pub fn stream_probe(&self, layer: usize, head: usize) -> Option<Arc<Svd>> {
        self.streams.get(&Self::stream_key(layer, head)).and_then(|s| s.probe.clone())
    }

    /// Decide stage for one planned occurrence: read the stream's
    /// previous rank *now* — so replays see the decisions of earlier
    /// co-batched requests — run the policy at boundaries, and advance
    /// stream state. Serial, lock-held; replay order is the correctness
    /// invariant. Refresh probes must already be committed via
    /// [`Self::commit_probe`].
    pub fn decide_step(
        &mut self,
        ctx: &DecideCtx<'_>,
        step: &StepPlan,
        svd: &Svd,
        n: usize,
        d: usize,
    ) -> Result<Decision> {
        let key = Self::stream_key(ctx.layer, step.head);
        let default_rank = self.cfg.rank_grid[self.cfg.rank_grid.len() / 2];
        let prev_rank = self
            .streams
            .get(&key)
            .and_then(|s| s.prev_rank)
            .unwrap_or(default_rank);
        let r_max = self.r_max();
        let (rank, masked, fresh) = if step.boundary {
            let state = featurize(
                &self.conv,
                ctx.x_layer,
                ctx.w,
                &svd.s,
                prev_rank,
                r_max,
                ctx.layer,
                ctx.n_layers,
            );
            let (r, m) = self.pick_rank(&state, &svd.s, prev_rank, ctx.reg)?;
            (r, m, true)
        } else {
            (prev_rank, false, false)
        };

        // Perturbation of the executed transition (Eq. 4).
        let perturbation =
            crate::spectral::rank_transition_perturbation(&svd.s, prev_rank, rank);

        if fresh {
            let grid = &self.cfg.rank_grid;
            if let (Some(fi), Some(ti)) = (
                grid.iter().position(|&g| g == prev_rank),
                grid.iter().position(|&g| g == rank),
            ) {
                self.transition_counts[fi][ti] += 1;
            }
            let seg = self.cfg.segment_len as u64;
            self.rank_trace.push((ctx.layer, step.calls / seg.max(1), rank));
        }

        // FLOPs ledger: the kernel part is charged at the rank's
        // *compiled bucket* — the masked factor apply always runs full
        // bucket-width matmuls, so charging the requested rank would
        // understate what the backend executes (and disagree with the
        // sim backend's roofline charges). The probe amortizes over the
        // segment.
        let bucket = ctx.reg.rank_bucket(rank);
        let kernel_flops = flops::lowrank_attention_flops(n, d, bucket, false);
        let bucket_max = ctx.reg.rank_bucket(r_max);
        let amortize = self.cfg.segment_len.max(1) as u64;
        let spent = kernel_flops + flops::partial_svd_flops(n, n, bucket_max) / amortize;

        // Projected-latency attribution: mirror exactly the charges this
        // step drives into the backend — the factor apply at the bucket
        // and, at boundaries on the Hlo source, one policy-net call. The
        // host-side probe is not a backend op and is deliberately absent,
        // so the per-request ledger matches the sim backend's to 1e-9.
        let profile = self.projection_profile(ctx.reg);
        let projected_ms = profile.map(|p| {
            let mut ms = project_latency_ms(kernel_flops, &p);
            if fresh && matches!(self.source.as_ref(), PolicySource::Hlo) {
                let pol = &ctx.reg.manifest.policy;
                ms += project_latency_ms(
                    flops::policy_overhead_flops(pol.state_dim, pol.d_model, pol.n_actions),
                    &p,
                );
            }
            ms
        });
        let projected_full_ms =
            profile.map(|p| project_latency_ms(flops::full_attention_flops(n, d), &p));

        self.streams
            .get_mut(&key)
            .expect("stream planned before decide")
            .prev_rank = Some(rank);
        Ok(Decision {
            rank,
            prev_rank,
            masked_by_safety: masked,
            perturbation,
            flops_spent: spent,
            flops_full: flops::full_attention_flops(n, d),
            projected_ms,
            projected_full_ms,
            fresh_decision: fresh,
        })
    }

    /// Serve one head's attention for a segment step. Returns the output
    /// and the decision record. `x_layer` is the layer input (for h_t).
    /// Thin wrapper over [`Self::attention_heads_batched`] so the single-
    /// head path (benches, oracle) and the engine's batched path cannot
    /// drift.
    #[allow(clippy::too_many_arguments)]
    pub fn attention(
        &mut self,
        reg: &ArtifactRegistry,
        x_layer: &Mat,
        w: &MhsaWeights,
        inp: &AttnInputs,
        layer: usize,
        head: usize,
        n_layers: usize,
    ) -> Result<(Mat, Decision)> {
        let mut out =
            self.attention_heads_batched(reg, x_layer, w, &[(head, inp)], layer, n_layers)?;
        Ok(out.remove(0))
    }

    /// Serve one segment step for several heads of a layer at once,
    /// driving the same plan → probe → decide → apply stages the engine
    /// pipeline composes across requests. Probe and apply fan out over
    /// the global thread pool in one dispatch each; decisions replay
    /// serially in head order, so results are bit-identical to calling
    /// [`Self::attention`] per head.
    ///
    /// `heads` pairs each head index with its projected Q/K/V inputs.
    pub fn attention_heads_batched(
        &mut self,
        reg: &ArtifactRegistry,
        x_layer: &Mat,
        w: &MhsaWeights,
        heads: &[(usize, &AttnInputs)],
        layer: usize,
        n_layers: usize,
    ) -> Result<Vec<(Mat, Decision)>> {
        if heads.is_empty() {
            return Ok(Vec::new());
        }

        // FULL-RANK short-circuit: dense kernel per head, fanned out.
        if matches!(self.source.as_ref(), PolicySource::FullRank) {
            let outs = global_pool().scoped_map(heads.len(), |i| {
                let inp = heads[i].1;
                reg.full_attention(&inp.q, &inp.k, &inp.v)
            });
            let profile = self.projection_profile(reg);
            let mut result = Vec::with_capacity(heads.len());
            for (y, &(_, inp)) in outs.into_iter().zip(heads) {
                result.push((
                    y?,
                    full_rank_decision(inp.seq_len(), inp.head_dim(), profile.as_ref()),
                ));
            }
            return Ok(result);
        }

        let bucket_max = reg.rank_bucket(self.r_max());

        // Plan — per-stream bookkeeping (cheap).
        let head_ids: Vec<usize> = heads.iter().map(|&(h, _)| h).collect();
        let steps = self.plan_steps(layer, &head_ids);

        // Probe — one pooled dispatch over every refresh step.
        let refresh: Vec<usize> = steps
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s.probe, ProbeSource::Refresh { .. }))
            .map(|(i, _)| i)
            .collect();
        let probed = {
            let steps_ref = &steps;
            let refresh_ref = &refresh;
            global_pool().scoped_map(refresh_ref.len(), |j| {
                let i = refresh_ref[j];
                match &steps_ref[i].probe {
                    ProbeSource::Refresh { cache_seed } => {
                        probe_head(heads[i].1, *cache_seed, bucket_max)
                    }
                    _ => unreachable!("refresh indices point at refresh steps"),
                }
            })
        };
        let mut svds = resolve_probes(&steps, &refresh, probed);

        // Decide — serial in head order so the trust-region tick and
        // policy RNG sequences match the serial controller. Same replay
        // rule as the engine pipeline: each fresh probe commits at its
        // own replay position (never earlier — a Snapshot step at a
        // lower call must not observe a later refresh) and even after a
        // decision error (probes of aborted requests stay published);
        // Snapshot steps re-read the stream (a no-op here, where the
        // caller holds the controller exclusively).
        let mut decisions: Vec<Decision> = Vec::with_capacity(steps.len());
        let mut failed: Option<anyhow::Error> = None;
        for (i, step) in steps.iter().enumerate() {
            let inp = heads[i].1;
            if matches!(step.probe, ProbeSource::Refresh { .. }) {
                self.commit_probe(layer, step.head, Arc::clone(&svds[i]));
            } else if matches!(step.probe, ProbeSource::Snapshot(_)) {
                if let Some(p) = self.stream_probe(layer, step.head) {
                    svds[i] = p;
                }
            }
            if failed.is_some() {
                continue;
            }
            let ctx = DecideCtx { reg, x_layer, w, layer, n_layers };
            match self.decide_step(&ctx, step, &svds[i], inp.seq_len(), inp.head_dim()) {
                Ok(dec) => decisions.push(dec),
                Err(e) => failed = Some(e),
            }
        }
        if let Some(e) = failed {
            return Err(e);
        }

        // Apply — one pooled dispatch of masked factor applies.
        let outs = {
            let svds_ref = &svds;
            let dec_ref = &decisions;
            global_pool().scoped_map(steps.len(), |i| {
                reg.lowrank_attention(&svds_ref[i], dec_ref[i].rank, &heads[i].1.v)
            })
        };
        let mut result = Vec::with_capacity(steps.len());
        for (y, dec) in outs.into_iter().zip(decisions) {
            result.push((y?, dec));
        }
        Ok(result)
    }
}

fn argmax_masked(logits: &[f64], mask: &[bool]) -> usize {
    logits
        .iter()
        .enumerate()
        .filter(|(i, _)| mask[*i])
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .expect("at least one open action")
}

fn nearest_open(grid: &[usize], target: usize, mask: &[bool]) -> usize {
    grid.iter()
        .enumerate()
        .filter(|(i, _)| mask[*i])
        .min_by_key(|(_, &r)| r.abs_diff(target))
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_argmax_skips_closed() {
        let logits = [5.0, 1.0, 3.0];
        assert_eq!(argmax_masked(&logits, &[false, true, true]), 2);
        assert_eq!(argmax_masked(&logits, &[true, true, true]), 0);
    }

    #[test]
    fn nearest_open_prefers_close_rank() {
        let grid = [16, 32, 64];
        assert_eq!(nearest_open(&grid, 30, &[true, true, true]), 1);
        assert_eq!(nearest_open(&grid, 30, &[true, false, true]), 0);
    }

    #[test]
    fn policy_source_names() {
        assert_eq!(PolicySource::Hlo.name(), "hlo-policy");
        assert_eq!(PolicySource::Fixed(32).name(), "fixed");
        assert_eq!(PolicySource::SoftThreshold(0.3).name(), "soft-threshold");
    }

    #[test]
    fn soft_threshold_source_serves_and_counts_flops() {
        let reg = ArtifactRegistry::open_host(64, 16);
        let cfg = ControllerConfig { use_trust_region: false, ..Default::default() };
        let mut c = RankController::new(cfg, PolicySource::SoftThreshold(0.5));
        let mut rng = Pcg32::seeded(12);
        let x = Mat::randn(64, 16, 1.0, &mut rng);
        let w = MhsaWeights::init(16, 1, &mut rng);
        let heads = crate::attention::project_heads(&x, &w, true);
        let (y, dec) = c
            .attention(&reg, &x, &w, &heads[0], 0, 0, 1)
            .expect("controller attention");
        assert_eq!((y.rows(), y.cols()), (64, 16));
        assert!(c.cfg.rank_grid.contains(&dec.rank), "rank {} on grid", dec.rank);
        assert!(dec.flops_spent < dec.flops_full, "low-rank path must save FLOPs");
    }

    #[test]
    fn plan_steps_links_cross_request_reuse() {
        // Three same-stream occurrences with segment_len=2: call 0 is a
        // boundary refresh, call 1 rides on it (Earlier), call 2 is the
        // next boundary refresh.
        let cfg = ControllerConfig { segment_len: 2, ..Default::default() };
        let mut c = RankController::new(cfg, PolicySource::Fixed(32));
        let steps = c.plan_steps(0, &[3, 3, 3]);
        assert_eq!(steps.len(), 3);
        assert!(steps[0].boundary && matches!(steps[0].probe, ProbeSource::Refresh { .. }));
        assert!(!steps[1].boundary);
        assert!(matches!(steps[1].probe, ProbeSource::Earlier(0)));
        assert!(steps[2].boundary && matches!(steps[2].probe, ProbeSource::Refresh { .. }));
        assert_eq!((steps[0].calls, steps[1].calls, steps[2].calls), (0, 1, 2));
    }

    #[test]
    fn plan_steps_snapshots_committed_probe() {
        // After a replay commits the refresh probe, a later non-boundary
        // plan resolves to a Snapshot of the committed factors — and the
        // snapshot shares the handle instead of copying them.
        let cfg = ControllerConfig { segment_len: 4, ..Default::default() };
        let mut c = RankController::new(cfg, PolicySource::Fixed(32));
        let first = c.plan_steps(1, &[0]);
        assert!(matches!(first[0].probe, ProbeSource::Refresh { .. }));
        let mut rng = crate::util::Pcg32::seeded(9);
        let a = Mat::randn(16, 16, 1.0, &mut rng);
        let probe = Arc::new(crate::linalg::top_k_svd(&a, 8, 3));
        c.commit_probe(1, 0, Arc::clone(&probe));
        assert!(c.stream_probe(1, 0).is_some());
        let second = c.plan_steps(1, &[0]);
        assert!(!second[0].boundary);
        match &second[0].probe {
            ProbeSource::Snapshot(svd) => {
                assert!(Arc::ptr_eq(svd, &probe), "snapshot must share, not copy");
            }
            _ => panic!("expected a snapshot"),
        }
    }

    #[test]
    fn full_rank_decision_spends_full_flops() {
        let d = full_rank_decision(64, 16, None);
        assert_eq!(d.rank, 64);
        assert_eq!(d.flops_spent, d.flops_full);
        assert!(d.fresh_decision && !d.masked_by_safety);
        assert!(d.projected_ms.is_none() && d.projected_full_ms.is_none());

        let p = DeviceProfile::A100;
        let dp = full_rank_decision(64, 16, Some(&p));
        let want = project_latency_ms(flops::full_attention_flops(64, 16), &p);
        assert_eq!(dp.projected_ms, Some(want));
        assert_eq!(dp.projected_full_ms, Some(want));
    }

    #[test]
    fn decide_step_charges_executed_bucket_widths() {
        // Grid rank 40 executes in the 48-wide compiled bucket: the
        // FLOPs ledger and the latency projection must price the bucket,
        // not the requested rank (regression for the metrics-vs-sim
        // ledger disagreement).
        let reg = ArtifactRegistry::open_host(64, 16);
        assert_eq!(reg.rank_bucket(40), 48);
        let cfg = ControllerConfig {
            reward_profile: Some(DeviceProfile::CPU_DEFAULT),
            ..Default::default()
        };
        let mut c = RankController::new(cfg, PolicySource::Fixed(40));
        let mut rng = Pcg32::seeded(4);
        let x = Mat::randn(64, 16, 1.0, &mut rng);
        let w = MhsaWeights::init(16, 1, &mut rng);
        let heads = crate::attention::project_heads(&x, &w, true);
        let inp = &heads[0];
        let (_, dec) = c
            .attention(&reg, &x, &w, inp, 0, 0, 1)
            .expect("controller attention");
        assert_eq!(dec.rank, 40);
        let n = inp.seq_len();
        let d = inp.head_dim();
        let kernel = flops::lowrank_attention_flops(n, d, 48, false);
        let amortized = flops::partial_svd_flops(n, n, reg.rank_bucket(64))
            / c.cfg.segment_len as u64;
        assert_eq!(dec.flops_spent, kernel + amortized, "bucket width, not rank 40");
        let want_ms = project_latency_ms(kernel, &DeviceProfile::CPU_DEFAULT);
        assert_eq!(dec.projected_ms, Some(want_ms));
    }

    // Device-backed integration tests live in rust/tests/serving.rs; the
    // batched-vs-serial equality test lives in
    // rust/tests/engine_concurrency.rs.
}
