//! Model/experiment configuration system: typed configs loadable from
//! JSON files or CLI overrides, shared by the launcher, examples and
//! benches.

pub mod config;

pub use config::{ExperimentConfig, LmModelConfig, ServingConfig};
