//! Typed configuration with JSON loading and CLI overrides.
//!
//! Precedence: defaults < JSON file (`--config path`) < CLI flags.

use crate::util::{Args, Json};
use anyhow::{Context, Result};
use std::path::Path;

/// LM shape used by Rust-side experiment models (the AOT LM's shape
/// lives in the artifact manifest; this config governs host-side
/// simulation models in the benches).
#[derive(Debug, Clone, PartialEq)]
pub struct LmModelConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub rank_grid: Vec<usize>,
}

impl Default for LmModelConfig {
    fn default() -> Self {
        LmModelConfig {
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            seq_len: 128,
            rank_grid: vec![16, 24, 32, 40, 48, 56, 64],
        }
    }
}

/// Serving engine knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    pub n_engines: usize,
    pub max_batch: usize,
    pub max_wait_ms: u64,
    pub queue_capacity: usize,
    pub segment_len: usize,
    pub use_trust_region: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            n_engines: 1,
            max_batch: 8,
            max_wait_ms: 5,
            queue_capacity: 1024,
            segment_len: 16,
            use_trust_region: true,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExperimentConfig {
    pub model: LmModelConfig,
    pub serving: ServingConfig,
    pub seed: u64,
}

impl ExperimentConfig {
    /// Load from JSON text (partial configs fine — missing keys default).
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        if let Some(m) = j.get("model") {
            let d = &mut cfg.model;
            set_usize(m, "d_model", &mut d.d_model);
            set_usize(m, "n_layers", &mut d.n_layers);
            set_usize(m, "n_heads", &mut d.n_heads);
            set_usize(m, "seq_len", &mut d.seq_len);
            if let Some(g) = m.get("rank_grid").and_then(|a| a.as_arr()) {
                d.rank_grid = g.iter().filter_map(|x| x.as_usize()).collect();
            }
        }
        if let Some(s) = j.get("serving") {
            let d = &mut cfg.serving;
            set_usize(s, "n_engines", &mut d.n_engines);
            set_usize(s, "max_batch", &mut d.max_batch);
            set_usize(s, "queue_capacity", &mut d.queue_capacity);
            set_usize(s, "segment_len", &mut d.segment_len);
            if let Some(v) = s.get("max_wait_ms").and_then(|x| x.as_f64()) {
                d.max_wait_ms = v as u64;
            }
            if let Some(v) = s.get("use_trust_region").and_then(|x| x.as_bool()) {
                d.use_trust_region = v;
            }
        }
        if let Some(v) = j.get("seed").and_then(|x| x.as_f64()) {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&text)
    }

    /// Apply CLI overrides (`--d-model`, `--n-layers`, `--seq-len`,
    /// `--ranks`, `--engines`, `--max-batch`, `--seed`, …).
    pub fn apply_args(mut self, args: &Args) -> Self {
        self.model.d_model = args.usize_or("d-model", self.model.d_model);
        self.model.n_layers = args.usize_or("n-layers", self.model.n_layers);
        self.model.n_heads = args.usize_or("n-heads", self.model.n_heads);
        self.model.seq_len = args.usize_or("seq-len", self.model.seq_len);
        self.model.rank_grid = args.usize_list_or("ranks", &self.model.rank_grid);
        self.serving.n_engines = args.usize_or("engines", self.serving.n_engines);
        self.serving.max_batch = args.usize_or("max-batch", self.serving.max_batch);
        self.serving.max_wait_ms = args.u64_or("max-wait-ms", self.serving.max_wait_ms);
        self.serving.segment_len = args.usize_or("segment-len", self.serving.segment_len);
        if args.flag("no-trust-region") {
            self.serving.use_trust_region = false;
        }
        self.seed = args.u64_or("seed", self.seed);
        self
    }

    /// Resolve from CLI: optional `--config file.json` plus overrides.
    pub fn resolve(args: &Args) -> Result<Self> {
        let base = match args.get("config") {
            Some(p) => Self::from_file(Path::new(p))?,
            None => Self::default(),
        };
        Ok(base.apply_args(args))
    }
}

fn set_usize(j: &Json, key: &str, out: &mut usize) {
    if let Some(v) = j.get(key).and_then(|x| x.as_usize()) {
        *out = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.model.d_model % c.model.n_heads, 0);
        assert!(!c.model.rank_grid.is_empty());
    }

    #[test]
    fn json_partial_override() {
        let c = ExperimentConfig::from_json(
            r#"{"model": {"d_model": 128, "rank_grid": [8, 16]}, "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(c.model.d_model, 128);
        assert_eq!(c.model.rank_grid, vec![8, 16]);
        assert_eq!(c.model.n_layers, 4); // default preserved
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn cli_overrides_json() {
        let args = Args::parse_from(
            ["x", "--d-model", "256", "--no-trust-region", "--ranks", "4,8"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let c = ExperimentConfig::default().apply_args(&args);
        assert_eq!(c.model.d_model, 256);
        assert!(!c.serving.use_trust_region);
        assert_eq!(c.model.rank_grid, vec![4, 8]);
    }

    #[test]
    fn bad_json_is_error() {
        assert!(ExperimentConfig::from_json("{nope").is_err());
    }
}
