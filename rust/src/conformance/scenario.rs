//! Seeded scenario generation for the differential conformance fuzzer.
//!
//! One `u64` seed deterministically expands into a full serving
//! scenario: kernel shape, layer stack, rank grid, segment length,
//! policy source, batching knobs, worker counts, a device profile for
//! the sim pairing, and the request trace itself. Every differential
//! check replays the *same* scenario through paired execution paths, so
//! a failure always reprints its seed as a one-command reproduction.

use crate::attention::MhsaWeights;
use crate::coordinator::{BatchPolicy, ControllerConfig, PolicySource};
use crate::linalg::{Mat, ProbeKernel};
use crate::sim::DeviceProfile;
use crate::util::Pcg32;
use std::time::Duration;

/// Policy generators the fuzzer draws rank schedules from. Each is
/// deterministic given the probe spectrum (no RNG, no cross-stream
/// state), so identical traces produce identical schedules on every
/// paired path — the property the bit-identity checks are defined over.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyKind {
    Fixed(usize),
    AdaptiveEnergy(f64),
    /// Soft-thresholding schedule (SoftLMs, arXiv:2411.10543) — the
    /// third rank-schedule generator.
    SoftThreshold(f64),
    FullRank,
}

impl PolicyKind {
    /// A fresh `PolicySource` (the source is not `Clone`; every engine
    /// of a pairing gets its own, built from the same scenario).
    pub fn source(&self) -> PolicySource {
        match *self {
            PolicyKind::Fixed(r) => PolicySource::Fixed(r),
            PolicyKind::AdaptiveEnergy(th) => PolicySource::AdaptiveEnergy(th),
            PolicyKind::SoftThreshold(tau) => PolicySource::SoftThreshold(tau),
            PolicyKind::FullRank => PolicySource::FullRank,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fixed(_) => "fixed",
            PolicyKind::AdaptiveEnergy(_) => "adaptive-energy",
            PolicyKind::SoftThreshold(_) => "soft-threshold",
            PolicyKind::FullRank => "full-rank",
        }
    }
}

/// One fully-expanded fuzz scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub seed: u64,
    /// Kernel sequence length (= request n).
    pub n: usize,
    pub head_dim: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    /// Rank grid the controllers decide over (subset of the default
    /// grid; max entry ≤ n so every probe fits the attention matrix).
    pub rank_grid: Vec<usize>,
    pub segment_len: usize,
    pub use_trust_region: bool,
    pub policy: PolicyKind,
    /// Worker count for the multi-worker side of the N-vs-1 pairing.
    pub n_workers: usize,
    pub max_batch: usize,
    pub overdrain: usize,
    /// Device profile for the host-vs-sim pairing's sim side.
    pub profile: DeviceProfile,
    /// Target layer per request, in submission order.
    pub request_layers: Vec<usize>,
    /// Which kernel path the probe's matmuls take on this scenario's
    /// side of the fused-vs-direct differential (`probe_kernel_failures`
    /// exercises both regardless; this knob varies the subspace-iteration
    /// depth the pairing runs at).
    pub probe_kernel: ProbeKernel,
}

impl Scenario {
    /// Expand a seed into a scenario. Pure: same seed, same scenario.
    pub fn generate(seed: u64) -> Scenario {
        let mut rng = Pcg32::new(seed, 0xfe2d_c0de);
        let n = 64;
        let head_dim = [8usize, 16][rng.below(2) as usize];
        let n_heads = 1 + rng.below(2) as usize;
        let n_layers = 1 + rng.below(3) as usize;

        // Random subset (≥ 2 entries) of the default grid, kept sorted.
        let full_grid = ControllerConfig::default().rank_grid;
        let mut rank_grid: Vec<usize> =
            full_grid.iter().copied().filter(|_| rng.below(2) == 0).collect();
        while rank_grid.len() < 2 {
            let r = full_grid[rng.below(full_grid.len() as u32) as usize];
            if !rank_grid.contains(&r) {
                rank_grid.push(r);
            }
        }
        rank_grid.sort_unstable();

        // Weighted toward 1 so the order-insensitive pairings (N-vs-1
        // workers, schedule perturbation) run often.
        let segment_len = [1usize, 1, 2, 3][rng.below(4) as usize];
        let use_trust_region = rng.below(4) == 0;

        let policy = match rng.below(4) {
            0 => PolicyKind::Fixed(rank_grid[rng.below(rank_grid.len() as u32) as usize]),
            1 => PolicyKind::AdaptiveEnergy(rng.uniform(0.7, 0.99)),
            2 => PolicyKind::SoftThreshold(rng.uniform(0.05, 0.6)),
            _ => PolicyKind::FullRank,
        };

        let n_workers = 2 + rng.below(3) as usize;
        let max_batch = 2 + rng.below(4) as usize;
        let overdrain = rng.below(1 + max_batch as u32) as usize;
        let profile = DeviceProfile::BUILTIN[rng.below(3) as usize];

        let n_requests = 4 + rng.below(7) as usize;
        let request_layers =
            (0..n_requests).map(|_| rng.below(n_layers as u32) as usize).collect();

        // Drawn LAST so every earlier field keeps its pre-existing
        // seed→value mapping (pinned fuzz corpora stay meaningful).
        let probe_kernel =
            if rng.below(2) == 0 { ProbeKernel::Fused } else { ProbeKernel::Direct };

        Scenario {
            seed,
            n,
            head_dim,
            n_heads,
            n_layers,
            rank_grid,
            segment_len,
            use_trust_region,
            policy,
            n_workers,
            max_batch,
            overdrain,
            profile,
            request_layers,
            probe_kernel,
        }
    }

    pub fn d_model(&self) -> usize {
        self.head_dim * self.n_heads
    }

    pub fn n_requests(&self) -> usize {
        self.request_layers.len()
    }

    /// The i-th request's input activations (n × d_model, row-major) —
    /// derived from a per-request RNG stream so every paired engine sees
    /// byte-identical inputs.
    pub fn request_input(&self, i: usize) -> Vec<f64> {
        let mut rng = Pcg32::new(self.seed ^ 0x1269_7a11, i as u64);
        Mat::randn(self.n, self.d_model(), 1.0, &mut rng).into_vec()
    }

    /// The frozen layer stack every engine of a pairing starts with.
    pub fn layers(&self) -> Vec<MhsaWeights> {
        let mut rng = Pcg32::new(self.seed ^ 0x11A7_ee15, 7);
        (0..self.n_layers)
            .map(|_| MhsaWeights::init(self.d_model(), self.n_heads, &mut rng))
            .collect()
    }

    pub fn controller_config(&self) -> ControllerConfig {
        ControllerConfig {
            rank_grid: self.rank_grid.clone(),
            use_trust_region: self.use_trust_region,
            segment_len: self.segment_len,
            seed: self.seed ^ 0xC011,
            ..Default::default()
        }
    }

    pub fn batch_policy(&self, max_batch: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(2),
            capacity: 4096,
            overdrain: self.overdrain,
        }
    }

    /// True when the scenario's rank schedule is independent of the
    /// cross-request decide order: every call is a segment boundary and
    /// the trust region (whose mask depends on the previous rank chain)
    /// is off. Only such scenarios are compared across *different*
    /// worker counts or adversarial schedules; the other pairings hold
    /// the serialization fixed.
    pub fn order_insensitive(&self) -> bool {
        self.segment_len == 1 && !self.use_trust_region
    }

    /// Largest grid rank.
    pub fn r_max(&self) -> usize {
        *self.rank_grid.iter().max().expect("non-empty grid")
    }

    /// One-line summary for fuzz progress output.
    pub fn describe(&self) -> String {
        format!(
            "n={} d_head={} heads={} layers={} grid={:?} seg={} trust={} policy={} \
             workers={} max_batch={} overdrain={} profile={} requests={} probe={:?}",
            self.n,
            self.head_dim,
            self.n_heads,
            self.n_layers,
            self.rank_grid,
            self.segment_len,
            self.use_trust_region,
            self.policy.name(),
            self.n_workers,
            self.max_batch,
            self.overdrain,
            self.profile.name,
            self.n_requests(),
            self.probe_kernel,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = Scenario::generate(42);
        let b = Scenario::generate(42);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(a.request_input(3), b.request_input(3));
    }

    #[test]
    fn seeds_vary_the_scenario() {
        // Not a tautology: at least one of 16 consecutive seeds must
        // differ from seed 0 in its summary line.
        let base = Scenario::generate(0).describe();
        assert!((1..16).any(|s| Scenario::generate(s).describe() != base));
    }

    #[test]
    fn scenarios_are_well_formed() {
        for seed in 0..64 {
            let sc = Scenario::generate(seed);
            assert!(sc.rank_grid.len() >= 2);
            assert!(sc.r_max() <= sc.n, "grid must fit the attention matrix");
            assert!(sc.rank_grid.windows(2).all(|w| w[0] < w[1]), "sorted, deduped");
            assert!(!sc.request_layers.is_empty());
            assert!(sc.request_layers.iter().all(|&l| l < sc.n_layers));
            assert!(sc.n_workers >= 2);
            assert_eq!(sc.request_input(0).len(), sc.n * sc.d_model());
            assert_eq!(sc.layers().len(), sc.n_layers);
        }
    }

    #[test]
    fn all_policy_kinds_reachable() {
        let mut seen = [false; 4];
        for seed in 0..64 {
            match Scenario::generate(seed).policy {
                PolicyKind::Fixed(_) => seen[0] = true,
                PolicyKind::AdaptiveEnergy(_) => seen[1] = true,
                PolicyKind::SoftThreshold(_) => seen[2] = true,
                PolicyKind::FullRank => seen[3] = true,
            }
        }
        assert!(seen.iter().all(|&s| s), "64 seeds must cover every policy kind");
    }
}
