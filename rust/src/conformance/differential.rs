//! Differential execution checks: one seeded request trace, paired
//! execution paths, bit-exact comparison.
//!
//! Pairings (all driven by [`super::run_seed`]):
//!
//! * **host vs sim** — the same trace through a host-backend engine and
//!   a sim-backend engine. The sim backend delegates its arithmetic to
//!   the host kernels and only *adds* a roofline latency ledger, so
//!   outputs, ranks and the analytic FLOPs ledgers must agree bit for
//!   bit; additionally the per-request `projected_ms` attributions must
//!   sum to the sim ledger's charge to 1e-9.
//! * **co-batched vs serial** — submit the whole trace at once (the
//!   staged pipeline co-batches it) vs one request at a time on a
//!   single-worker engine. The pipeline's documented invariant is
//!   bit-identity.
//! * **N workers vs 1 worker** — only for order-insensitive scenarios
//!   (`segment_len == 1`, trust region off): rank schedules must not
//!   depend on how worker threads interleave.
//!
//! Independent of any pairing, every run checks the **FLOPs
//! conservation law**: each response's `flops_spent`/`flops_full` must
//! equal the analytic recomputation from its reported ranks (kernel
//! cost at the rank's compiled bucket plus the segment-amortized probe),
//! and **every ticket resolves** — success or typed error, never a hang.

use super::scenario::{PolicyKind, Scenario};
use crate::coordinator::{
    AttentionResponse, EngineConfig, EngineResult, PipelineHooks, ServingEngine, SubmitOptions,
};
use crate::flops;
use crate::runtime::ArtifactRegistry;
use std::sync::Arc;
use std::time::Duration;

/// Upper bound on any single wait — a conformance failure, not a hang.
const WAIT: Duration = Duration::from_secs(30);

/// Build one engine for a scenario. Callers choose worker count, batch
/// depth and hooks per pairing side; everything else comes from the
/// scenario so paired engines differ only in the axis under test.
pub fn build_engine(
    sc: &Scenario,
    reg: Arc<ArtifactRegistry>,
    n_workers: usize,
    max_batch: usize,
    hooks: PipelineHooks,
) -> ServingEngine {
    let lm_params = Arc::new(vec![0f32; reg.manifest.lm.param_count]);
    ServingEngine::start_with_config(
        reg,
        lm_params,
        sc.layers(),
        sc.controller_config(),
        sc.policy.source(),
        EngineConfig {
            n_workers,
            batch_policy: sc.batch_policy(max_batch),
            hooks,
        },
    )
}

/// Submit the scenario's whole trace, then wait for every ticket.
/// `None` entries mark tickets that failed to resolve within [`WAIT`] —
/// itself a conformance violation surfaced by the caller.
pub fn run_trace(
    sc: &Scenario,
    engine: &ServingEngine,
) -> Vec<Option<EngineResult<AttentionResponse>>> {
    let tickets: Vec<_> = (0..sc.n_requests())
        .map(|i| {
            engine.submit_attention_opts(
                sc.request_input(i),
                sc.n,
                sc.d_model(),
                sc.request_layers[i],
                SubmitOptions::default(),
            )
        })
        .collect();
    tickets
        .into_iter()
        .map(|t| match t {
            Ok(ticket) => ticket.wait_timeout(WAIT),
            Err(e) => Some(Err(e)),
        })
        .collect()
}

/// Submit and complete the trace one request at a time (the serial
/// reference path of the co-batched pairing).
pub fn run_trace_serial(
    sc: &Scenario,
    engine: &ServingEngine,
) -> Vec<Option<EngineResult<AttentionResponse>>> {
    (0..sc.n_requests())
        .map(|i| {
            match engine.submit_attention_opts(
                sc.request_input(i),
                sc.n,
                sc.d_model(),
                sc.request_layers[i],
                SubmitOptions::default(),
            ) {
                Ok(ticket) => ticket.wait_timeout(WAIT),
                Err(e) => Some(Err(e)),
            }
        })
        .collect()
}

/// Bit-exact comparison of two runs of the same trace. `check_projected`
/// includes `projected_ms` (valid only when both sides share a backend
/// kind — host engines report `None`, sim engines `Some`).
pub fn compare_runs(
    label: &str,
    a: &[Option<EngineResult<AttentionResponse>>],
    b: &[Option<EngineResult<AttentionResponse>>],
    check_projected: bool,
) -> Vec<String> {
    let mut failures = Vec::new();
    if a.len() != b.len() {
        failures.push(format!("{label}: trace lengths differ ({} vs {})", a.len(), b.len()));
        return failures;
    }
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        match (ra, rb) {
            (None, _) | (_, None) => {
                failures.push(format!("{label}: request {i} did not resolve within {WAIT:?}"));
            }
            (Some(Err(ea)), Some(Err(eb))) => {
                if ea.kind != eb.kind {
                    failures.push(format!(
                        "{label}: request {i} error kinds differ ({} vs {})",
                        ea.kind, eb.kind
                    ));
                }
            }
            (Some(Ok(_)), Some(Err(e))) | (Some(Err(e)), Some(Ok(_))) => {
                failures.push(format!(
                    "{label}: request {i} succeeded on one path, failed on the other ({e})"
                ));
            }
            (Some(Ok(ya)), Some(Ok(yb))) => {
                failures.extend(
                    compare_ok(label, i, ya, yb, check_projected).into_iter(),
                );
            }
        }
    }
    failures
}

fn compare_ok(
    label: &str,
    i: usize,
    a: &AttentionResponse,
    b: &AttentionResponse,
    check_projected: bool,
) -> Vec<String> {
    let mut failures = Vec::new();
    if a.ranks != b.ranks {
        failures.push(format!(
            "{label}: request {i} ranks differ ({:?} vs {:?})",
            a.ranks, b.ranks
        ));
    }
    if (a.flops_spent, a.flops_full) != (b.flops_spent, b.flops_full) {
        failures.push(format!(
            "{label}: request {i} FLOPs ledgers differ ({}/{} vs {}/{})",
            a.flops_spent, a.flops_full, b.flops_spent, b.flops_full
        ));
    }
    if a.y.len() != b.y.len() {
        failures.push(format!(
            "{label}: request {i} output lengths differ ({} vs {})",
            a.y.len(),
            b.y.len()
        ));
    } else if let Some(j) =
        (0..a.y.len()).find(|&j| a.y[j].to_bits() != b.y[j].to_bits())
    {
        failures.push(format!(
            "{label}: request {i} outputs differ at y[{j}]: {:e} vs {:e}",
            a.y[j], b.y[j]
        ));
    }
    if check_projected {
        let pa = a.projected_ms.map(f64::to_bits);
        let pb = b.projected_ms.map(f64::to_bits);
        if pa != pb {
            failures.push(format!(
                "{label}: request {i} projected_ms differ ({:?} vs {:?})",
                a.projected_ms, b.projected_ms
            ));
        }
    }
    failures
}

/// FLOPs conservation: recompute each successful response's ledger from
/// its reported ranks. A dynamic-rank decision charges the factor apply
/// at the rank's *compiled bucket* plus the probe SVD amortized over the
/// segment; the full-rank source charges the dense kernel on both sides
/// of the ledger.
pub fn flops_conservation_failures(
    sc: &Scenario,
    reg: &ArtifactRegistry,
    results: &[Option<EngineResult<AttentionResponse>>],
) -> Vec<String> {
    let mut failures = Vec::new();
    let n = sc.n;
    let d = sc.head_dim;
    let full_per_head = flops::full_attention_flops(n, d);
    let bucket_max = reg.rank_bucket(sc.r_max());
    let amortize = sc.segment_len.max(1) as u64;
    for (i, r) in results.iter().enumerate() {
        let Some(Ok(resp)) = r else { continue };
        if resp.ranks.len() != sc.n_heads {
            failures.push(format!(
                "flops: request {i} reports {} ranks for {} heads",
                resp.ranks.len(),
                sc.n_heads
            ));
            continue;
        }
        let want_full = full_per_head * sc.n_heads as u64;
        let want_spent: u64 = match sc.policy {
            PolicyKind::FullRank => want_full,
            _ => resp
                .ranks
                .iter()
                .map(|&r| {
                    flops::lowrank_attention_flops(n, d, reg.rank_bucket(r), false)
                        + flops::partial_svd_flops(n, n, bucket_max) / amortize
                })
                .sum(),
        };
        if resp.flops_full != want_full {
            failures.push(format!(
                "flops: request {i} flops_full {} != analytic {}",
                resp.flops_full, want_full
            ));
        }
        if resp.flops_spent != want_spent {
            failures.push(format!(
                "flops: request {i} flops_spent {} != analytic {} (ranks {:?})",
                resp.flops_spent, want_spent, resp.ranks
            ));
        }
        // Note: no `spent ≤ full` assertion — at ranks near n with a
        // short amortization segment the factor apply plus probe
        // legitimately exceeds the dense kernel (the paper's savings are
        // an operating-point property; the *accounting* is the
        // invariant).
    }
    failures
}

/// Run the trace on a sim-backend engine and check that the per-request
/// `projected_ms` attributions sum to the backend's latency ledger to
/// 1e-9. `tamper_ms` injects a deliberate ledger drift *between* the
/// run and the check — 0.0 in production; the bug-injection test passes
/// a nonzero drift and asserts this function reports it.
pub fn sim_ledger_failures(sc: &Scenario, tamper_ms: f64) -> Vec<String> {
    let reg = Arc::new(ArtifactRegistry::open_sim(sc.n, sc.head_dim, sc.profile));
    let ledger_mark = reg
        .latency_ledger()
        .expect("sim backend carries a latency ledger")
        .mark();
    let results = {
        let engine = build_engine(sc, Arc::clone(&reg), 1, sc.max_batch, PipelineHooks::default());
        run_trace(sc, &engine)
    };
    let mut failures = Vec::new();
    let mut attributed = 0.0f64;
    for (i, r) in results.iter().enumerate() {
        match r {
            None => failures.push(format!("ledger: request {i} did not resolve")),
            Some(Err(e)) => failures.push(format!("ledger: request {i} failed: {e}")),
            Some(Ok(resp)) => match resp.projected_ms {
                Some(ms) => attributed += ms,
                None => failures.push(format!(
                    "ledger: request {i} reports no projected_ms on a sim backend"
                )),
            },
        }
    }
    if tamper_ms != 0.0 {
        reg.latency_ledger().expect("sim ledger").add_ms(tamper_ms);
    }
    let charged = reg.latency_ledger().expect("sim ledger").since(ledger_mark);
    if (attributed - charged).abs() > 1e-9 {
        failures.push(format!(
            "ledger: per-request projected_ms sum {attributed:.12} ms disagrees with the \
             sim ledger charge {charged:.12} ms (drift {:+.3e})",
            charged - attributed
        ));
    }
    failures
}

/// Pairing 1: host vs sim, plus per-run conservation checks on both.
pub fn host_vs_sim_failures(sc: &Scenario) -> Vec<String> {
    let reg_h = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let reg_s = Arc::new(ArtifactRegistry::open_sim(sc.n, sc.head_dim, sc.profile));
    let host = {
        let engine =
            build_engine(sc, Arc::clone(&reg_h), 1, sc.max_batch, PipelineHooks::default());
        run_trace(sc, &engine)
    };
    let sim = {
        let engine =
            build_engine(sc, Arc::clone(&reg_s), 1, sc.max_batch, PipelineHooks::default());
        run_trace(sc, &engine)
    };
    let mut failures = compare_runs("host-vs-sim", &host, &sim, false);
    failures.extend(flops_conservation_failures(sc, &reg_h, &host));
    failures.extend(flops_conservation_failures(sc, &reg_s, &sim));
    failures
}

/// Pairing 2: the staged co-batched pipeline vs one-request-at-a-time on
/// a single-worker host engine.
pub fn batched_vs_serial_failures(sc: &Scenario) -> Vec<String> {
    let reg = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let batched = {
        let engine = build_engine(sc, Arc::clone(&reg), 1, sc.max_batch, PipelineHooks::default());
        run_trace(sc, &engine)
    };
    let serial = {
        let engine = build_engine(sc, Arc::clone(&reg), 1, 1, PipelineHooks::default());
        run_trace_serial(sc, &engine)
    };
    compare_runs("batched-vs-serial", &batched, &serial, true)
}

/// Kernel-path pairing: route the probe's matmuls through the fused
/// (packed-A reuse) and direct (`matmul_at`) paths and demand bit
/// identity, on scenario-shaped data.
///
/// Three checks per seed:
/// 1. `partial_svd_with` Fused vs Direct on an n×n attention-shaped
///    matrix (subspace depth varied by the scenario's `probe_kernel`
///    knob) — U/σ/V must agree to the bit;
/// 2. `PackedAt::matmul_at` vs `matmul_at` on a rank-grid-width RHS —
///    bit identity of the raw product;
/// 3. the packed GEMM core vs the `matmul_naive` oracle at 1e-9
///    absolute (values may legally differ in bits from the oracle —
///    only the paired kernel paths are held to bit identity).
pub fn probe_kernel_failures(sc: &Scenario) -> Vec<String> {
    use crate::linalg::matmul::matmul_naive;
    use crate::linalg::{matmul, matmul_at, partial_svd_with, Mat, PackedAt, ProbeKernel};
    use crate::util::Pcg32;

    let mut failures = Vec::new();
    let mut rng = Pcg32::new(sc.seed ^ 0x9106_be75, 3);
    let a = Mat::randn(sc.n, sc.n, 1.0, &mut rng);

    // 1. Fused vs direct probe pass.
    let n_iter = match sc.probe_kernel {
        ProbeKernel::Fused => 2,
        ProbeKernel::Direct => 1,
    };
    let k = sc.r_max().min(sc.n);
    let svd_seed = sc.seed ^ 0x0b5e;
    let f = partial_svd_with(&a, k, 8, n_iter, svd_seed, ProbeKernel::Fused);
    let d = partial_svd_with(&a, k, 8, n_iter, svd_seed, ProbeKernel::Direct);
    if f.s.iter().zip(&d.s).any(|(x, y)| x.to_bits() != y.to_bits())
        || f.u.data().iter().zip(d.u.data()).any(|(x, y)| x.to_bits() != y.to_bits())
        || f.v.data().iter().zip(d.v.data()).any(|(x, y)| x.to_bits() != y.to_bits())
    {
        failures.push(format!(
            "probe-kernel: fused vs direct partial_svd differ in bits \
             (n={} k={k} n_iter={n_iter})",
            sc.n
        ));
    }

    // 2. Packed vs direct Aᵀ·B on a rank-grid-width RHS.
    let w = sc.rank_grid[0].min(sc.n).max(1);
    let q = Mat::randn(sc.n, w, 1.0, &mut rng);
    let direct = matmul_at(&a, &q);
    let packed = PackedAt::pack(&a, w).matmul_at(&q);
    if direct.data().iter().zip(packed.data()).any(|(x, y)| x.to_bits() != y.to_bits()) {
        failures.push(format!(
            "probe-kernel: PackedAt::matmul_at differs in bits from matmul_at (n={} w={w})",
            sc.n
        ));
    }

    // 3. Packed core vs naive oracle (tolerance, not bits).
    let got = matmul(&a, &q);
    let want = matmul_naive(&a, &q);
    if !got.allclose(&want, 1e-9) {
        failures.push(format!(
            "probe-kernel: packed matmul drifts from the naive oracle beyond 1e-9 \
             (n={} w={w})",
            sc.n
        ));
    }
    failures
}

/// Pairing 3: N workers vs 1 worker (order-insensitive scenarios only).
pub fn workers_failures(sc: &Scenario) -> Vec<String> {
    if !sc.order_insensitive() {
        return Vec::new();
    }
    let reg_n = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let reg_1 = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let many = {
        let engine =
            build_engine(sc, reg_n, sc.n_workers, sc.max_batch, PipelineHooks::default());
        run_trace(sc, &engine)
    };
    let one = {
        let engine = build_engine(sc, reg_1, 1, sc.max_batch, PipelineHooks::default());
        run_trace(sc, &engine)
    };
    compare_runs(
        &format!("{}-workers-vs-1", sc.n_workers),
        &many,
        &one,
        true,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(miri, ignore)] // spawns engine threads; covered natively
    fn a_quick_seed_passes_every_differential_pairing() {
        // Seed 1 under the generator: a smoke check that the harness
        // itself is consistent (the fuzz binary and CI corpus cover the
        // breadth).
        let sc = Scenario::generate(1);
        let mut failures = host_vs_sim_failures(&sc);
        failures.extend(batched_vs_serial_failures(&sc));
        failures.extend(workers_failures(&sc));
        failures.extend(sim_ledger_failures(&sc, 0.0));
        failures.extend(probe_kernel_failures(&sc));
        assert!(failures.is_empty(), "seed 1 failures:\n{}", failures.join("\n"));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn injected_ledger_drift_is_caught() {
        // The ledger-agreement invariant must actually bite: drifting
        // the sim ledger by 0.5 ms after the run makes the check fail
        // and the failure text names the drift.
        let sc = Scenario::generate(1);
        let failures = sim_ledger_failures(&sc, 0.5);
        assert!(
            failures.iter().any(|f| f.contains("disagrees with the")),
            "injected drift went undetected: {failures:?}"
        );
    }
}
