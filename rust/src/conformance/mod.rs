//! Differential fuzzing + adversarial-schedule conformance layer.
//!
//! One `u64` seed expands ([`Scenario::generate`]) into a complete
//! serving scenario, and [`run_seed`] drives the same request trace
//! through every paired execution path the engine promises are
//! equivalent:
//!
//! * **host vs sim** — the sim backend delegates compute to the host
//!   kernels and only adds roofline latency accounting, so outputs,
//!   rank schedules and FLOPs ledgers must be bit-identical
//!   (`f64::to_bits`), and the per-request `projected_ms` attribution
//!   must agree with the sim's latency ledger to 1e-9;
//! * **co-batched vs serial** — draining requests in batches must not
//!   change any per-request result;
//! * **N workers vs 1** — for order-insensitive scenarios, worker
//!   parallelism must not change results either;
//! * **adversarial schedules** — seeded jitter at the post-probe stage
//!   boundary permutes batch interleavings; the serialized decide
//!   trace (observed via [`crate::coordinator::PipelineHooks`]) must
//!   stay a legal permutation with identical per-request schedules,
//!   and racing cancels/deadlines must resolve every ticket with a
//!   typed lifecycle error;
//! * **fused vs direct probe kernels** — the probe's matmuls through
//!   the packed-A-reuse path and the per-call `matmul_at` path must be
//!   bit-identical, and the packed GEMM core must stay within 1e-9 of
//!   the naive oracle.
//!
//! Every failure carries its seed; `drrl fuzz --seed N` replays it
//! deterministically. `CONFORMANCE.md` at the repo root catalogues the
//! invariants this module machine-checks.
//!
//! The sibling [`lint`] pass (`drrl lint`, implemented by
//! [`crate::analysis`]) enforces the source-level contracts the fuzzer
//! relies on across all of `rust/src/`: poison-shedding lock
//! discipline, no wall-clock reads in decide-critical sections, no raw
//! channels outside the completion layer, an acyclic lock-order graph,
//! ordered iteration in bit-identity-critical modules, panic-free
//! worker contexts, and shape-pure `linalg` partitions (rules R1–R7 in
//! CONFORMANCE.md § "Static rules").

pub mod differential;
pub mod lint;
pub mod perturb;
pub mod scenario;

pub use differential::{
    batched_vs_serial_failures, host_vs_sim_failures, probe_kernel_failures, sim_ledger_failures,
    workers_failures,
};
pub use lint::{run_lint, scan_source, LintViolation};
pub use perturb::{cancel_race_failures, perturbation_failures, validate_trace};
pub use scenario::{PolicyKind, Scenario};

use std::fmt;

/// Everything a failing seed needs to be reproduced: the seed, the
/// expanded scenario and every differential mismatch it produced.
#[derive(Debug)]
pub struct FailureReport {
    pub seed: u64,
    pub scenario: String,
    pub failures: Vec<String>,
}

impl fmt::Display for FailureReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "seed {} failed conformance", self.seed)?;
        writeln!(f, "  scenario: {}", self.scenario)?;
        for failure in &self.failures {
            writeln!(f, "  - {failure}")?;
        }
        write!(f, "  reproduce with: {}", repro_command(self.seed))
    }
}

/// The one-command reproduction for a failing seed.
pub fn repro_command(seed: u64) -> String {
    format!("drrl fuzz --seed {seed}")
}

/// Run every conformance pairing for one seed. `Ok(())` means the seed's
/// scenario is indistinguishable across all paired execution paths.
pub fn run_seed(seed: u64) -> Result<(), FailureReport> {
    let sc = Scenario::generate(seed);
    let mut failures = Vec::new();
    failures.extend(host_vs_sim_failures(&sc));
    failures.extend(batched_vs_serial_failures(&sc));
    failures.extend(workers_failures(&sc));
    failures.extend(sim_ledger_failures(&sc, 0.0));
    failures.extend(perturbation_failures(&sc));
    failures.extend(cancel_race_failures(&sc));
    failures.extend(probe_kernel_failures(&sc));
    if failures.is_empty() {
        Ok(())
    } else {
        Err(FailureReport { seed, scenario: sc.describe(), failures })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_repro_command_round_trips_the_seed() {
        assert_eq!(repro_command(0xDEAD), "drrl fuzz --seed 57005");
    }

    #[test]
    fn failure_reports_print_seed_scenario_and_repro() {
        let report = FailureReport {
            seed: 7,
            scenario: "n=64 ...".into(),
            failures: vec!["host-vs-sim: y[0] differs".into()],
        };
        let text = report.to_string();
        assert!(text.contains("seed 7"));
        assert!(text.contains("n=64"));
        assert!(text.contains("y[0] differs"));
        assert!(text.contains("drrl fuzz --seed 7"));
    }
}
