//! Source-level lint pass — thin shim over [`crate::analysis`].
//!
//! PR 6's deliberately dumb line-oriented scanner lived here; it has
//! been replaced by the static-analysis subsystem in
//! [`crate::analysis`] (lexer → structural model → local type map →
//! typed call graph → fixed-point dataflow → rules R1–R14), which
//! scans `rust/src/`,
//! `rust/tests/`, `rust/benches/` and `examples/` instead of two
//! hand-picked directories. This module keeps the conformance-layer
//! surface stable: [`run_lint`], [`scan_source`] and [`LintViolation`]
//! re-export or wrap the analysis implementations, and the live-tree
//! test below pins the real repository free of error-level findings
//! under the full rule set (findings in test/bench/example code are
//! advisory and never gate).
//!
//! See CONFORMANCE.md § "Static rules" for the R1–R14 catalogue and
//! the `lint:allow(rule)` suppression mechanism.

use std::path::Path;

pub use crate::analysis::{run_lint, LintViolation};

/// Analyze one file's source text under every file-local rule (plus any
/// lock-order cycle visible within the file). Kept for API continuity
/// with the old scanner; tests feed synthetic sources without touching
/// the filesystem.
pub fn scan_source(path: &Path, source: &str) -> Vec<LintViolation> {
    crate::analysis::analyze_source(path, source)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Level;

    #[test]
    fn scan_source_matches_the_analysis_pass() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let v = scan_source(Path::new("rust/src/coordinator/engine.rs"), src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "lock-unwrap");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // reads the live source tree from disk
    fn the_live_tree_is_clean() {
        // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there,
        // sources under rust/src/).
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let violations = run_lint(root).expect("scan the live tree");
        let errors: Vec<_> =
            violations.iter().filter(|v| v.level == Level::Error).collect();
        assert!(
            errors.is_empty(),
            "error-level lint findings in the live tree:\n{}",
            errors.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
