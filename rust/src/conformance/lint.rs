//! Source-scanning lint pass for the concurrency-critical tree.
//!
//! `cargo clippy` cannot see project-local contracts, so `drrl lint`
//! enforces three of them over `rust/src/coordinator/` and
//! `rust/src/runtime/` by scanning the source text directly:
//!
//! * **R1 `lock-unwrap`** — no `.lock().unwrap()` / `.lock().expect(..)`
//!   (or the condvar equivalents) on synchronization primitives. A
//!   worker panic would poison the lock and cascade into every other
//!   thread; the tree must go through [`crate::util::LockExt`] /
//!   [`crate::util::CondvarExt`], which shed poison instead.
//! * **R2 `instant-in-decide`** — no `Instant::now()` inside
//!   decide-critical sections. Decisions must be a pure function of the
//!   trace so the differential fuzzer can demand bit-identity; wall
//!   -clock reads belong at stage boundaries, outside the shard lock.
//!   Scope: all of `rank_controller.rs`, plus any region of
//!   `pipeline.rs` holding a shard lock guard (tracked by brace depth).
//! * **R3 `raw-mpsc`** — no `std::sync::mpsc` outside
//!   `coordinator/completion.rs`; tickets and completion queues are the
//!   one sanctioned channel surface. A site that genuinely needs a raw
//!   channel (e.g. PJRT literals that are not `Send`-safe through the
//!   completion queue) documents itself with a `lint:allow(mpsc)`
//!   comment in the contiguous comment block directly above the line.
//!
//! The scanner is deliberately dumb — line-oriented, no parsing — so it
//! can't be wrong in interesting ways; unit tests feed it synthetic
//! sources per rule, and a live-tree test keeps the real tree clean.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintViolation {
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub text: String,
}

impl fmt::Display for LintViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.text.trim())
    }
}

/// Scan the repository rooted at `root` (the directory holding
/// `rust/src/`) and return every violation, sorted by file then line.
pub fn run_lint(root: &Path) -> io::Result<Vec<LintViolation>> {
    let mut violations = Vec::new();
    for dir in ["rust/src/coordinator", "rust/src/runtime"] {
        let dir = root.join(dir);
        let mut entries: Vec<PathBuf> = fs::read_dir(&dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "rs"))
            .collect();
        entries.sort();
        for path in entries {
            let source = fs::read_to_string(&path)?;
            violations.extend(scan_source(&path, &source));
        }
    }
    violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(violations)
}

/// Scan one file's source text. Split out from [`run_lint`] so tests can
/// feed synthetic sources without touching the filesystem.
pub fn scan_source(path: &Path, source: &str) -> Vec<LintViolation> {
    let lines: Vec<&str> = source.lines().collect();
    let file_name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
    let in_completion = path.ends_with("coordinator/completion.rs") || file_name == "completion.rs";
    let mut violations = Vec::new();

    // R2 region tracking for pipeline.rs: while a shard-lock guard is
    // live (brace depth has not dropped below the depth at the lock
    // line), Instant::now is decide-critical.
    let mut depth: i64 = 0;
    let mut shard_lock_depths: Vec<i64> = Vec::new();

    for (idx, raw) in lines.iter().enumerate() {
        let line_no = idx + 1;
        let line = strip_line_comment(raw);
        let trimmed = raw.trim_start();
        let is_comment = trimmed.starts_with("//");

        if !is_comment {
            // R1: poisoning unwrap/expect on lock or condvar results.
            if line.contains(".lock().unwrap()")
                || line.contains(".lock().expect(")
                || line.contains(".read().unwrap()")
                || line.contains(".write().unwrap()")
                || (line.contains(".wait(") || line.contains(".wait_timeout("))
                    && (line.contains(").unwrap()") || line.contains(").expect("))
            {
                violations.push(LintViolation {
                    file: path.to_path_buf(),
                    line: line_no,
                    rule: "lock-unwrap",
                    text: raw.to_string(),
                });
            }

            // R3: raw std channels outside the completion layer.
            if !in_completion
                && (line.contains("std::sync::mpsc") || line.contains("use mpsc::"))
                && !allowed_above(&lines, idx, "lint:allow(mpsc)")
            {
                violations.push(LintViolation {
                    file: path.to_path_buf(),
                    line: line_no,
                    rule: "raw-mpsc",
                    text: raw.to_string(),
                });
            }
        }

        // R2 scoping.
        let decide_critical = match file_name {
            "rank_controller.rs" => true,
            "pipeline.rs" => {
                if !is_comment && line.contains("shards") && line.contains(".lock") {
                    shard_lock_depths.push(depth);
                }
                !shard_lock_depths.is_empty()
            }
            _ => false,
        };
        if decide_critical && !is_comment && line.contains("Instant::now") {
            violations.push(LintViolation {
                file: path.to_path_buf(),
                line: line_no,
                rule: "instant-in-decide",
                text: raw.to_string(),
            });
        }

        if !is_comment {
            for ch in line.chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => {
                        depth -= 1;
                        while shard_lock_depths.last().is_some_and(|&d| depth < d) {
                            shard_lock_depths.pop();
                        }
                    }
                    _ => {}
                }
            }
        }
    }
    violations
}

/// Drop a trailing `// ...` comment so commented-out code on the same
/// line as real code can't trip a rule. (String literals containing
/// `//` are rare enough in this tree to not matter; the scanner errs
/// toward fewer false positives.)
fn strip_line_comment(line: &str) -> &str {
    match line.find("//") {
        Some(pos) => &line[..pos],
        None => line,
    }
}

/// Is `marker` present in the contiguous comment block directly above
/// line `idx` (0-based)?
fn allowed_above(lines: &[&str], idx: usize, marker: &str) -> bool {
    for prior in lines[..idx].iter().rev() {
        let t = prior.trim_start();
        if t.starts_with("//") || t.starts_with("#[") {
            if t.contains(marker) {
                return true;
            }
        } else if t.is_empty() {
            return false; // blank line breaks the contiguous block
        } else {
            return false;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(file: &str, src: &str) -> Vec<LintViolation> {
        scan_source(Path::new(file), src)
    }

    #[test]
    fn r1_flags_poisoning_lock_unwraps() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let v = scan("rust/src/coordinator/engine.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "lock-unwrap");
        assert_eq!(v[0].line, 2);

        let ok = "fn f() {\n    let g = state.lock_unpoisoned();\n}\n";
        assert!(scan("rust/src/coordinator/engine.rs", ok).is_empty());
    }

    #[test]
    fn r1_flags_condvar_unwraps_but_not_ticket_waits() {
        let bad = "let g = cv.wait(guard).unwrap();\n";
        assert_eq!(scan("rust/src/coordinator/engine.rs", bad).len(), 1);
        // Ticket::wait returns a result, not a poisoned guard.
        let ok = "let r = ticket.wait();\n";
        assert!(scan("rust/src/coordinator/engine.rs", ok).is_empty());
    }

    #[test]
    fn r2_flags_instant_now_anywhere_in_rank_controller() {
        let src = "fn decide() {\n    let t = Instant::now();\n}\n";
        let v = scan("rust/src/coordinator/rank_controller.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "instant-in-decide");
        // Same text in a file outside the decide-critical scope is fine.
        assert!(scan("rust/src/coordinator/batcher.rs", src).is_empty());
    }

    #[test]
    fn r2_tracks_shard_lock_regions_in_pipeline() {
        let bad = concat!(
            "fn decide_stage() {\n",
            "    {\n",
            "        let mut shard = shared.shards[layer].lock_unpoisoned();\n",
            "        let t = Instant::now();\n",
            "    }\n",
            "    let after = Instant::now();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", bad);
        assert_eq!(v.len(), 1, "only the in-guard read is critical: {v:?}");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn r3_flags_raw_mpsc_unless_annotated() {
        let bad = "use std::sync::mpsc;\n";
        let v = scan("rust/src/runtime/worker.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "raw-mpsc");

        let allowed = concat!(
            "// PJRT literals are not Send; a thread-local channel is the\n",
            "// sanctioned escape hatch here. lint:allow(mpsc)\n",
            "use std::sync::mpsc;\n",
        );
        assert!(scan("rust/src/runtime/worker.rs", allowed).is_empty());

        // A blank line breaks the annotation's contiguous block.
        let broken = "// lint:allow(mpsc)\n\nuse std::sync::mpsc;\n";
        assert_eq!(scan("rust/src/runtime/worker.rs", broken).len(), 1);

        // completion.rs owns the channel surface.
        assert!(scan("rust/src/coordinator/completion.rs", bad).is_empty());
    }

    #[test]
    fn comment_lines_never_match() {
        let src = "// old code: state.lock().unwrap() — do not resurrect\n";
        assert!(scan("rust/src/coordinator/engine.rs", src).is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // reads the live source tree from disk
    fn the_live_tree_is_clean() {
        // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there,
        // sources under rust/src/).
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let violations = run_lint(root).expect("scan the live tree");
        assert!(
            violations.is_empty(),
            "lint violations in the live tree:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
