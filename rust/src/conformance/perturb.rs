//! Adversarial-schedule conformance: record the serialized decide order
//! through [`PipelineHooks::on_decide`], perturb worker timing so
//! batches interleave differently, and assert the serialization
//! invariants still hold.
//!
//! The decide stage replays rank decisions under the layer's shard lock,
//! so the `on_decide` emission order *is* the serialization the
//! bit-identity guarantees are defined over. An adversarial schedule
//! (worker jitter at the post-probe boundary, different worker counts,
//! permuted batch formation) may legally change which decisions land in
//! which drained batch — but it must never:
//!
//! * change any order-insensitive scenario's per-request results,
//! * decide a (request, head) pair twice or drop one,
//! * replay one request's heads out of head order within a layer,
//! * turn a boundary decision stale (`segment_len == 1` ⇒ every
//!   decision fresh).
//!
//! [`validate_trace`] checks the last three properties as a pure
//! function over recorded traces, so tests can corrupt a trace and
//! watch the validator catch it (the "previously-unpinned invariant
//! class" demanded by the conformance issue).
//!
//! The same hook machinery drives the cancel/deadline race harness:
//! seeded cancel timings land tickets' deaths right at the post-probe
//! stage boundary across permuted schedules, pinning the pipeline's
//! cooperative-cancellation contract (typed errors only, every ticket
//! resolves, no completed-request metrics for reaped work).

use super::differential::{build_engine, compare_runs, run_trace};
use super::scenario::Scenario;
use crate::coordinator::{
    DecideEvent, ErrorKind, PipelineHooks, SubmitOptions,
};
use crate::runtime::ArtifactRegistry;
use crate::util::{LockExt, Pcg32};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A shared decide-trace sink plus the hooks that feed it.
pub fn recording_hooks() -> (Arc<Mutex<Vec<DecideEvent>>>, PipelineHooks) {
    let sink: Arc<Mutex<Vec<DecideEvent>>> = Arc::new(Mutex::new(Vec::new()));
    let writer = Arc::clone(&sink);
    let hooks = PipelineHooks {
        after_probe: None,
        on_decide: Some(Arc::new(move |e| writer.lock_unpoisoned().push(e))),
    };
    (sink, hooks)
}

/// Seeded worker jitter at the post-probe stage boundary: each firing
/// sleeps 0–4 ms, drawn from a shared deterministic stream. Under
/// multiple workers this permutes how same-layer batches interleave at
/// the decide lock.
pub fn jitter_hook(seed: u64) -> Arc<dyn Fn() + Send + Sync> {
    let rng = Mutex::new(Pcg32::new(seed, 0x7177_e4));
    Arc::new(move || {
        let ms = rng.lock_unpoisoned().below(5) as u64;
        std::thread::sleep(Duration::from_millis(ms));
    })
}

/// Validate a recorded decide trace against the reference run's trace.
///
/// Checks, per layer stream:
/// 1. the perturbed schedule decided exactly the same (request, head)
///    pairs — none dropped, none doubled;
/// 2. each pair's decided rank matches the reference (rank schedules of
///    order-insensitive scenarios are schedule-independent);
/// 3. within each request, heads replay in ascending head order (the
///    pipeline's request-major, head-minor replay rule);
/// 4. when `all_fresh`, every decision re-ran the policy (`segment_len
///    == 1` makes every call a boundary).
///
/// Pure: tests corrupt a trace and assert this reports it.
pub fn validate_trace(
    perturbed: &[DecideEvent],
    reference: &[DecideEvent],
    all_fresh: bool,
) -> Result<(), String> {
    type Key = (usize, u64, usize); // (layer, request, head)
    let count = |trace: &[DecideEvent]| -> BTreeMap<Key, (usize, usize)> {
        let mut m: BTreeMap<Key, (usize, usize)> = BTreeMap::new();
        for e in trace {
            let entry = m.entry((e.layer, e.request, e.head)).or_insert((0, e.rank));
            entry.0 += 1;
            entry.1 = e.rank;
        }
        m
    };
    let got = count(perturbed);
    let want = count(reference);
    for (key, (n, _)) in &got {
        match want.get(key) {
            None => {
                return Err(format!(
                    "trace: decided (layer {}, request {}, head {}) which the reference never did",
                    key.0, key.1, key.2
                ))
            }
            Some(_) if *n != 1 => {
                return Err(format!(
                    "trace: (layer {}, request {}, head {}) decided {n} times",
                    key.0, key.1, key.2
                ))
            }
            Some((_, want_rank)) => {
                let got_rank = got[key].1;
                if got_rank != *want_rank {
                    return Err(format!(
                        "trace: (layer {}, request {}, head {}) rank {got_rank} != reference \
                         rank {want_rank}",
                        key.0, key.1, key.2
                    ));
                }
            }
        }
    }
    if let Some(key) = want.keys().find(|k| !got.contains_key(*k)) {
        return Err(format!(
            "trace: (layer {}, request {}, head {}) was never decided",
            key.0, key.1, key.2
        ));
    }
    // Head order within each (layer, request) must ascend.
    let mut last_head: BTreeMap<(usize, u64), usize> = BTreeMap::new();
    for e in perturbed {
        if let Some(&prev) = last_head.get(&(e.layer, e.request)) {
            if e.head <= prev {
                return Err(format!(
                    "trace: layer {} request {} replayed head {} after head {prev} \
                     (head order must ascend within a request)",
                    e.layer, e.request, e.head
                ));
            }
        }
        last_head.insert((e.layer, e.request), e.head);
    }
    if all_fresh {
        if let Some(e) = perturbed.iter().find(|e| !e.fresh) {
            return Err(format!(
                "trace: layer {} request {} head {} reused a stale decision with \
                 segment_len == 1",
                e.layer, e.request, e.head
            ));
        }
    }
    Ok(())
}

/// Schedule-perturbation check for one scenario: a single-worker
/// reference run vs a multi-worker run with seeded post-probe jitter.
/// Only order-insensitive scenarios are compared (the pairing would be
/// vacuous otherwise).
pub fn perturbation_failures(sc: &Scenario) -> Vec<String> {
    if !sc.order_insensitive() {
        return Vec::new();
    }
    let reg_ref = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let (ref_trace, ref_hooks) = recording_hooks();
    let reference = {
        let engine = build_engine(sc, reg_ref, 1, sc.max_batch, ref_hooks);
        run_trace(sc, &engine)
    };

    let reg_adv = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let (adv_trace, mut adv_hooks) = recording_hooks();
    adv_hooks.after_probe = Some(jitter_hook(sc.seed ^ 0xAD7E));
    let perturbed = {
        let engine = build_engine(sc, reg_adv, sc.n_workers, sc.max_batch, adv_hooks);
        run_trace(sc, &engine)
    };

    let mut failures = compare_runs("perturbed-schedule", &perturbed, &reference, true);
    let reference_events = ref_trace.lock_unpoisoned();
    let perturbed_events = adv_trace.lock_unpoisoned();
    if let Err(e) = validate_trace(&perturbed_events, &reference_events, true) {
        failures.push(format!("perturbed-schedule: {e}"));
    }
    failures
}

/// Cancel/deadline race harness: a seeded subset of the trace's tickets
/// is cancelled from a client thread while seeded jitter stretches the
/// post-probe boundary, and another subset carries deadlines tight
/// enough to expire mid-flight. Every ticket must resolve with either a
/// success or a *typed* cancel/deadline error — never `Internal`, never
/// a hang — and completed-request metrics must count exactly the
/// successes.
pub fn cancel_race_failures(sc: &Scenario) -> Vec<String> {
    let mut failures = Vec::new();
    let reg = Arc::new(ArtifactRegistry::open_host(sc.n, sc.head_dim));
    let hooks = PipelineHooks {
        after_probe: Some(jitter_hook(sc.seed ^ 0xCA4C)),
        on_decide: None,
    };
    let engine = build_engine(sc, Arc::clone(&reg), sc.n_workers, sc.max_batch, hooks);

    let mut rng = Pcg32::new(sc.seed ^ 0xCA4C_E11E, 3);
    let mut tickets = Vec::new();
    let mut cancellers = Vec::new();
    for i in 0..sc.n_requests() {
        // Per-request fate: 0 = plain, 1 = racing client cancel,
        // 2 = tight deadline that may expire at a stage boundary.
        let fate = rng.below(3);
        let opts = if fate == 2 {
            SubmitOptions::deadline_in(Duration::from_millis(rng.below(4) as u64))
        } else {
            SubmitOptions::default()
        };
        match engine.submit_attention_opts(
            sc.request_input(i),
            sc.n,
            sc.d_model(),
            sc.request_layers[i],
            opts,
        ) {
            Ok(ticket) => {
                if fate == 1 {
                    let token = ticket.cancel_token();
                    let delay = Duration::from_millis(rng.below(6) as u64);
                    cancellers.push(std::thread::spawn(move || {
                        std::thread::sleep(delay);
                        token.cancel();
                    }));
                }
                tickets.push((i, ticket));
            }
            Err(e) => {
                // Submit-time expiry of an already-dead deadline is a
                // legal typed outcome; anything else is a failure.
                if e.kind != ErrorKind::DeadlineExceeded {
                    failures.push(format!("cancel-race: request {i} rejected at submit: {e}"));
                }
            }
        }
    }

    let mut ok = 0u64;
    for (i, ticket) in tickets {
        match ticket.wait_timeout(Duration::from_secs(30)) {
            None => failures.push(format!("cancel-race: request {i} never resolved")),
            Some(Ok(_)) => ok += 1,
            Some(Err(e)) => match e.kind {
                ErrorKind::Cancelled | ErrorKind::DeadlineExceeded => {}
                other => failures.push(format!(
                    "cancel-race: request {i} failed with non-lifecycle kind {other}: {e}"
                )),
            },
        }
    }
    for c in cancellers {
        let _ = c.join();
    }
    if engine.metrics.requests() != ok {
        failures.push(format!(
            "cancel-race: metrics counted {} completed requests but {ok} tickets succeeded",
            engine.metrics.requests()
        ));
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(layer: usize, request: u64, head: usize, rank: usize) -> DecideEvent {
        DecideEvent { layer, head, request, step: 0, rank, prev_rank: rank, fresh: true }
    }

    #[test]
    fn validator_accepts_a_reordered_but_legal_trace() {
        // Requests may interleave across batches; head order within a
        // request must hold. This reordering is legal.
        let reference = vec![
            event(0, 1, 0, 32),
            event(0, 1, 1, 16),
            event(0, 2, 0, 32),
            event(0, 2, 1, 64),
        ];
        let perturbed = vec![
            event(0, 2, 0, 32),
            event(0, 1, 0, 32),
            event(0, 2, 1, 64),
            event(0, 1, 1, 16),
        ];
        validate_trace(&perturbed, &reference, true).expect("legal interleaving");
    }

    #[test]
    fn validator_catches_a_permuted_head_order() {
        // Deliberate bug injection: swapping one request's two head
        // events breaks the request-major, head-minor replay rule.
        let reference = vec![event(0, 1, 0, 32), event(0, 1, 1, 16)];
        let corrupted = vec![event(0, 1, 1, 16), event(0, 1, 0, 32)];
        let err = validate_trace(&corrupted, &reference, true)
            .expect_err("permuted head order must be caught");
        assert!(err.contains("head order"), "unexpected message: {err}");
    }

    #[test]
    fn validator_catches_dropped_and_doubled_decisions() {
        let reference = vec![event(0, 1, 0, 32), event(0, 2, 0, 32)];
        let dropped = vec![event(0, 1, 0, 32)];
        assert!(validate_trace(&dropped, &reference, true).is_err());
        let doubled = vec![event(0, 1, 0, 32), event(0, 1, 0, 32), event(0, 2, 0, 32)];
        assert!(validate_trace(&doubled, &reference, true).is_err());
    }

    #[test]
    fn validator_catches_a_rank_divergence_and_staleness() {
        let reference = vec![event(0, 1, 0, 32)];
        let diverged = vec![event(0, 1, 0, 64)];
        let err = validate_trace(&diverged, &reference, true).expect_err("rank divergence");
        assert!(err.contains("rank"), "unexpected message: {err}");
        let stale =
            vec![DecideEvent { fresh: false, ..event(0, 1, 0, 32) }];
        let err = validate_trace(&stale, &reference, true).expect_err("stale decision");
        assert!(err.contains("stale"), "unexpected message: {err}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // spawns engine threads; covered natively
    fn a_quick_seed_survives_perturbation_and_cancel_races() {
        // Seed 3 generates an order-insensitive scenario under the
        // current generator; the assert guards that so a generator
        // change can't silently turn this test vacuous.
        let sc = (3..64)
            .map(Scenario::generate)
            .find(|s| s.order_insensitive())
            .expect("some seed in 3..64 is order-insensitive");
        let mut failures = perturbation_failures(&sc);
        failures.extend(cancel_race_failures(&sc));
        assert!(failures.is_empty(), "failures:\n{}", failures.join("\n"));
    }
}
