//! Snapshot diffing for the committed `BENCH_*.json` perf trajectory.
//!
//! `drrl bench-diff <baseline.json> <current.json>` compares two
//! harness snapshots case by case and reports the per-benchmark delta.
//! Each case is judged on its best available metric: GFLOP/s when both
//! snapshots carry it (higher is better), otherwise `ns_per_iter`
//! (lower is better). A case whose delta is worse than the regression
//! threshold (default 20%) marks the diff as failed; cases present in
//! only one snapshot are reported but never fail the diff (benches come
//! and go across PRs).

use crate::util::Json;
use std::collections::BTreeMap;

/// One per-case comparison between baseline and current snapshots.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    /// Which metric the delta is computed on: `"gflops"` or `"ns_per_iter"`.
    pub metric: &'static str,
    pub base: f64,
    pub cur: f64,
    /// Signed percent change, oriented so positive = improvement
    /// (throughput up, or time down).
    pub pct: f64,
    /// True when the case got worse by more than the threshold.
    pub regression: bool,
}

impl BenchDelta {
    /// One formatted report line.
    pub fn row(&self) -> String {
        let unit = if self.metric == "gflops" { "GFLOP/s" } else { "ns/iter" };
        let tag = if self.regression { "  << REGRESSION" } else { "" };
        format!(
            "{:<40} {:>12.2} -> {:>12.2} {unit}  {:>+7.1}%{tag}",
            self.name, self.base, self.cur, self.pct
        )
    }
}

/// The full diff: per-case deltas plus the cases unique to either side.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    pub deltas: Vec<BenchDelta>,
    pub only_in_baseline: Vec<String>,
    pub only_in_current: Vec<String>,
}

impl DiffReport {
    pub fn regressions(&self) -> usize {
        self.deltas.iter().filter(|d| d.regression).count()
    }
}

/// Per-case fields the diff needs, pulled out of one snapshot.
struct CaseMetrics {
    ns_per_iter: f64,
    gflops: Option<f64>,
}

fn cases_of(j: &Json, which: &str) -> Result<BTreeMap<String, CaseMetrics>, String> {
    let sv = j
        .get("schema_version")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("{which}: missing numeric schema_version"))?;
    if sv != 1.0 {
        return Err(format!("{which}: unsupported schema_version {sv}"));
    }
    let cases = j
        .get("cases")
        .and_then(|c| c.as_arr())
        .ok_or_else(|| format!("{which}: missing array field: cases"))?;
    let mut out = BTreeMap::new();
    for (i, c) in cases.iter().enumerate() {
        let name = c
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("{which}: case {i}: missing string name"))?;
        let ns = c
            .get("ns_per_iter")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{which}: case {i} ({name}): missing ns_per_iter"))?;
        if !ns.is_finite() || ns <= 0.0 {
            return Err(format!("{which}: case {i} ({name}): bad ns_per_iter {ns}"));
        }
        let gflops = c.get("gflops").and_then(|v| v.as_f64());
        if let Some(g) = gflops {
            if !g.is_finite() || g <= 0.0 {
                return Err(format!("{which}: case {i} ({name}): bad gflops {g}"));
            }
        }
        out.insert(name.to_string(), CaseMetrics { ns_per_iter: ns, gflops });
    }
    Ok(out)
}

/// Diff two parsed snapshots. `max_regress_pct` is the allowed
/// worsening per case, in percent (e.g. 20.0).
pub fn diff_snapshots(
    baseline: &Json,
    current: &Json,
    max_regress_pct: f64,
) -> Result<DiffReport, String> {
    if !(max_regress_pct.is_finite() && max_regress_pct >= 0.0) {
        return Err(format!("bad regression threshold {max_regress_pct}"));
    }
    let base = cases_of(baseline, "baseline")?;
    let cur = cases_of(current, "current")?;
    let mut report = DiffReport::default();
    for (name, b) in &base {
        let Some(c) = cur.get(name) else {
            report.only_in_baseline.push(name.clone());
            continue;
        };
        // GFLOP/s when both sides have it (higher better), else
        // ns_per_iter (lower better). `pct` is oriented so positive is
        // always an improvement.
        let (metric, bval, cval, pct) = match (b.gflops, c.gflops) {
            (Some(bg), Some(cg)) => ("gflops", bg, cg, (cg / bg - 1.0) * 1e2),
            _ => (
                "ns_per_iter",
                b.ns_per_iter,
                c.ns_per_iter,
                (b.ns_per_iter / c.ns_per_iter - 1.0) * 1e2,
            ),
        };
        report.deltas.push(BenchDelta {
            name: name.clone(),
            metric,
            base: bval,
            cur: cval,
            pct,
            regression: pct < -max_regress_pct,
        });
    }
    for name in cur.keys() {
        if !base.contains_key(name) {
            report.only_in_current.push(name.clone());
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(cases: &[(&str, f64, Option<f64>)]) -> Json {
        let case_objs: Vec<Json> = cases
            .iter()
            .map(|(name, ns, gf)| {
                let mut pairs = vec![
                    ("name".to_string(), Json::Str((*name).into())),
                    ("ns_per_iter".to_string(), Json::Num(*ns)),
                ];
                if let Some(g) = gf {
                    pairs.push(("gflops".to_string(), Json::Num(*g)));
                }
                Json::Obj(pairs.into_iter().collect())
            })
            .collect();
        Json::Obj(
            [
                ("schema_version".to_string(), Json::Num(1.0)),
                ("cases".to_string(), Json::Arr(case_objs)),
            ]
            .into_iter()
            .collect(),
        )
    }

    #[test]
    fn gflops_preferred_and_oriented_higher_better() {
        let base = snap(&[("mm", 1000.0, Some(50.0))]);
        let cur = snap(&[("mm", 2000.0, Some(55.0))]);
        let r = diff_snapshots(&base, &cur, 20.0).unwrap();
        assert_eq!(r.deltas.len(), 1);
        let d = &r.deltas[0];
        assert_eq!(d.metric, "gflops");
        assert!((d.pct - 10.0).abs() < 1e-9, "pct {}", d.pct);
        assert!(!d.regression);
    }

    #[test]
    fn ns_per_iter_oriented_lower_better() {
        // 1000 -> 500 ns is a 100% improvement; 1000 -> 2000 is -50%.
        let base = snap(&[("fast", 1000.0, None), ("slow", 1000.0, None)]);
        let cur = snap(&[("fast", 500.0, None), ("slow", 2000.0, None)]);
        let r = diff_snapshots(&base, &cur, 20.0).unwrap();
        let fast = r.deltas.iter().find(|d| d.name == "fast").unwrap();
        let slow = r.deltas.iter().find(|d| d.name == "slow").unwrap();
        assert!((fast.pct - 100.0).abs() < 1e-9);
        assert!(!fast.regression);
        assert!((slow.pct + 50.0).abs() < 1e-9);
        assert!(slow.regression);
    }

    #[test]
    fn threshold_is_exclusive_at_the_boundary() {
        // Exactly -20% with a 20% threshold is allowed (pct < -max).
        let base = snap(&[("m", 1000.0, Some(100.0))]);
        let cur = snap(&[("m", 1000.0, Some(80.0))]);
        let r = diff_snapshots(&base, &cur, 20.0).unwrap();
        assert!(!r.deltas[0].regression);
        let r = diff_snapshots(&base, &cur, 19.9).unwrap();
        assert!(r.deltas[0].regression);
        assert_eq!(r.regressions(), 1);
    }

    #[test]
    fn disjoint_cases_reported_but_never_fail() {
        let base = snap(&[("gone", 1000.0, None), ("both", 1000.0, None)]);
        let cur = snap(&[("both", 1001.0, None), ("new", 10.0, None)]);
        let r = diff_snapshots(&base, &cur, 20.0).unwrap();
        assert_eq!(r.only_in_baseline, vec!["gone".to_string()]);
        assert_eq!(r.only_in_current, vec!["new".to_string()]);
        assert_eq!(r.deltas.len(), 1);
        assert_eq!(r.regressions(), 0);
    }

    #[test]
    fn mixed_gflops_presence_falls_back_to_time() {
        let base = snap(&[("m", 1000.0, Some(100.0))]);
        let cur = snap(&[("m", 900.0, None)]);
        let r = diff_snapshots(&base, &cur, 20.0).unwrap();
        assert_eq!(r.deltas[0].metric, "ns_per_iter");
    }

    #[test]
    fn malformed_snapshots_are_rejected() {
        let no_cases = Json::Obj(
            [("schema_version".to_string(), Json::Num(1.0))].into_iter().collect(),
        );
        assert!(diff_snapshots(&no_cases, &no_cases, 20.0).is_err());
        let bad_ns = snap(&[("m", f64::NAN, None)]);
        let ok = snap(&[("m", 1.0, None)]);
        assert!(diff_snapshots(&bad_ns, &ok, 20.0).is_err());
        assert!(diff_snapshots(&ok, &bad_ns, 20.0).is_err());
        assert!(diff_snapshots(&ok, &ok, f64::NAN).is_err());
    }
}
