//! Self-built micro/macro benchmark harness (criterion is unavailable in
//! the offline build): warmup, timed iterations, mean/p50/p99, throughput,
//! CSV emission, and a machine-readable JSON snapshot (`write_json`) for
//! the committed `BENCH_*.json` perf trajectory.

pub mod diff;

pub use diff::{diff_snapshots, BenchDelta, DiffReport};

use crate::util::{global_pool, Json, LatencyStats, Stopwatch};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
    /// Optional items/second (set via `Bench::throughput`).
    pub throughput: Option<f64>,
    /// Optional GFLOP/s (set via `Bench::gflops` where the case declares
    /// a flop count).
    pub gflops: Option<f64>,
}

impl BenchResult {
    pub fn row(&self) -> String {
        let tput = self
            .throughput
            .map(|t| format!(" {t:>12.1}/s"))
            .unwrap_or_default();
        let gf = self.gflops.map(|g| format!(" {g:>8.2} GFLOP/s")).unwrap_or_default();
        format!(
            "{:<40} {:>8} iters  mean {:>10.4}ms  p50 {:>10.4}ms  p99 {:>10.4}ms{}{}",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p99_ms, tput, gf
        )
    }
}

/// Benchmark runner.
pub struct Bench {
    /// Target measuring wall-time per case (the runner iterates until
    /// either this elapses or `max_iters` is hit).
    pub measure_secs: f64,
    pub warmup_iters: u64,
    pub max_iters: u64,
    pub min_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_secs: 1.0,
            warmup_iters: 3,
            max_iters: 10_000,
            min_iters: 5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bench { measure_secs: 0.3, warmup_iters: 1, max_iters: 200, ..Default::default() }
    }

    /// Time `f` repeatedly; records and returns the result.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut stats = LatencyStats::new();
        let total = Stopwatch::start();
        let mut iters = 0u64;
        while (total.elapsed().as_secs_f64() < self.measure_secs && iters < self.max_iters)
            || iters < self.min_iters
        {
            let sw = Stopwatch::start();
            f();
            stats.record(sw.elapsed_ms());
            iters += 1;
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ms: stats.mean(),
            p50_ms: stats.p50(),
            p99_ms: stats.p99(),
            min_ms: stats.min(),
            throughput: None,
            gflops: None,
        });
        println!("{}", self.results.last().unwrap().row());
        self.results.last().unwrap()
    }

    /// Attach a throughput figure (items per iteration) to the last case.
    pub fn throughput(&mut self, items_per_iter: f64) {
        if let Some(last) = self.results.last_mut() {
            last.throughput = Some(items_per_iter / (last.mean_ms / 1e3));
            println!("  ↳ {:.1} items/s", last.throughput.unwrap());
        }
    }

    /// Attach a GFLOP/s figure (declared GFLOP per iteration) to the last
    /// case.
    pub fn gflops(&mut self, gflop_per_iter: f64) {
        if let Some(last) = self.results.last_mut() {
            last.gflops = Some(gflop_per_iter / (last.mean_ms / 1e3));
            println!("  ↳ {:.2} GFLOP/s", last.gflops.unwrap());
        }
    }

    /// Record an externally measured scenario metric (macro benches that
    /// time one structured run rather than a tight loop): p50/p99/min are
    /// pinned to the mean.
    pub fn record(&mut self, name: &str, iters: u64, mean_ms: f64, throughput: Option<f64>) {
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ms,
            p50_ms: mean_ms,
            p99_ms: mean_ms,
            min_ms: mean_ms,
            throughput,
            gflops: None,
        });
        println!("{}", self.results.last().unwrap().row());
    }

    /// Write all results as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,iters,mean_ms,p50_ms,p99_ms,min_ms,throughput_per_s")?;
        for r in &self.results {
            writeln!(
                f,
                "{},{},{},{},{},{},{}",
                r.name,
                r.iters,
                r.mean_ms,
                r.p50_ms,
                r.p99_ms,
                r.min_ms,
                r.throughput.map(|t| t.to_string()).unwrap_or_default()
            )?;
        }
        Ok(())
    }

    /// Serialize all results as the machine-readable `BENCH_*.json`
    /// schema (see CI's bench-snapshot leg and `drrl bench-check`):
    /// schema_version, bench name, quick flag, host fingerprint, and one
    /// entry per case with ns/iter plus the full timing row.
    pub fn to_json(&self, bench_name: &str) -> Json {
        let mut root = BTreeMap::new();
        root.insert("schema_version".into(), Json::Num(1.0));
        root.insert("bench".into(), Json::Str(bench_name.to_string()));
        root.insert("quick".into(), Json::Bool(quick_mode()));
        let mut host = BTreeMap::new();
        host.insert("os".into(), Json::Str(std::env::consts::OS.to_string()));
        host.insert("arch".into(), Json::Str(std::env::consts::ARCH.to_string()));
        let n_cpus =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64;
        host.insert("n_cpus".into(), Json::Num(n_cpus));
        host.insert("pool_threads".into(), Json::Num(global_pool().size() as f64));
        root.insert("host".into(), Json::Obj(host));
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut c = BTreeMap::new();
                c.insert("name".into(), Json::Str(r.name.clone()));
                c.insert("iters".into(), Json::Num(r.iters as f64));
                c.insert("ns_per_iter".into(), Json::Num(r.mean_ms * 1e6));
                c.insert("mean_ms".into(), Json::Num(r.mean_ms));
                c.insert("p50_ms".into(), Json::Num(r.p50_ms));
                c.insert("p99_ms".into(), Json::Num(r.p99_ms));
                c.insert("min_ms".into(), Json::Num(r.min_ms));
                if let Some(t) = r.throughput {
                    c.insert("throughput_per_s".into(), Json::Num(t));
                }
                if let Some(g) = r.gflops {
                    c.insert("gflops".into(), Json::Num(g));
                }
                Json::Obj(c)
            })
            .collect();
        root.insert("cases".into(), Json::Arr(cases));
        Json::Obj(root)
    }

    /// Write the JSON snapshot to `path` (pretty-printed: the files are
    /// committed and diffed as the repo's perf trajectory).
    pub fn write_json(&self, path: &Path, bench_name: &str) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json(bench_name).to_string_pretty())
    }
}

/// Parse `--bench-json <path>` (or `--bench-json=path`) from argv — the
/// benches write their JSON snapshot there when present.
pub fn bench_json_path() -> Option<std::path::PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    for (i, a) in args.iter().enumerate() {
        if a == "--bench-json" {
            return args.get(i + 1).map(std::path::PathBuf::from);
        }
        if let Some(rest) = a.strip_prefix("--bench-json=") {
            return Some(std::path::PathBuf::from(rest));
        }
    }
    None
}

/// Write arbitrary experiment rows (non-timing tables/series) as CSV.
pub fn write_table_csv(path: &Path, header: &str, rows: &[String]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

/// Returns true when `--quick` or DRRL_BENCH_QUICK=1 — benches then run
/// reduced workloads (CI smoke).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("DRRL_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench banner.
pub fn banner(title: &str, paper_claim: &str) {
    println!("\n============================================================");
    println!("{title}");
    println!("paper: {paper_claim}");
    println!("============================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_case() {
        let mut b = Bench { measure_secs: 0.05, warmup_iters: 1, ..Default::default() };
        let mut acc = 0u64;
        b.case("spin", || {
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters >= 5);
        assert!(acc > 0);
    }

    #[test]
    fn json_snapshot_schema() {
        let mut b = Bench { measure_secs: 0.01, warmup_iters: 0, ..Default::default() };
        // Enough work that mean_ms is strictly positive on any clock, so
        // the derived gflops stays finite.
        let mut acc = 0u64;
        b.case("noop", || {
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(acc > 0);
        b.gflops(0.001);
        let j = b.to_json("unit");
        assert_eq!(j.get("schema_version").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("bench").and_then(|v| v.as_str()), Some("unit"));
        assert!(j.get("host").and_then(|h| h.get("n_cpus")).is_some());
        let cases = j.get("cases").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(cases.len(), 1);
        let c0 = &cases[0];
        assert_eq!(c0.get("name").and_then(|v| v.as_str()), Some("noop"));
        for field in ["iters", "ns_per_iter", "mean_ms", "p50_ms", "p99_ms", "min_ms", "gflops"] {
            let v = c0.get(field).and_then(|v| v.as_f64()).unwrap();
            assert!(v.is_finite(), "{field}");
        }
        // Round-trips through the parser (pretty output is valid JSON).
        let reparsed = crate::util::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(reparsed.get("bench").and_then(|v| v.as_str()), Some("unit"));
    }

    #[test]
    fn csv_roundtrip() {
        let mut b = Bench { measure_secs: 0.01, warmup_iters: 0, ..Default::default() };
        b.case("noop", || {});
        b.throughput(100.0);
        let path = std::env::temp_dir().join("drrl_bench_test.csv");
        b.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,iters"));
        assert!(text.contains("noop"));
        let _ = std::fs::remove_file(path);
    }
}
