//! Self-built micro/macro benchmark harness (criterion is unavailable in
//! the offline build): warmup, timed iterations, mean/p50/p99, throughput
//! and CSV emission for the experiment benches in `rust/benches/`.

use crate::util::{LatencyStats, Stopwatch};
use std::io::Write;
use std::path::Path;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub min_ms: f64,
    /// Optional items/second (set via `Bench::throughput`).
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn row(&self) -> String {
        let tput = self
            .throughput
            .map(|t| format!(" {t:>12.1}/s"))
            .unwrap_or_default();
        format!(
            "{:<40} {:>8} iters  mean {:>10.4}ms  p50 {:>10.4}ms  p99 {:>10.4}ms{}",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p99_ms, tput
        )
    }
}

/// Benchmark runner.
pub struct Bench {
    /// Target measuring wall-time per case (the runner iterates until
    /// either this elapses or `max_iters` is hit).
    pub measure_secs: f64,
    pub warmup_iters: u64,
    pub max_iters: u64,
    pub min_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_secs: 1.0,
            warmup_iters: 3,
            max_iters: 10_000,
            min_iters: 5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bench { measure_secs: 0.3, warmup_iters: 1, max_iters: 200, ..Default::default() }
    }

    /// Time `f` repeatedly; records and returns the result.
    pub fn case<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut stats = LatencyStats::new();
        let total = Stopwatch::start();
        let mut iters = 0u64;
        while (total.elapsed().as_secs_f64() < self.measure_secs && iters < self.max_iters)
            || iters < self.min_iters
        {
            let sw = Stopwatch::start();
            f();
            stats.record(sw.elapsed_ms());
            iters += 1;
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ms: stats.mean(),
            p50_ms: stats.p50(),
            p99_ms: stats.p99(),
            min_ms: stats.min(),
            throughput: None,
        });
        println!("{}", self.results.last().unwrap().row());
        self.results.last().unwrap()
    }

    /// Attach a throughput figure (items per iteration) to the last case.
    pub fn throughput(&mut self, items_per_iter: f64) {
        if let Some(last) = self.results.last_mut() {
            last.throughput = Some(items_per_iter / (last.mean_ms / 1e3));
            println!("  ↳ {:.1} items/s", last.throughput.unwrap());
        }
    }

    /// Write all results as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,iters,mean_ms,p50_ms,p99_ms,min_ms,throughput_per_s")?;
        for r in &self.results {
            writeln!(
                f,
                "{},{},{},{},{},{},{}",
                r.name,
                r.iters,
                r.mean_ms,
                r.p50_ms,
                r.p99_ms,
                r.min_ms,
                r.throughput.map(|t| t.to_string()).unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

/// Write arbitrary experiment rows (non-timing tables/series) as CSV.
pub fn write_table_csv(path: &Path, header: &str, rows: &[String]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

/// Returns true when `--quick` or DRRL_BENCH_QUICK=1 — benches then run
/// reduced workloads (CI smoke).
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("DRRL_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench banner.
pub fn banner(title: &str, paper_claim: &str) {
    println!("\n============================================================");
    println!("{title}");
    println!("paper: {paper_claim}");
    println!("============================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_case() {
        let mut b = Bench { measure_secs: 0.05, warmup_iters: 1, ..Default::default() };
        let mut acc = 0u64;
        b.case("spin", || {
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters >= 5);
        assert!(acc > 0);
    }

    #[test]
    fn csv_roundtrip() {
        let mut b = Bench { measure_secs: 0.01, warmup_iters: 0, ..Default::default() };
        b.case("noop", || {});
        b.throughput(100.0);
        let path = std::env::temp_dir().join("drrl_bench_test.csv");
        b.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,iters"));
        assert!(text.contains("noop"));
        let _ = std::fs::remove_file(path);
    }
}
