//! Byte-level tokenizer (vocab = 256) — matches the LM artifact's vocab
//! and needs no learned merges, keeping the data path fully
//! deterministic and dependency-free.

/// Byte tokenizer; token id = byte value.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the quick brown fox 123!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn ids_in_vocab() {
        let t = ByteTokenizer;
        for id in t.encode("héllo ☃") {
            assert!((0..256).contains(&id));
        }
    }

    #[test]
    fn decode_clamps_out_of_range() {
        let t = ByteTokenizer;
        let s = t.decode(&[72, 105, 999, -5]);
        assert!(s.starts_with("Hi"));
    }
}
