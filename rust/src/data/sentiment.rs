//! Synthetic SST-2-like sentiment task (DESIGN.md §2 substitution for
//! GLUE). Template-generated sentences with lexical sentiment carriers,
//! negation flips and neutral filler — the degradation mechanism the
//! paper measures (rank starvation losing carrier-token attention) is
//! exercised directly.

use super::tokenizer::ByteTokenizer;
use crate::util::Pcg32;

const POSITIVE: &[&str] = &[
    "wonderful", "brilliant", "delightful", "moving", "superb", "charming", "gripping",
    "masterful", "heartfelt", "dazzling",
];

const NEGATIVE: &[&str] = &[
    "dreadful", "tedious", "clumsy", "hollow", "bland", "grating", "lifeless", "muddled",
    "shallow", "dismal",
];

const SUBJECTS: &[&str] =
    &["the film", "this movie", "the plot", "the acting", "the script", "the direction",
      "the cast", "the pacing"];

const FILLER: &[&str] = &[
    "in its second act", "from start to finish", "despite the runtime",
    "for the most part", "in every scene", "by any measure",
];

/// One labelled example.
#[derive(Debug, Clone)]
pub struct SentimentExample {
    /// Byte-level tokens (for the LM-compatible path).
    pub tokens: Vec<i32>,
    /// Word-level tokens over the closed template vocabulary (for the
    /// classifier — sentiment carriers stay single tokens).
    pub word_tokens: Vec<i32>,
    /// 1 = positive.
    pub label: usize,
    pub text: String,
}

/// Closed word vocabulary of the template language. Index 0 is padding,
/// 1 is <unk>.
pub fn word_vocab() -> Vec<String> {
    let mut v: Vec<String> = vec!["<pad>".into(), "<unk>".into()];
    let mut push_words = |words: &[&str]| {
        for w in words {
            for part in w.split_whitespace() {
                let p = part.trim_matches(|c: char| !c.is_alphanumeric()).to_lowercase();
                if !p.is_empty() && !v.iter().any(|x| x == &p) {
                    v.push(p);
                }
            }
        }
    };
    push_words(POSITIVE);
    push_words(NEGATIVE);
    push_words(SUBJECTS);
    push_words(FILLER);
    push_words(&["is", "not"]);
    v
}

/// Encode text over the closed vocabulary (whitespace split, punctuation
/// stripped, lowercase).
pub fn encode_words(text: &str, vocab: &[String], seq_len: usize) -> Vec<i32> {
    let mut out: Vec<i32> = text
        .split_whitespace()
        .map(|w| {
            let p = w.trim_matches(|c: char| !c.is_alphanumeric()).to_lowercase();
            vocab.iter().position(|x| x == &p).unwrap_or(1) as i32
        })
        .collect();
    out.resize(seq_len, 0);
    out
}

/// Generate a balanced labelled dataset. ~15% of examples contain a
/// negation ("not", flipping the carrier), which is what separates
/// attention-based classifiers from bag-of-words.
pub fn generate_dataset(n: usize, seq_len: usize, seed: u64) -> Vec<SentimentExample> {
    let mut rng = Pcg32::new(seed, 0x5E47);
    let tok = ByteTokenizer;
    let vocab = word_vocab();
    let word_len = 12;
    (0..n)
        .map(|i| {
            let positive = i % 2 == 0;
            let negate = rng.next_f64() < 0.15;
            // The carried sentiment is flipped if negated.
            let carrier_positive = positive ^ negate;
            let carrier = if carrier_positive {
                POSITIVE[rng.range(0, POSITIVE.len())]
            } else {
                NEGATIVE[rng.range(0, NEGATIVE.len())]
            };
            let subject = SUBJECTS[rng.range(0, SUBJECTS.len())];
            let filler = FILLER[rng.range(0, FILLER.len())];
            let text = if negate {
                format!("{subject} is not {carrier} {filler}.")
            } else {
                format!("{subject} is {carrier} {filler}.")
            };
            let mut tokens = tok.encode(&text);
            tokens.resize(seq_len, b' ' as i32); // pad / truncate
            let word_tokens = encode_words(&text, &vocab, word_len);
            SentimentExample { tokens, word_tokens, label: usize::from(positive), text }
        })
        .collect()
}

/// Train/test split helper.
pub fn split(data: Vec<SentimentExample>, train_frac: f64) -> (Vec<SentimentExample>, Vec<SentimentExample>) {
    let k = (data.len() as f64 * train_frac) as usize;
    let mut d = data;
    let test = d.split_off(k);
    (d, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_labels() {
        let d = generate_dataset(100, 64, 1);
        let pos = d.iter().filter(|e| e.label == 1).count();
        assert_eq!(pos, 50);
    }

    #[test]
    fn tokens_padded_to_len() {
        let d = generate_dataset(10, 48, 2);
        assert!(d.iter().all(|e| e.tokens.len() == 48));
    }

    #[test]
    fn negation_flips_carrier() {
        let d = generate_dataset(400, 96, 3);
        let negated: Vec<_> = d.iter().filter(|e| e.text.contains(" not ")).collect();
        assert!(!negated.is_empty());
        for e in negated {
            let has_neg_word = NEGATIVE.iter().any(|w| e.text.contains(w));
            let has_pos_word = POSITIVE.iter().any(|w| e.text.contains(w));
            if e.label == 1 {
                // positive + negation ⇒ negative carrier word in text
                assert!(has_neg_word && !has_pos_word, "{}", e.text);
            } else {
                assert!(has_pos_word && !has_neg_word, "{}", e.text);
            }
        }
    }

    #[test]
    fn split_fractions() {
        let d = generate_dataset(100, 32, 4);
        let (tr, te) = split(d, 0.8);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
    }

    #[test]
    fn deterministic() {
        let a = generate_dataset(5, 32, 9);
        let b = generate_dataset(5, 32, 9);
        assert_eq!(a[3].text, b[3].text);
    }
}
