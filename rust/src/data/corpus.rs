//! Synthetic corpora with matched statistical profiles (DESIGN.md §2).
//!
//! The paper evaluates on Wikitext-103, PTB and BookCorpus — none of
//! which ship with this repo. The generators below produce text whose
//! *statistics* drive the same mechanisms the paper measures: Zipfian
//! unigram frequencies, Markov topic structure (attention heads latch
//! onto topic transitions), repeated named entities (high-rank targets)
//! and filler phrases (low-rank redundancy).

use super::tokenizer::ByteTokenizer;
use crate::util::Pcg32;

/// Which statistical profile to synthesize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusProfile {
    /// "wiki103-sim": large vocabulary mix, encyclopedic sentence frames,
    /// heavy named-entity reuse.
    Wiki103,
    /// "ptb-sim": small vocabulary, short newswire sentences.
    Ptb,
    /// "book-sim": long narrative runs, dialogue, high filler ratio.
    Book,
}

impl CorpusProfile {
    pub fn name(&self) -> &'static str {
        match self {
            CorpusProfile::Wiki103 => "wiki103-sim",
            CorpusProfile::Ptb => "ptb-sim",
            CorpusProfile::Book => "book-sim",
        }
    }

    pub fn all() -> [CorpusProfile; 3] {
        [CorpusProfile::Wiki103, CorpusProfile::Ptb, CorpusProfile::Book]
    }
}

const ENTITIES: &[&str] = &[
    "Aldera", "Boreth", "Cassian", "Dravos", "Eleth", "Fenwick", "Galdor", "Hestia", "Ilmar",
    "Jorvik", "Kaelen", "Lyra", "Morvan", "Nerith", "Oskar", "Pellar",
];

const WIKI_FRAMES: &[&str] = &[
    "{E} is a city in the northern province of {E}.",
    "The {N} of {E} was established in the year {Y}.",
    "{E} served as the capital of {E} until {Y}.",
    "According to the census of {Y}, {E} had a population of {Y}.",
    "The {N} connects {E} with the region of {E}.",
    "{E} was renamed after the {N} of {Y}.",
];

const PTB_FRAMES: &[&str] = &[
    "{E} corp said its {N} rose to {Y} from {Y}.",
    "shares of {E} fell {Y} points.",
    "the {N} board approved the {N} of {E}.",
    "{E} posted a {N} loss of {Y}.",
    "analysts expect the {N} to reach {Y}.",
];

const BOOK_FRAMES: &[&str] = &[
    "{E} walked slowly through the {N}, thinking of {E}.",
    "\"I never believed the {N},\" said {E} quietly.",
    "the {N} stretched on and on, and {E} kept walking.",
    "night fell over the {N} while {E} waited for {E}.",
    "it was the kind of {N} that {E} remembered from childhood.",
    "and so the days passed, one after another, quiet and slow.",
];

const NOUNS: &[&str] = &[
    "river", "council", "market", "quarter", "library", "treaty", "harvest", "railway",
    "festival", "garden", "border", "archive", "station", "valley", "forest", "road",
];

/// Zipf sampler over a word list: P(i) ∝ 1/(i+1)^s.
fn zipf_pick<'a>(words: &'a [&'a str], s: f64, rng: &mut Pcg32) -> &'a str {
    let weights: Vec<f64> = (0..words.len()).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    words[rng.weighted(&weights)]
}

/// Generate `n_bytes` of synthetic text for the profile.
pub fn generate_text(profile: CorpusProfile, n_bytes: usize, seed: u64) -> String {
    let mut rng = Pcg32::new(seed, profile as u64 + 1);
    let frames = match profile {
        CorpusProfile::Wiki103 => WIKI_FRAMES,
        CorpusProfile::Ptb => PTB_FRAMES,
        CorpusProfile::Book => BOOK_FRAMES,
    };
    let zipf_s = match profile {
        CorpusProfile::Wiki103 => 1.1,
        CorpusProfile::Ptb => 1.4, // small effective vocab
        CorpusProfile::Book => 0.9,
    };
    // Markov topic state: a small set of "active" entities that recur
    // until a topic transition resamples them.
    let mut topic: Vec<&str> = (0..3).map(|_| ENTITIES[rng.range(0, ENTITIES.len())]).collect();
    let mut out = String::with_capacity(n_bytes + 128);
    while out.len() < n_bytes {
        if rng.next_f64() < 0.15 {
            // Topic transition (context shift → spectrum-dense region).
            let slot = rng.range(0, topic.len());
            topic[slot] = ENTITIES[rng.range(0, ENTITIES.len())];
        }
        let frame = frames[rng.range(0, frames.len())];
        let mut sentence = String::with_capacity(frame.len() + 16);
        let mut chars = frame.chars().peekable();
        while let Some(c) = chars.next() {
            if c == '{' {
                let kind = chars.next().unwrap_or('N');
                let _ = chars.next(); // closing '}'
                match kind {
                    'E' => sentence.push_str(topic[rng.range(0, topic.len())]),
                    'N' => sentence.push_str(zipf_pick(NOUNS, zipf_s, &mut rng)),
                    'Y' => {
                        let y = 1800 + rng.range(0, 230);
                        sentence.push_str(&y.to_string());
                    }
                    _ => {}
                }
            } else {
                sentence.push(c);
            }
        }
        out.push_str(&sentence);
        out.push(' ');
    }
    out.truncate(n_bytes);
    out
}

/// A tokenized corpus with train/valid split.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub profile: CorpusProfile,
    pub train: Vec<i32>,
    pub valid: Vec<i32>,
}

impl Corpus {
    /// Build a corpus of `n_bytes` total (90/10 split).
    pub fn build(profile: CorpusProfile, n_bytes: usize, seed: u64) -> Corpus {
        let text = generate_text(profile, n_bytes, seed);
        let tokens = ByteTokenizer.encode(&text);
        let split = tokens.len() * 9 / 10;
        Corpus { profile, train: tokens[..split].to_vec(), valid: tokens[split..].to_vec() }
    }

    /// Sample a (tokens, targets) LM batch: targets are tokens shifted
    /// left by one within each window.
    pub fn sample_batch(
        &self,
        split_train: bool,
        batch: usize,
        seq_len: usize,
        rng: &mut Pcg32,
    ) -> (Vec<i32>, Vec<i32>) {
        let data = if split_train { &self.train } else { &self.valid };
        assert!(data.len() > seq_len + 1, "corpus too small");
        let mut tokens = Vec::with_capacity(batch * seq_len);
        let mut targets = Vec::with_capacity(batch * seq_len);
        for _ in 0..batch {
            let start = rng.range(0, data.len() - seq_len - 1);
            tokens.extend_from_slice(&data[start..start + seq_len]);
            targets.extend_from_slice(&data[start + 1..start + seq_len + 1]);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_size() {
        for p in CorpusProfile::all() {
            let t = generate_text(p, 10_000, 1);
            assert_eq!(t.len(), 10_000);
        }
    }

    #[test]
    fn profiles_have_distinct_statistics() {
        let a = generate_text(CorpusProfile::Wiki103, 20_000, 2);
        let b = generate_text(CorpusProfile::Book, 20_000, 2);
        assert_ne!(a[..500], b[..500]);
        // Book profile has dialogue quotes; ptb has lowercase finance.
        assert!(b.contains('"'));
        assert!(generate_text(CorpusProfile::Ptb, 20_000, 2).contains("shares"));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate_text(CorpusProfile::Wiki103, 5_000, 7);
        let b = generate_text(CorpusProfile::Wiki103, 5_000, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn entity_reuse_creates_repetition() {
        // Topic persistence means entities repeat within a window far
        // more often than under independent sampling.
        let t = generate_text(CorpusProfile::Wiki103, 50_000, 3);
        let hits = ENTITIES.iter().map(|e| t.matches(e).count()).max().unwrap();
        assert!(hits > 20, "max entity count {hits}");
    }

    #[test]
    fn batches_shift_targets_by_one() {
        let c = Corpus::build(CorpusProfile::Ptb, 50_000, 4);
        let mut rng = Pcg32::seeded(5);
        let (tok, tgt) = c.sample_batch(true, 4, 32, &mut rng);
        assert_eq!(tok.len(), 4 * 32);
        assert_eq!(tgt.len(), 4 * 32);
        // Within each row, tgt[i] should equal tok[i+1].
        for b in 0..4 {
            for i in 0..31 {
                assert_eq!(tgt[b * 32 + i], tok[b * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn split_sizes() {
        let c = Corpus::build(CorpusProfile::Book, 10_000, 6);
        assert!(c.train.len() > c.valid.len());
        assert_eq!(c.train.len() + c.valid.len(), 10_000);
    }
}
