//! Data substrate: byte tokenizer, synthetic corpora with matched
//! statistical profiles (wiki103-sim / ptb-sim / book-sim) and the
//! synthetic sentiment task (DESIGN.md §2 substitutions).

pub mod corpus;
pub mod sentiment;
pub mod tokenizer;

pub use corpus::{generate_text, Corpus, CorpusProfile};
pub use sentiment::{generate_dataset, split, SentimentExample};
pub use tokenizer::ByteTokenizer;
