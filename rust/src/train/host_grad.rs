//! Host gradient of the decoder LM + the fused AdamW update — the pure-
//! Rust equivalent of the `lm_train_step` artifact
//! (python/compile/model.py::train_step), closing the host backend's
//! last PJRT-only gap so [`super::LmTrainer`] runs fully offline.
//!
//! The forward mirrors [`super::HostLm`] under full-rank causal
//! attention (the differentiable train path — the low-rank approximators
//! are a serving-time substitution, exactly as in the AOT graph, which
//! trains through the `ref` attention oracle). The backward is a
//! hand-written reverse pass over the same flat f32 parameter layout:
//! cross-entropy → unembedding → final layernorm → per-layer FFN/GELU,
//! layernorm, causal-softmax attention and QKV/output projections →
//! positional/token embeddings. Gradients accumulate in f64 and cross
//! back to f32 only at the AdamW update, matching the boundary precision
//! of the device path.

use crate::linalg::{matmul, matmul_at, matmul_bt, Mat};
use crate::runtime::LmShape;
use anyhow::Result;

struct LayerParams {
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
    w1: Mat,
    b1: Vec<f64>,
    w2: Mat,
    b2: Vec<f64>,
}

struct Params {
    embed: Mat, // V × d
    pos: Mat,   // L × d
    layers: Vec<LayerParams>,
    lnf_g: Vec<f64>,
    lnf_b: Vec<f64>,
    head: Mat, // d × V
}

/// Gradient accumulator with the same structure; flattened back into the
/// AOT layout at the end (so no offset bookkeeping can drift from the
/// parse order).
struct Grads {
    embed: Mat,
    pos: Mat,
    layers: Vec<LayerGrads>,
    lnf_g: Vec<f64>,
    lnf_b: Vec<f64>,
    head: Mat,
}

struct LayerGrads {
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
    w1: Mat,
    b1: Vec<f64>,
    w2: Mat,
    b2: Vec<f64>,
}

fn parse(params: &[f32], shape: &LmShape) -> Params {
    assert_eq!(params.len(), shape.param_count, "param vector size");
    let d = shape.d_model;
    let mut off = 0usize;
    let mut take = |rows: usize, cols: usize| -> Mat {
        let n = rows * cols;
        let m = Mat::from_f32(rows, cols, &params[off..off + n]);
        off += n;
        m
    };
    // Order MUST mirror python model.py::param_slices / HostLm::from_flat.
    let embed = take(shape.vocab, d);
    let pos = take(shape.seq_len, d);
    let mut layers = Vec::with_capacity(shape.n_layers);
    for _ in 0..shape.n_layers {
        layers.push(LayerParams {
            ln1_g: take(1, d).into_vec(),
            ln1_b: take(1, d).into_vec(),
            wq: take(d, d),
            wk: take(d, d),
            wv: take(d, d),
            wo: take(d, d),
            ln2_g: take(1, d).into_vec(),
            ln2_b: take(1, d).into_vec(),
            w1: take(d, shape.d_ff),
            b1: take(1, shape.d_ff).into_vec(),
            w2: take(shape.d_ff, d),
            b2: take(1, d).into_vec(),
        });
    }
    let lnf_g = take(1, d).into_vec();
    let lnf_b = take(1, d).into_vec();
    let head = take(d, shape.vocab);
    Params { embed, pos, layers, lnf_g, lnf_b, head }
}

impl Grads {
    fn zeros(shape: &LmShape) -> Grads {
        let d = shape.d_model;
        Grads {
            embed: Mat::zeros(shape.vocab, d),
            pos: Mat::zeros(shape.seq_len, d),
            layers: (0..shape.n_layers)
                .map(|_| LayerGrads {
                    ln1_g: vec![0.0; d],
                    ln1_b: vec![0.0; d],
                    wq: Mat::zeros(d, d),
                    wk: Mat::zeros(d, d),
                    wv: Mat::zeros(d, d),
                    wo: Mat::zeros(d, d),
                    ln2_g: vec![0.0; d],
                    ln2_b: vec![0.0; d],
                    w1: Mat::zeros(d, shape.d_ff),
                    b1: vec![0.0; shape.d_ff],
                    w2: Mat::zeros(shape.d_ff, d),
                    b2: vec![0.0; d],
                })
                .collect(),
            lnf_g: vec![0.0; d],
            lnf_b: vec![0.0; d],
            head: Mat::zeros(d, shape.vocab),
        }
    }

    /// Flatten into the AOT parameter layout as f32.
    fn into_flat(self, shape: &LmShape) -> Vec<f32> {
        let mut out: Vec<f32> = Vec::with_capacity(shape.param_count);
        let push_mat = |out: &mut Vec<f32>, m: &Mat| {
            out.extend(m.data().iter().map(|&x| x as f32));
        };
        let push_vec = |out: &mut Vec<f32>, v: &[f64]| {
            out.extend(v.iter().map(|&x| x as f32));
        };
        push_mat(&mut out, &self.embed);
        push_mat(&mut out, &self.pos);
        for l in &self.layers {
            push_vec(&mut out, &l.ln1_g);
            push_vec(&mut out, &l.ln1_b);
            push_mat(&mut out, &l.wq);
            push_mat(&mut out, &l.wk);
            push_mat(&mut out, &l.wv);
            push_mat(&mut out, &l.wo);
            push_vec(&mut out, &l.ln2_g);
            push_vec(&mut out, &l.ln2_b);
            push_mat(&mut out, &l.w1);
            push_vec(&mut out, &l.b1);
            push_mat(&mut out, &l.w2);
            push_vec(&mut out, &l.b2);
        }
        push_vec(&mut out, &self.lnf_g);
        push_vec(&mut out, &self.lnf_b);
        push_mat(&mut out, &self.head);
        debug_assert_eq!(out.len(), shape.param_count);
        out
    }
}

// ── layernorm with cached normalization state ──

struct LnCache {
    xhat: Mat,
    inv: Vec<f64>,
}

fn ln_forward(x: &Mat, g: &[f64], b: &[f64]) -> (Mat, LnCache) {
    let (n, d) = x.shape();
    let mut y = Mat::zeros(n, d);
    let mut xhat = Mat::zeros(n, d);
    let mut inv = vec![0.0; n];
    for i in 0..n {
        let row = x.row(i);
        let mu = row.iter().sum::<f64>() / d as f64;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / d as f64;
        let iv = 1.0 / (var + 1e-5).sqrt();
        inv[i] = iv;
        for j in 0..d {
            let h = (row[j] - mu) * iv;
            xhat.row_mut(i)[j] = h;
            y.row_mut(i)[j] = h * g[j] + b[j];
        }
    }
    (y, LnCache { xhat, inv })
}

fn ln_backward(
    dy: &Mat,
    cache: &LnCache,
    g: &[f64],
    dg: &mut [f64],
    db: &mut [f64],
) -> Mat {
    let (n, d) = dy.shape();
    let mut dx = Mat::zeros(n, d);
    for i in 0..n {
        let dyr = dy.row(i);
        let xh = cache.xhat.row(i);
        for j in 0..d {
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        // dxhat = dy ⊙ g; dx = inv·(dxhat − mean(dxhat) − xhat·mean(dxhat⊙xhat)).
        let mut mean_dxh = 0.0;
        let mut mean_dxh_xh = 0.0;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            mean_dxh += dxh;
            mean_dxh_xh += dxh * xh[j];
        }
        mean_dxh /= d as f64;
        mean_dxh_xh /= d as f64;
        let dxr = dx.row_mut(i);
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dxr[j] = cache.inv[i] * (dxh - mean_dxh - xh[j] * mean_dxh_xh);
        }
    }
    dx
}

// ── gelu (tanh approximation, matching jax.nn.gelu) ──

fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + ((2.0 / std::f64::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

fn gelu_grad(x: f64) -> f64 {
    let c = (2.0 / std::f64::consts::PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * c * (1.0 + 3.0 * 0.044715 * x * x)
}

// ── causal softmax attention with cached attention matrices ──

fn slice_head(m: &Mat, lo: usize, hi: usize) -> Mat {
    let n = m.rows();
    let mut out = Mat::zeros(n, hi - lo);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&m.row(i)[lo..hi]);
    }
    out
}

fn scatter_head(dst: &mut Mat, src: &Mat, lo: usize) {
    for i in 0..src.rows() {
        let row = dst.row_mut(i);
        for (j, &v) in src.row(i).iter().enumerate() {
            row[lo + j] += v;
        }
    }
}

/// Forward one causal softmax head; returns (Y, A).
fn attn_forward(q: &Mat, k: &Mat, v: &Mat) -> (Mat, Mat) {
    let (n, hd) = q.shape();
    let scale = 1.0 / (hd as f64).sqrt();
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        let qi = q.row(i);
        let mut max = f64::NEG_INFINITY;
        let mut scores = vec![0.0f64; i + 1];
        for (j, s) in scores.iter_mut().enumerate() {
            let kj = k.row(j);
            *s = qi.iter().zip(kj).map(|(x, y)| x * y).sum::<f64>() * scale;
            max = max.max(*s);
        }
        let mut denom = 0.0;
        for s in scores.iter_mut() {
            *s = (*s - max).exp();
            denom += *s;
        }
        let ar = a.row_mut(i);
        for (j, &s) in scores.iter().enumerate() {
            ar[j] = s / denom;
        }
    }
    (matmul(&a, v), a)
}

/// Backward one head: given dY and the cached A, accumulate (dQ, dK, dV).
fn attn_backward(dy: &Mat, a: &Mat, q: &Mat, k: &Mat, v: &Mat) -> (Mat, Mat, Mat) {
    let (n, hd) = q.shape();
    let scale = 1.0 / (hd as f64).sqrt();
    let dv = matmul_at(a, dy); // Aᵀ·dY
    let da = matmul_bt(dy, v); // dY·Vᵀ
    // dS = A ⊙ (dA − rowsum(dA ⊙ A)); masked (j > i) entries have A = 0.
    let mut ds = Mat::zeros(n, n);
    for i in 0..n {
        let ar = a.row(i);
        let dar = da.row(i);
        let dot: f64 = ar.iter().zip(dar).map(|(x, y)| x * y).sum();
        let dsr = ds.row_mut(i);
        for j in 0..=i {
            dsr[j] = ar[j] * (dar[j] - dot) * scale;
        }
    }
    let dq = matmul(&ds, k);
    let dk = matmul_at(&ds, q); // dSᵀ·Q
    (dq, dk, dv)
}

/// Loss and flat gradient of the mean next-token cross-entropy over one
/// (B, L) batch under full-rank causal attention. The gradient layout is
/// the AOT flat parameter layout.
pub fn lm_loss_and_grad(
    params: &[f32],
    shape: &LmShape,
    tokens: &[i32],
    targets: &[i32],
) -> Result<(f64, Vec<f32>)> {
    let (b, n, d) = (shape.batch, shape.seq_len, shape.d_model);
    anyhow::ensure!(params.len() == shape.param_count, "param vector size");
    anyhow::ensure!(tokens.len() == b * n && targets.len() == b * n, "token batch shape");
    let p = parse(params, shape);
    let mut g = Grads::zeros(shape);
    let n_heads = shape.n_heads;
    let hd = d / n_heads;
    let total_positions = (b * n) as f64;
    let mut total_loss = 0.0;

    for row in 0..b {
        let toks = &tokens[row * n..(row + 1) * n];
        let tgts = &targets[row * n..(row + 1) * n];
        let clamp = |t: i32| t.clamp(0, shape.vocab as i32 - 1) as usize;

        // ── forward with caches ──
        let mut x = Mat::zeros(n, d);
        for (i, &t) in toks.iter().enumerate() {
            let e = p.embed.row(clamp(t));
            let ps = p.pos.row(i);
            for (j, v) in x.row_mut(i).iter_mut().enumerate() {
                *v = e[j] + ps[j];
            }
        }
        struct LayerCache {
            h: Mat,
            ln1: LnCache,
            q: Mat,
            k: Mat,
            v: Mat,
            heads_a: Vec<Mat>,
            cat: Mat,
            h2: Mat,
            ln2: LnCache,
            ff_pre: Mat,
        }
        let mut caches: Vec<LayerCache> = Vec::with_capacity(shape.n_layers);
        for lp in &p.layers {
            let (h, ln1) = ln_forward(&x, &lp.ln1_g, &lp.ln1_b);
            let q = matmul(&h, &lp.wq);
            let k = matmul(&h, &lp.wk);
            let v = matmul(&h, &lp.wv);
            let mut cat = Mat::zeros(n, d);
            let mut heads_a = Vec::with_capacity(n_heads);
            for head in 0..n_heads {
                let (lo, hi) = (head * hd, (head + 1) * hd);
                let (y, a) =
                    attn_forward(&slice_head(&q, lo, hi), &slice_head(&k, lo, hi), &slice_head(&v, lo, hi));
                for i in 0..n {
                    cat.row_mut(i)[lo..hi].copy_from_slice(y.row(i));
                }
                heads_a.push(a);
            }
            x.add_inplace(&matmul(&cat, &lp.wo));
            let (h2, ln2) = ln_forward(&x, &lp.ln2_g, &lp.ln2_b);
            let mut ff_pre = matmul(&h2, &lp.w1);
            for i in 0..n {
                for (j, v) in ff_pre.row_mut(i).iter_mut().enumerate() {
                    *v += lp.b1[j];
                }
            }
            let ff_act = ff_pre.map(gelu);
            let mut ff2 = matmul(&ff_act, &lp.w2);
            for i in 0..n {
                for (j, v) in ff2.row_mut(i).iter_mut().enumerate() {
                    *v += lp.b2[j];
                }
            }
            x.add_inplace(&ff2);
            caches.push(LayerCache { h, ln1, q, k, v, heads_a, cat, h2, ln2, ff_pre });
        }
        let (xf, lnf) = ln_forward(&x, &p.lnf_g, &p.lnf_b);
        let logits = matmul(&xf, &p.head);

        // ── loss + dlogits (softmax − onehot, scaled by 1/(B·L)) ──
        let mut dlogits = Mat::zeros(n, shape.vocab);
        for i in 0..n {
            let lr = logits.row(i);
            let max = lr.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let denom: f64 = lr.iter().map(|v| (v - max).exp()).sum();
            let lse = max + denom.ln();
            let t = clamp(tgts[i]);
            total_loss += lse - lr[t];
            let dr = dlogits.row_mut(i);
            for j in 0..shape.vocab {
                dr[j] = ((lr[j] - max).exp() / denom
                    - if j == t { 1.0 } else { 0.0 })
                    / total_positions;
            }
        }

        // ── backward ──
        g.head.add_inplace(&matmul_at(&xf, &dlogits));
        let dxf = matmul_bt(&dlogits, &p.head);
        let mut dx = ln_backward(&dxf, &lnf, &p.lnf_g, &mut g.lnf_g, &mut g.lnf_b);

        for (li, lp) in p.layers.iter().enumerate().rev() {
            let c = &caches[li];
            let gl = &mut g.layers[li];
            // FFN sublayer: x_out = x_mid + gelu(ff_pre)·w2 + b2.
            let ff_act = c.ff_pre.map(gelu);
            for i in 0..n {
                for (j, &v) in dx.row(i).iter().enumerate() {
                    gl.b2[j] += v;
                }
            }
            gl.w2.add_inplace(&matmul_at(&ff_act, &dx));
            let dff_act = matmul_bt(&dx, &lp.w2);
            let mut dff_pre = Mat::zeros(n, shape.d_ff);
            for i in 0..n {
                let pre = c.ff_pre.row(i);
                let da = dff_act.row(i);
                let dp = dff_pre.row_mut(i);
                for j in 0..shape.d_ff {
                    dp[j] = da[j] * gelu_grad(pre[j]);
                    gl.b1[j] += dp[j];
                }
            }
            gl.w1.add_inplace(&matmul_at(&c.h2, &dff_pre));
            let dh2 = matmul_bt(&dff_pre, &lp.w1);
            // Residual: dx (through the skip) + LN2 backward into x_mid.
            dx.add_inplace(&ln_backward(&dh2, &c.ln2, &lp.ln2_g, &mut gl.ln2_g, &mut gl.ln2_b));

            // Attention sublayer: x_mid = x_in + cat·wo.
            gl.wo.add_inplace(&matmul_at(&c.cat, &dx));
            let dcat = matmul_bt(&dx, &lp.wo);
            let mut dq_full = Mat::zeros(n, d);
            let mut dk_full = Mat::zeros(n, d);
            let mut dv_full = Mat::zeros(n, d);
            for head in 0..n_heads {
                let (lo, hi) = (head * hd, (head + 1) * hd);
                let (dq, dk, dv) = attn_backward(
                    &slice_head(&dcat, lo, hi),
                    &c.heads_a[head],
                    &slice_head(&c.q, lo, hi),
                    &slice_head(&c.k, lo, hi),
                    &slice_head(&c.v, lo, hi),
                );
                scatter_head(&mut dq_full, &dq, lo);
                scatter_head(&mut dk_full, &dk, lo);
                scatter_head(&mut dv_full, &dv, lo);
            }
            gl.wq.add_inplace(&matmul_at(&c.h, &dq_full));
            gl.wk.add_inplace(&matmul_at(&c.h, &dk_full));
            gl.wv.add_inplace(&matmul_at(&c.h, &dv_full));
            let mut dh = matmul_bt(&dq_full, &lp.wq);
            dh.add_inplace(&matmul_bt(&dk_full, &lp.wk));
            dh.add_inplace(&matmul_bt(&dv_full, &lp.wv));
            dx.add_inplace(&ln_backward(&dh, &c.ln1, &lp.ln1_g, &mut gl.ln1_g, &mut gl.ln1_b));
        }

        // Embeddings.
        for (i, &t) in toks.iter().enumerate() {
            let dr = dx.row(i);
            let er = g.embed.row_mut(clamp(t));
            for (e, &v) in er.iter_mut().zip(dr) {
                *e += v;
            }
            let pr = g.pos.row_mut(i);
            for (pv, &v) in pr.iter_mut().zip(dr) {
                *pv += v;
            }
        }
    }

    Ok((total_loss / total_positions, g.into_flat(shape)))
}

/// One fused AdamW update over the flat vectors, matching the AOT
/// train-step hyperparameters (β₁ = 0.9, β₂ = 0.999, ε = 1e-8, decoupled
/// weight decay). `step` counts completed steps before this one.
pub fn adamw_step(
    params: &mut [f32],
    adam_m: &mut [f32],
    adam_v: &mut [f32],
    grad: &[f32],
    step: f32,
    lr: f64,
    weight_decay: f64,
) {
    let (b1, b2, eps) = (0.9f64, 0.999f64, 1e-8f64);
    let t = step as f64 + 1.0;
    let mc = 1.0 - b1.powf(t);
    let vc = 1.0 - b2.powf(t);
    for i in 0..params.len() {
        let gi = grad[i] as f64;
        let m = b1 * adam_m[i] as f64 + (1.0 - b1) * gi;
        let v = b2 * adam_v[i] as f64 + (1.0 - b2) * gi * gi;
        adam_m[i] = m as f32;
        adam_v[i] = v as f32;
        let update = (m / mc) / ((v / vc).sqrt() + eps) + weight_decay * params[i] as f64;
        params[i] = (params[i] as f64 - lr * update) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::train::HostLm;
    use crate::util::Pcg32;

    fn tiny_shape() -> LmShape {
        let mut lm = Manifest::synthetic(16, 4).lm;
        // Shrink for the finite-difference check.
        lm.vocab = 11;
        lm.seq_len = 6;
        lm.d_model = 8;
        lm.n_layers = 1;
        lm.n_heads = 2;
        lm.d_ff = 12;
        lm.batch = 2;
        lm.param_count = lm.flat_param_count();
        lm
    }

    fn batch(shape: &LmShape, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<i32>) {
        let mut rng = Pcg32::seeded(seed);
        let mut params = vec![0f32; shape.param_count];
        rng.fill_normal_f32(&mut params, 0.05);
        let bl = shape.batch * shape.seq_len;
        let tokens: Vec<i32> = (0..bl).map(|_| rng.below(shape.vocab as u32) as i32).collect();
        let targets: Vec<i32> =
            tokens.iter().map(|&t| (t + 1) % shape.vocab as i32).collect();
        (params, tokens, targets)
    }

    #[test]
    fn loss_matches_host_lm_forward() {
        let shape = tiny_shape();
        let (params, tokens, targets) = batch(&shape, 3);
        let (loss, _) = lm_loss_and_grad(&params, &shape, &tokens, &targets).unwrap();
        let host = HostLm::from_flat(&params, &shape);
        let mut want = 0.0;
        for b in 0..shape.batch {
            want += host.loss(
                &tokens[b * shape.seq_len..(b + 1) * shape.seq_len],
                &targets[b * shape.seq_len..(b + 1) * shape.seq_len],
                &crate::train::AttnMethod::Full,
                1,
            );
        }
        want /= shape.batch as f64;
        // Same math, possibly different summation association than the
        // blocked reference kernel — equal to float-noise tolerance.
        assert!((loss - want).abs() < 1e-6, "grad-path loss {loss} vs forward {want}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let shape = tiny_shape();
        let (params, tokens, targets) = batch(&shape, 4);
        let (_, grad) = lm_loss_and_grad(&params, &shape, &tokens, &targets).unwrap();

        let loss_at = |p: &[f32]| -> f64 {
            let host = HostLm::from_flat(p, &shape);
            let mut total = 0.0;
            for b in 0..shape.batch {
                total += host.loss(
                    &tokens[b * shape.seq_len..(b + 1) * shape.seq_len],
                    &targets[b * shape.seq_len..(b + 1) * shape.seq_len],
                    &crate::train::AttnMethod::Full,
                    1,
                );
            }
            total / shape.batch as f64
        };

        // Probe a deterministic spread of parameters across every group
        // (embeddings, layer weights, final LN, head).
        let mut rng = Pcg32::seeded(9);
        let eps = 1e-3f32;
        let mut checked = 0;
        let mut max_rel: f64 = 0.0;
        for _ in 0..24 {
            let i = rng.range(0, params.len());
            let mut up = params.clone();
            up[i] += eps;
            let mut dn = params.clone();
            dn[i] -= eps;
            let fd = (loss_at(&up) - loss_at(&dn)) / (2.0 * eps as f64);
            let an = grad[i] as f64;
            let denom = fd.abs().max(an.abs());
            if denom < 1e-5 {
                continue; // both ~zero — uninformative
            }
            max_rel = max_rel.max((fd - an).abs() / denom);
            checked += 1;
        }
        assert!(checked >= 10, "too few informative probes ({checked})");
        assert!(max_rel < 5e-2, "finite-diff mismatch: max rel err {max_rel}");
    }

    #[test]
    fn adamw_steps_reduce_loss_on_repeated_batch() {
        let shape = tiny_shape();
        let (mut params, tokens, targets) = batch(&shape, 5);
        let mut m = vec![0f32; params.len()];
        let mut v = vec![0f32; params.len()];
        let (first, _) = lm_loss_and_grad(&params, &shape, &tokens, &targets).unwrap();
        let mut last = first;
        for step in 0..12 {
            let (loss, grad) = lm_loss_and_grad(&params, &shape, &tokens, &targets).unwrap();
            adamw_step(&mut params, &mut m, &mut v, &grad, step as f32, shape.lr, shape.weight_decay);
            last = loss;
        }
        assert!(last < first, "loss did not drop: {first} → {last}");
    }
}
