//! Training drivers: the AOT-backed LM trainer (e2e example + Table 1)
//! and the swappable-attention sentiment classifier (Table 3).

pub mod classifier;
pub mod host_grad;
pub mod host_lm;
pub mod lm;

pub use classifier::{AttnMethod, SentimentClassifier};
pub use host_grad::{adamw_step, lm_loss_and_grad};
pub use host_lm::HostLm;
pub use lm::{generate_greedy, LmTrainer};
