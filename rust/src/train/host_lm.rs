//! Host-side decoder LM forward with *swappable attention*.
//!
//! Parses the flat f32 parameter vector produced by the AOT train step
//! (layout mirrors python/compile/model.py::param_slices) and evaluates
//! the LM loss on the host with any `AttnMethod` — this is how Table 1/2
//! measure the PPL impact of each approximation on one identically
//! trained model, without needing per-method training artifacts.
//!
//! A test asserts the host forward matches the device `lm_eval_loss`
//! artifact to float tolerance under full-rank attention.

use super::classifier::AttnMethod;
use crate::attention::{
    full_attention, lowrank_attention, projection_attention, AttnInputs,
};
use crate::linalg::{matmul, top_k_svd, Mat};
use crate::policy::{nystrom_attention, performer_attention};
use crate::runtime::LmShape;
use crate::spectral::{rank_for_energy, soft_threshold_rank};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Parsed host-side model.
///
/// All evaluation entry points take `&self` (the rank counters are
/// atomics), so one parsed instance can be shared across threads — the
/// host backend caches a parsed model per parameter vector and serves
/// concurrent `lm_logits` calls from it.
pub struct HostLm {
    pub shape: LmShape,
    embed: Mat,  // vocab × d
    pos: Mat,    // L × d
    layers: Vec<LayerParams>,
    lnf_g: Vec<f64>,
    lnf_b: Vec<f64>,
    head: Mat, // d × vocab
    /// Mean selected rank per evaluation (dynamic methods).
    rank_sum: AtomicU64,
    rank_count: AtomicU64,
}

struct LayerParams {
    ln1_g: Vec<f64>,
    ln1_b: Vec<f64>,
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    ln2_g: Vec<f64>,
    ln2_b: Vec<f64>,
    w1: Mat,
    b1: Vec<f64>,
    w2: Mat,
    b2: Vec<f64>,
}

impl HostLm {
    /// Parse the flat parameter vector (AOT layout).
    pub fn from_flat(params: &[f32], shape: &LmShape) -> HostLm {
        assert_eq!(params.len(), shape.param_count, "param vector size");
        let mut off = 0usize;
        let mut take_mat = |rows: usize, cols: usize| -> Mat {
            let n = rows * cols;
            let m = Mat::from_f32(rows, cols, &params[off..off + n]);
            off += n;
            m
        };
        // NOTE: closures capture `off` mutably; order below MUST mirror
        // python/compile/model.py::param_slices.
        let d = shape.d_model;
        let embed = take_mat(shape.vocab, d);
        let pos = take_mat(shape.seq_len, d);
        let mut layers = Vec::with_capacity(shape.n_layers);
        for _ in 0..shape.n_layers {
            let ln1_g = take_mat(1, d).into_vec();
            let ln1_b = take_mat(1, d).into_vec();
            let wq = take_mat(d, d);
            let wk = take_mat(d, d);
            let wv = take_mat(d, d);
            let wo = take_mat(d, d);
            let ln2_g = take_mat(1, d).into_vec();
            let ln2_b = take_mat(1, d).into_vec();
            let w1 = take_mat(d, shape.d_ff);
            let b1 = take_mat(1, shape.d_ff).into_vec();
            let w2 = take_mat(shape.d_ff, d);
            let b2 = take_mat(1, d).into_vec();
            layers.push(LayerParams {
                ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2,
            });
        }
        let lnf_g = take_mat(1, d).into_vec();
        let lnf_b = take_mat(1, d).into_vec();
        let head = take_mat(d, shape.vocab);
        HostLm {
            shape: shape.clone(),
            embed,
            pos,
            layers,
            lnf_g,
            lnf_b,
            head,
            rank_sum: AtomicU64::new(0),
            rank_count: AtomicU64::new(0),
        }
    }

    fn count_rank(&self, r: usize) {
        self.rank_sum.fetch_add(r as u64, Ordering::Relaxed);
        self.rank_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Reset the dynamic-method rank accounting (Table 1/2 reuse one
    /// parsed model across methods).
    pub fn reset_rank_stats(&self) {
        self.rank_sum.store(0, Ordering::Relaxed);
        self.rank_count.store(0, Ordering::Relaxed);
    }

    fn layernorm(x: &Mat, g: &[f64], b: &[f64]) -> Mat {
        let mut out = x.clone();
        for i in 0..x.rows() {
            let row = out.row_mut(i);
            let mu = row.iter().sum::<f64>() / row.len() as f64;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / row.len() as f64;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - mu) * inv * g[j] + b[j];
            }
        }
        out
    }

    fn gelu(x: f64) -> f64 {
        // tanh approximation (matches jax.nn.gelu default).
        0.5 * x * (1.0 + ((2.0 / std::f64::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
    }

    fn head_attention(
        &self,
        inp: &AttnInputs,
        method: &AttnMethod,
        seed: u64,
    ) -> Mat {
        match method {
            AttnMethod::Full => full_attention(inp),
            AttnMethod::FixedRank(r) => {
                self.count_rank(*r);
                lowrank_attention(inp, *r, seed)
            }
            AttnMethod::Performer { n_features } => performer_attention(inp, *n_features, seed),
            AttnMethod::Nystrom { n_landmarks } => nystrom_attention(inp, *n_landmarks, seed),
            AttnMethod::RandomRank { grid, seed: rseed } => {
                // Reserve this draw's index atomically so concurrent
                // callers sharing a cached model never seed identical
                // rank streams; single-threaded the sequence matches the
                // old read-then-increment exactly.
                let count = self.rank_count.fetch_add(1, Ordering::Relaxed);
                let mut rng = crate::util::Pcg32::seeded(rseed.wrapping_add(count ^ seed));
                let r = grid[rng.range(0, grid.len())];
                self.rank_sum.fetch_add(r as u64, Ordering::Relaxed);
                lowrank_attention(inp, r, seed)
            }
            AttnMethod::AdaptiveSvd { threshold, r_max } => {
                let a = crate::attention::attention_matrix(inp);
                let probe = top_k_svd(&a, (*r_max).min(a.rows()), seed);
                let r = rank_for_energy(&probe.s, *threshold).min(*r_max);
                self.count_rank(r);
                crate::attention::lowrank_attention_output(&probe, r, &inp.v)
            }
            AttnMethod::SoftThreshold { tau, r_max } => {
                let a = crate::attention::attention_matrix(inp);
                let probe = top_k_svd(&a, (*r_max).min(a.rows()), seed);
                let r = soft_threshold_rank(&probe.s, *tau).min(*r_max);
                self.count_rank(r);
                crate::attention::lowrank_attention_output(&probe, r, &inp.v)
            }
            AttnMethod::DrRl { grid, actor } => {
                let a = crate::attention::attention_matrix(inp);
                let r_max = *grid.iter().max().unwrap();
                let probe = top_k_svd(&a, r_max.min(a.rows()), seed);
                let conv = crate::rl::ConvFeaturizer::new(0xC0117);
                let w = crate::attention::MhsaWeights {
                    wq: self.layers[0].wq.clone(),
                    wk: self.layers[0].wk.clone(),
                    wv: self.layers[0].wv.clone(),
                    wo: self.layers[0].wo.clone(),
                    n_heads: self.shape.n_heads,
                };
                let state = crate::rl::featurize(
                    &conv,
                    &inp.q,
                    &w,
                    &probe.s,
                    grid[grid.len() / 2],
                    r_max,
                    0,
                    self.shape.n_layers,
                );
                let dist = actor.distribution(&state.features, None);
                let r = grid[dist.argmax()].min(probe.s.len());
                self.count_rank(r);
                crate::attention::lowrank_attention_output(&probe, r, &inp.v)
            }
        }
    }

    /// Forward one sequence (n tokens) → logits (n × vocab).
    pub fn forward(&self, tokens: &[i32], method: &AttnMethod, seed: u64) -> Mat {
        let d = self.shape.d_model;
        let n = tokens.len();
        assert!(n <= self.shape.seq_len);
        let mut x = Mat::zeros(n, d);
        for (i, &t) in tokens.iter().enumerate() {
            let e = self.embed.row(t.clamp(0, self.shape.vocab as i32 - 1) as usize);
            let p = self.pos.row(i);
            for (j, v) in x.row_mut(i).iter_mut().enumerate() {
                *v = e[j] + p[j];
            }
        }
        let hd = d / self.shape.n_heads;
        for (l, lp) in self.layers.iter().enumerate() {
            let h = Self::layernorm(&x, &lp.ln1_g, &lp.ln1_b);
            let q = matmul(&h, &lp.wq);
            let k = matmul(&h, &lp.wk);
            let v = matmul(&h, &lp.wv);
            let mut outs = Vec::with_capacity(self.shape.n_heads);
            for head in 0..self.shape.n_heads {
                let sl = |m: &Mat| -> Mat {
                    let mut out = Mat::zeros(n, hd);
                    for i in 0..n {
                        out.row_mut(i).copy_from_slice(&m.row(i)[head * hd..(head + 1) * hd]);
                    }
                    out
                };
                let inp = AttnInputs { q: sl(&q), k: sl(&k), v: sl(&v), causal: true };
                let head_seed = seed ^ ((l as u64) << 8 | head as u64);
                outs.push(self.head_attention(&inp, method, head_seed));
            }
            let mut cat = outs[0].clone();
            for o in &outs[1..] {
                cat = cat.hcat(o);
            }
            let attn = matmul(&cat, &lp.wo);
            x.add_inplace(&attn);
            let h2 = Self::layernorm(&x, &lp.ln2_g, &lp.ln2_b);
            let mut ff = matmul(&h2, &lp.w1);
            for i in 0..n {
                for (j, fv) in ff.row_mut(i).iter_mut().enumerate() {
                    *fv = Self::gelu(*fv + lp.b1[j]);
                }
            }
            let mut ff2 = matmul(&ff, &lp.w2);
            for i in 0..n {
                for (j, fv) in ff2.row_mut(i).iter_mut().enumerate() {
                    *fv += lp.b2[j];
                }
            }
            x.add_inplace(&ff2);
        }
        let x = Self::layernorm(&x, &self.lnf_g, &self.lnf_b);
        matmul(&x, &self.head)
    }

    /// Mean next-token cross-entropy over one (tokens, targets) sequence.
    pub fn loss(&self, tokens: &[i32], targets: &[i32], method: &AttnMethod, seed: u64) -> f64 {
        let logits = self.forward(tokens, method, seed);
        let mut total = 0.0;
        for i in 0..tokens.len() {
            let row = logits.row(i);
            let max = row.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let lse = max + row.iter().map(|v| (v - max).exp()).sum::<f64>().ln();
            total += lse - row[targets[i].clamp(0, self.shape.vocab as i32 - 1) as usize];
        }
        total / tokens.len() as f64
    }

    /// PPL over a batch of (tokens, targets) pairs flattened row-major.
    pub fn eval_ppl(
        &self,
        tokens: &[i32],
        targets: &[i32],
        batch: usize,
        seq_len: usize,
        method: &AttnMethod,
        seed: u64,
    ) -> f64 {
        let mut total = 0.0;
        for b in 0..batch {
            let t = &tokens[b * seq_len..(b + 1) * seq_len];
            let g = &targets[b * seq_len..(b + 1) * seq_len];
            total += self.loss(t, g, method, seed.wrapping_add(b as u64));
        }
        (total / batch as f64).exp()
    }

    pub fn mean_rank(&self) -> f64 {
        let count = self.rank_count.load(Ordering::Relaxed);
        if count == 0 {
            0.0
        } else {
            self.rank_sum.load(Ordering::Relaxed) as f64 / count as f64
        }
    }
}

/// Projection baseline weights per layer (Linformer-style, Table 1's
/// "Fixed Low-Rank [9]" when used as architecture substitute).
pub fn projection_matrices(n: usize, r: usize, n_layers: usize, seed: u64) -> BTreeMap<usize, Mat> {
    let mut rng = crate::util::Pcg32::seeded(seed);
    (0..n_layers)
        .map(|l| (l, Mat::randn(r, n, (1.0 / n as f64).sqrt(), &mut rng)))
        .collect()
}

const _: () = {
    // keep the import used even when the projection path is disabled
    let _ = projection_attention as fn(&AttnInputs, &Mat) -> Mat;
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactRegistry, Manifest};
    use crate::util::Pcg32;

    #[test]
    fn host_forward_matches_device_eval_loss() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let lm = reg.manifest.lm.clone();
        let mut rng = Pcg32::seeded(3);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let tokens: Vec<i32> =
            (0..lm.batch * lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let device_loss = reg.lm_eval_loss(&params, &tokens, &targets).unwrap();

        let host = HostLm::from_flat(&params, &lm);
        let mut host_loss = 0.0;
        for b in 0..lm.batch {
            host_loss += host.loss(
                &tokens[b * lm.seq_len..(b + 1) * lm.seq_len],
                &targets[b * lm.seq_len..(b + 1) * lm.seq_len],
                &AttnMethod::Full,
                1,
            );
        }
        host_loss /= lm.batch as f64;
        let rel = (host_loss - device_loss).abs() / device_loss;
        assert!(rel < 2e-3, "host {host_loss} vs device {device_loss} (rel {rel})");
    }

    #[test]
    fn lowrank_eval_close_to_full_at_high_rank() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let lm = reg.manifest.lm.clone();
        let mut rng = Pcg32::seeded(5);
        let mut params = vec![0f32; lm.param_count];
        rng.fill_normal_f32(&mut params, 0.02);
        let tokens: Vec<i32> =
            (0..lm.seq_len).map(|_| rng.below(lm.vocab as u32) as i32).collect();
        let targets: Vec<i32> = tokens.iter().map(|&t| (t + 1) % lm.vocab as i32).collect();
        let host = HostLm::from_flat(&params, &lm);
        let full = host.loss(&tokens, &targets, &AttnMethod::Full, 1);
        let hi = host.loss(&tokens, &targets, &AttnMethod::FixedRank(96), 1);
        let lo = host.loss(&tokens, &targets, &AttnMethod::FixedRank(4), 1);
        assert!((hi - full).abs() < (lo - full).abs() + 1e-9,
            "high-rank should approximate better: full {full}, r96 {hi}, r4 {lo}");
    }
}
