//! Downstream sentiment classifier (Table 3 harness).
//!
//! Architecture: frozen byte embedding → one frozen MHSA layer whose
//! *attention mechanism* is swappable (full / DR-RL dynamic rank /
//! fixed rank / adaptive-SVD / Performer / Nyströmformer) → mean pool →
//! trainable MLP head. Freezing everything upstream of the head makes
//! the comparison exactly about how much task-relevant signal each
//! attention approximation preserves — the mechanism the paper's Table 3
//! measures — while keeping training fast and identical across methods.

use crate::attention::{
    full_attention, lowrank_attention, project_heads, AttnInputs, MhsaWeights,
};
use crate::data::{sentiment::word_vocab, SentimentExample};
use crate::linalg::{top_k_svd, Mat};
use crate::nn::{Act, AdamW, Categorical, Mlp};
use crate::policy::{nystrom_attention, performer_attention};
use crate::rl::{featurize, ConvFeaturizer};
use crate::spectral::{rank_for_energy, soft_threshold_rank};
use crate::util::Pcg32;

/// Attention mechanism under test.
#[derive(Clone)]
pub enum AttnMethod {
    Full,
    /// DR-RL with a trained actor (greedy) choosing from the rank grid.
    DrRl { grid: Vec<usize>, actor: std::sync::Arc<crate::rl::ActorCritic> },
    FixedRank(usize),
    AdaptiveSvd { threshold: f64, r_max: usize },
    /// Soft-thresholding rule (SoftLMs, arXiv:2411.10543): rank = #{σ_i :
    /// σ_i − τ·σ_0 > 0} over the probe spectrum.
    SoftThreshold { tau: f64, r_max: usize },
    Performer { n_features: usize },
    Nystrom { n_landmarks: usize },
    /// Uniform-random rank from the grid (Table 1 control).
    RandomRank { grid: Vec<usize>, seed: u64 },
}

impl AttnMethod {
    pub fn name(&self) -> &'static str {
        match self {
            AttnMethod::Full => "full-rank",
            AttnMethod::DrRl { .. } => "dr-rl",
            AttnMethod::FixedRank(_) => "fixed-rank",
            AttnMethod::AdaptiveSvd { .. } => "adaptive-svd",
            AttnMethod::SoftThreshold { .. } => "soft-threshold",
            AttnMethod::Performer { .. } => "performer",
            AttnMethod::Nystrom { .. } => "nystromformer",
            AttnMethod::RandomRank { .. } => "random-rank",
        }
    }
}

/// Frozen encoder + trainable head.
pub struct SentimentClassifier {
    pub d_model: usize,
    embed: Mat, // vocab × d_model, frozen
    attn: MhsaWeights,
    conv: ConvFeaturizer,
    pub method: AttnMethod,
    pub head: Mlp,
    pub opt: AdamW,
    seed: u64,
    /// Mean rank chosen by dynamic methods (FLOPs reporting).
    pub rank_sum: u64,
    pub rank_count: u64,
}

impl SentimentClassifier {
    pub fn new(d_model: usize, n_heads: usize, method: AttnMethod, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let vocab = word_vocab().len();
        let embed = Mat::randn(vocab, d_model, 0.5, &mut rng);
        let attn = MhsaWeights::init(d_model, n_heads, &mut rng);
        let mut head_rng = Pcg32::seeded(seed ^ 0x4EAD);
        // Head sees [mean-pool ⊕ max-pool] features.
        let head = Mlp::new(&[2 * d_model, 32, 2], Act::Tanh, &mut head_rng);
        let n_params = head.n_params();
        SentimentClassifier {
            d_model,
            embed,
            attn,
            conv: ConvFeaturizer::new(seed ^ 0xC0117),
            method,
            head,
            opt: AdamW::new(n_params, 3e-3),
            seed,
            rank_sum: 0,
            rank_count: 0,
        }
    }

    fn embed_tokens(&self, tokens: &[i32]) -> Mat {
        let vmax = self.embed.rows() as i32 - 1;
        let mut x = Mat::zeros(tokens.len(), self.d_model);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(t.clamp(0, vmax) as usize));
        }
        x
    }

    /// Frozen encoder: word tokens → pooled feature vector
    /// ([mean ⊕ max] over the sequence).
    pub fn features(&mut self, tokens: &[i32]) -> Vec<f64> {
        let x = self.embed_tokens(tokens);
        let heads = project_heads(&x, &self.attn, false);
        let outs: Vec<Mat> = heads
            .iter()
            .enumerate()
            .map(|(h, inp)| self.head_attention(inp, h))
            .collect();
        // Residual connection (as in a real transformer block): keeps the
        // raw lexical signal visible to the pooled features while the
        // attention term contributes the contextual (e.g. negation) part.
        let mut merged = crate::attention::merge_heads(&outs, &self.attn);
        merged.add_inplace(&x);
        let n = merged.rows() as f64;
        let mut f = Vec::with_capacity(2 * self.d_model);
        for j in 0..self.d_model {
            f.push((0..merged.rows()).map(|i| merged[(i, j)]).sum::<f64>() / n);
        }
        for j in 0..self.d_model {
            f.push((0..merged.rows()).map(|i| merged[(i, j)]).fold(f64::NEG_INFINITY, f64::max));
        }
        f
    }

    fn head_attention(&mut self, inp: &AttnInputs, h: usize) -> Mat {
        let seed = self.seed.wrapping_add(h as u64);
        match &self.method {
            AttnMethod::Full => full_attention(inp),
            AttnMethod::FixedRank(r) => lowrank_attention(inp, *r, seed),
            AttnMethod::Performer { n_features } => {
                performer_attention(inp, *n_features, seed)
            }
            AttnMethod::Nystrom { n_landmarks } => nystrom_attention(inp, *n_landmarks, seed),
            AttnMethod::RandomRank { grid, seed: rseed } => {
                let mut rng = Pcg32::seeded(rseed.wrapping_add(self.rank_count));
                let r = grid[rng.range(0, grid.len())];
                self.rank_sum += r as u64;
                self.rank_count += 1;
                lowrank_attention(inp, r, seed)
            }
            AttnMethod::AdaptiveSvd { threshold, r_max } => {
                let a = crate::attention::attention_matrix(inp);
                let probe = top_k_svd(&a, (*r_max).min(a.rows()), seed);
                let r = rank_for_energy(&probe.s, *threshold).min(*r_max);
                self.rank_sum += r as u64;
                self.rank_count += 1;
                crate::attention::lowrank_attention_output(&probe, r, &inp.v)
            }
            AttnMethod::SoftThreshold { tau, r_max } => {
                let a = crate::attention::attention_matrix(inp);
                let probe = top_k_svd(&a, (*r_max).min(a.rows()), seed);
                let r = soft_threshold_rank(&probe.s, *tau).min(*r_max);
                self.rank_sum += r as u64;
                self.rank_count += 1;
                crate::attention::lowrank_attention_output(&probe, r, &inp.v)
            }
            AttnMethod::DrRl { grid, actor } => {
                let a = crate::attention::attention_matrix(inp);
                let r_max = *grid.iter().max().unwrap();
                let probe = top_k_svd(&a, r_max.min(a.rows()), seed);
                let prev = grid[grid.len() / 2];
                let state = featurize(
                    &self.conv,
                    &inp.q,
                    &self.attn,
                    &probe.s,
                    prev,
                    r_max,
                    h,
                    self.attn.n_heads,
                );
                let dist = actor.distribution(&state.features, None);
                let r = grid[dist.argmax()].min(probe.s.len());
                self.rank_sum += r as u64;
                self.rank_count += 1;
                crate::attention::lowrank_attention_output(&probe, r, &inp.v)
            }
        }
    }

    /// Train the head on examples (frozen features cached by the caller
    /// if reuse is wanted). Returns last-epoch accuracy.
    pub fn train_head(&mut self, data: &[SentimentExample], epochs: usize) -> f64 {
        // Pre-compute features once — the encoder is frozen.
        let feats: Vec<Vec<f64>> = data.iter().map(|e| self.features(&e.word_tokens)).collect();
        let labels: Vec<usize> = data.iter().map(|e| e.label).collect();
        let mut rng = Pcg32::seeded(self.seed ^ 0x7121);
        let mut order: Vec<usize> = (0..data.len()).collect();
        let mut acc = 0.0;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut correct = 0usize;
            for chunk in order.chunks(32) {
                let mut batch = Mat::zeros(chunk.len(), 2 * self.d_model);
                for (bi, &i) in chunk.iter().enumerate() {
                    batch.row_mut(bi).copy_from_slice(&feats[i]);
                }
                let logits = self.head.forward(&batch);
                let mut dl = Mat::zeros(chunk.len(), 2);
                for (bi, &i) in chunk.iter().enumerate() {
                    let dist = Categorical::from_logits(logits.row(bi), None);
                    if dist.argmax() == labels[i] {
                        correct += 1;
                    }
                    let g = dist.grad_nll_wrt_logits(labels[i]);
                    for (j, gv) in g.iter().enumerate() {
                        dl[(bi, j)] = gv / chunk.len() as f64;
                    }
                }
                self.head.zero_grad();
                self.head.backward(&dl);
                self.opt.step(&mut self.head);
            }
            acc = correct as f64 / data.len() as f64;
        }
        acc
    }

    /// Accuracy on held-out examples.
    pub fn evaluate(&mut self, data: &[SentimentExample]) -> f64 {
        let mut correct = 0usize;
        for e in data {
            let f = self.features(&e.word_tokens);
            let x = Mat::from_vec(1, 2 * self.d_model, f);
            let logits = self.head.forward_inference(&x);
            let pred = Categorical::from_logits(logits.row(0), None).argmax();
            if pred == e.label {
                correct += 1;
            }
        }
        correct as f64 / data.len() as f64
    }

    pub fn mean_rank(&self) -> f64 {
        if self.rank_count == 0 {
            0.0
        } else {
            self.rank_sum as f64 / self.rank_count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_dataset, split};

    fn run_method(method: AttnMethod, n: usize) -> f64 {
        let data = generate_dataset(n, 48, 11);
        let (train, test) = split(data, 0.8);
        let mut clf = SentimentClassifier::new(32, 2, method, 5);
        clf.train_head(&train, 100);
        clf.evaluate(&test)
    }

    #[test]
    fn full_attention_learns_task() {
        let acc = run_method(AttnMethod::Full, 160);
        assert!(acc > 0.75, "full-rank acc {acc}");
    }

    #[test]
    fn tiny_fixed_rank_degrades() {
        let full = run_method(AttnMethod::Full, 160);
        let starved = run_method(AttnMethod::FixedRank(1), 160);
        assert!(
            starved <= full + 0.05,
            "rank-1 {starved} should not beat full {full}"
        );
    }

    #[test]
    fn adaptive_svd_tracks_mean_rank() {
        let data = generate_dataset(20, 48, 12);
        let mut clf = SentimentClassifier::new(32, 2,
            AttnMethod::AdaptiveSvd { threshold: 0.9, r_max: 8 }, 6);
        for e in &data {
            clf.features(&e.word_tokens);
        }
        assert!(clf.rank_count > 0);
        let mr = clf.mean_rank();
        assert!((1.0..=8.0).contains(&mr), "mean rank {mr}");
    }

    #[test]
    fn soft_threshold_tracks_mean_rank() {
        let data = generate_dataset(20, 48, 12);
        let mut clf = SentimentClassifier::new(32, 2,
            AttnMethod::SoftThreshold { tau: 0.3, r_max: 8 }, 6);
        for e in &data {
            clf.features(&e.word_tokens);
        }
        assert!(clf.rank_count > 0);
        let mr = clf.mean_rank();
        assert!((1.0..=8.0).contains(&mr), "mean rank {mr}");
    }

    #[test]
    fn method_names() {
        assert_eq!(AttnMethod::Full.name(), "full-rank");
        assert_eq!(AttnMethod::Performer { n_features: 8 }.name(), "performer");
        assert_eq!(
            AttnMethod::SoftThreshold { tau: 0.3, r_max: 8 }.name(),
            "soft-threshold"
        );
    }
}
