//! LM training / evaluation drivers over the typed backend's
//! `lm_train_step` op (the AOT artifact under PJRT, the hand-written
//! backward + fused AdamW on the host — see [`super::host_grad`]).
//!
//! The Rust side owns all state (params + Adam moments as flat f32
//! vectors) and drives the backend step by step — Python never runs,
//! and no artifacts are required. PPL = exp(mean CE loss over
//! validation batches).

use crate::data::Corpus;
use crate::runtime::ArtifactRegistry;
use crate::util::{Pcg32, Stopwatch};
use anyhow::Result;

/// Training state + curves.
pub struct LmTrainer<'r> {
    pub reg: &'r ArtifactRegistry,
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub step: u64,
    /// (step, loss) curve — Fig 2 left panel.
    pub curve: Vec<(u64, f64)>,
    rng: Pcg32,
}

impl<'r> LmTrainer<'r> {
    /// Fresh GPT-style init (σ=0.02), matching python model.init_params.
    pub fn new(reg: &'r ArtifactRegistry, seed: u64) -> Self {
        let p = reg.manifest.lm.param_count;
        let mut rng = Pcg32::seeded(seed);
        let mut params = vec![0f32; p];
        rng.fill_normal_f32(&mut params, 0.02);
        LmTrainer {
            reg,
            params,
            adam_m: vec![0.0; p],
            adam_v: vec![0.0; p],
            step: 0,
            curve: Vec::new(),
            rng: Pcg32::seeded(seed ^ 0x7A41),
        }
    }

    /// Train for `steps` on the corpus; returns wall seconds.
    pub fn train(&mut self, corpus: &Corpus, steps: usize, log_every: usize) -> Result<f64> {
        let lm = self.reg.manifest.lm.clone();
        let sw = Stopwatch::start();
        for _ in 0..steps {
            let (tokens, targets) =
                corpus.sample_batch(true, lm.batch, lm.seq_len, &mut self.rng);
            let loss = self.reg.lm_train_step(
                &mut self.params,
                &mut self.adam_m,
                &mut self.adam_v,
                self.step as f32,
                &tokens,
                &targets,
            )?;
            self.step += 1;
            if log_every > 0 && (self.step as usize) % log_every == 0 {
                crate::log_info!(
                    "[{}] step {:5} loss {:.4}",
                    corpus.profile.name(),
                    self.step,
                    loss
                );
            }
            self.curve.push((self.step, loss));
        }
        Ok(sw.elapsed().as_secs_f64())
    }

    /// Validation perplexity over `n_batches`.
    pub fn eval_ppl(&mut self, corpus: &Corpus, n_batches: usize) -> Result<f64> {
        let lm = self.reg.manifest.lm.clone();
        let mut total = 0.0;
        for _ in 0..n_batches {
            let (tokens, targets) =
                corpus.sample_batch(false, lm.batch, lm.seq_len, &mut self.rng);
            total += self.reg.lm_eval_loss(&self.params, &tokens, &targets)?;
        }
        Ok((total / n_batches as f64).exp())
    }

    /// Final (most recent) training loss.
    pub fn last_loss(&self) -> f64 {
        self.curve.last().map(|&(_, l)| l).unwrap_or(f64::NAN)
    }
}

/// Greedy generation through the logits artifact (serving demo): append
/// argmax token repeatedly. The artifact has fixed (B, L) shape, so the
/// prompt occupies a suffix window.
pub fn generate_greedy(
    reg: &ArtifactRegistry,
    params: &[f32],
    prompt: &[i32],
    n_new: usize,
) -> Result<Vec<i32>> {
    let lm = &reg.manifest.lm;
    let mut ctx: Vec<i32> = prompt.to_vec();
    for _ in 0..n_new {
        // Build a full (B, L) batch: row 0 = right-aligned context.
        let mut tokens = vec![b' ' as i32; lm.batch * lm.seq_len];
        let take = ctx.len().min(lm.seq_len);
        let dst0 = lm.seq_len - take;
        tokens[dst0..lm.seq_len].copy_from_slice(&ctx[ctx.len() - take..]);
        let logits = reg.lm_logits(params, &tokens)?;
        // Last position of row 0.
        let off = (lm.seq_len - 1) * lm.vocab;
        let row = &logits[off..off + lm.vocab];
        let next = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        ctx.push(next);
    }
    Ok(ctx[prompt.len()..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusProfile;
    use crate::runtime::Manifest;

    #[test]
    fn short_training_reduces_loss_and_ppl_finite() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let corpus = Corpus::build(CorpusProfile::Ptb, 100_000, 1);
        let mut tr = LmTrainer::new(&reg, 42);
        tr.train(&corpus, 12, 0).unwrap();
        let first = tr.curve[0].1;
        let last = tr.last_loss();
        assert!(last < first, "loss {first} → {last}");
        let ppl = tr.eval_ppl(&corpus, 2).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
    }

    #[test]
    fn generation_produces_tokens() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = ArtifactRegistry::open(&dir).unwrap();
        let tr = LmTrainer::new(&reg, 7);
        let prompt: Vec<i32> = "the ".bytes().map(|b| b as i32).collect();
        let out = generate_greedy(&reg, &tr.params, &prompt, 4).unwrap();
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|&t| (0..256).contains(&t)));
    }
}
