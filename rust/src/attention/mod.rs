//! Attention substrate: reference full-rank MHSA (Eq. 1), truncated-SVD
//! low-rank attention in factor form, the masked-rank formulation used by
//! the AOT Pallas kernel, and Linformer-style projection baselines.

pub mod full;
pub mod lowrank;
pub mod mhsa;
pub mod softmax;

pub use full::{apply_attention, attention_matrix, attention_scores, full_attention, AttnInputs};
pub use lowrank::{
    lowrank_attention, lowrank_attention_matrix, lowrank_attention_output,
    masked_rank_attention, projection_attention,
};
pub use mhsa::{merge_heads, mhsa_full, mhsa_lowrank, project_heads, MhsaWeights};
pub use softmax::{causal_mask_inplace, softmax_rows, softmax_rows_inplace};
