//! Low-rank attention approximations.
//!
//! Two families, mirroring the paper and the L1 Pallas kernel:
//!
//! 1. **Score-factor attention** (the DR-RL path): truncated SVD of the
//!    post-softmax attention matrix A ≈ U_r Σ_r V_rᵀ, applied to V in
//!    factor form — `O(n·r·(n+d))` instead of `O(n²d)` once factors are
//!    known, with factors maintained incrementally across rank changes.
//! 2. **Projection attention** (Linformer-style fixed-rank baseline):
//!    K, V projected to r rows before the softmax.

use super::full::{attention_matrix, AttnInputs};
use crate::linalg::{matmul, matmul_at, matmul_bt, top_k_svd, Mat, Svd};

/// Rank-r approximation of the attention matrix via truncated SVD.
pub fn lowrank_attention_matrix(inp: &AttnInputs, r: usize, seed: u64) -> Mat {
    let a = attention_matrix(inp);
    let d = top_k_svd(&a, r, seed);
    d.reconstruct(r)
}

/// Y_r = A_r · V computed in factor form: U_r · (Σ_r V_rᵀ · V).
/// Never materializes the n×n matrix — this is the shape the Pallas
/// kernel executes on the accelerator.
pub fn lowrank_attention_output(svd: &Svd, r: usize, v: &Mat) -> Mat {
    let r = r.min(svd.s.len());
    // W = V_rᵀ · V : r×d  (V_r is n×r).
    let vr = svd.v.take_cols(r);
    let mut w = matmul_at(&vr, v);
    // Scale rows of W by σ.
    for i in 0..r {
        let si = svd.s[i];
        for x in w.row_mut(i).iter_mut() {
            *x *= si;
        }
    }
    matmul(&svd.u.take_cols(r), &w)
}

/// End-to-end low-rank attention: decompose scores at rank r, apply to V.
pub fn lowrank_attention(inp: &AttnInputs, r: usize, seed: u64) -> Mat {
    let a = attention_matrix(inp);
    let d = top_k_svd(&a, r, seed);
    lowrank_attention_output(&d, r, &inp.v)
}

/// Masked-rank attention: the static-shape formulation the AOT Pallas
/// kernel uses. Factors are computed at `r_max` but columns ≥ `r_eff`
/// are zeroed by the mask, so one compiled executable serves every rank.
pub fn masked_rank_attention(inp: &AttnInputs, r_max: usize, r_eff: usize, seed: u64) -> Mat {
    let a = attention_matrix(inp);
    let d = top_k_svd(&a, r_max, seed);
    let mut masked = Svd { u: d.u.clone(), s: d.s.clone(), v: d.v.clone() };
    for i in r_eff.min(masked.s.len())..masked.s.len() {
        masked.s[i] = 0.0;
    }
    lowrank_attention_output(&masked, r_max, &inp.v)
}

/// Linformer-style projection attention baseline: K, V are projected from
/// n rows to r rows with a fixed random matrix E (shared per layer).
pub fn projection_attention(inp: &AttnInputs, e: &Mat) -> Mat {
    // e: r×n projection. K' = E·K (r×d), V' = E·V (r×d).
    let kp = matmul(e, &inp.k);
    let vp = matmul(e, &inp.v);
    let d = inp.head_dim() as f64;
    let mut scores = matmul_bt(&inp.q, &kp); // n×r
    scores.scale_inplace(1.0 / d.sqrt());
    super::softmax::softmax_rows_inplace(&mut scores);
    matmul(&scores, &vp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::full_attention;
    use crate::util::Pcg32;

    fn inputs(n: usize, d: usize, seed: u64) -> AttnInputs {
        let mut rng = Pcg32::seeded(seed);
        AttnInputs {
            q: Mat::randn(n, d, 1.0, &mut rng),
            k: Mat::randn(n, d, 1.0, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal: false,
        }
    }

    #[test]
    fn factor_form_matches_materialized() {
        let inp = inputs(20, 8, 1);
        let a = attention_matrix(&inp);
        let d = top_k_svd(&a, 6, 7);
        let y_factor = lowrank_attention_output(&d, 6, &inp.v);
        let y_mat = matmul(&d.reconstruct(6), &inp.v);
        assert!(y_factor.allclose(&y_mat, 1e-8));
    }

    #[test]
    fn error_decreases_with_rank() {
        let inp = inputs(24, 8, 2);
        let y_full = full_attention(&inp);
        let mut last = f64::INFINITY;
        for r in [2, 6, 12, 24] {
            let y = lowrank_attention(&inp, r, 3);
            let err = (&y_full - &y).fro_norm();
            assert!(err <= last + 1e-6, "rank {r}: err {err} > prev {last}");
            last = err;
        }
    }

    #[test]
    fn full_rank_recovers_exact() {
        let inp = inputs(12, 6, 3);
        let y_full = full_attention(&inp);
        let y = lowrank_attention(&inp, 12, 4);
        assert!(y_full.allclose(&y, 1e-6));
    }

    #[test]
    fn masked_rank_equals_truncation() {
        let inp = inputs(16, 8, 4);
        let y_masked = masked_rank_attention(&inp, 12, 5, 9);
        // Masking at r_eff inside an r_max decomposition = truncating the
        // same decomposition at r_eff.
        let a = attention_matrix(&inp);
        let d = top_k_svd(&a, 12, 9);
        let y_trunc = lowrank_attention_output(&d, 5, &inp.v);
        assert!(y_masked.allclose(&y_trunc, 1e-8));
    }

    #[test]
    fn projection_attention_shapes_and_rows() {
        let inp = inputs(20, 8, 5);
        let mut rng = Pcg32::seeded(6);
        let e = Mat::randn(4, 20, (1.0 / 20.0f64).sqrt(), &mut rng);
        let y = projection_attention(&inp, &e);
        assert_eq!(y.shape(), (20, 8));
        assert!(y.data().iter().all(|x| x.is_finite()));
    }
}
