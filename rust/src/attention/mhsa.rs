//! Multi-head self-attention plumbing: per-head projection, head split /
//! merge, output projection, and per-head rank application — the Rust
//! mirror of the L2 JAX model's attention block (used by the oracle, the
//! reward computation and the CPU fallback path).

use super::full::{full_attention, AttnInputs};
use super::lowrank::lowrank_attention;
use crate::linalg::{matmul, Mat};
use crate::util::Pcg32;

/// Weights for one MHSA layer.
#[derive(Debug, Clone)]
pub struct MhsaWeights {
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub n_heads: usize,
}

impl MhsaWeights {
    /// Xavier-ish random init.
    pub fn init(d_model: usize, n_heads: usize, rng: &mut Pcg32) -> Self {
        assert_eq!(d_model % n_heads, 0, "d_model must divide n_heads");
        let std = (2.0 / (d_model + d_model) as f64).sqrt();
        MhsaWeights {
            wq: Mat::randn(d_model, d_model, std, rng),
            wk: Mat::randn(d_model, d_model, std, rng),
            wv: Mat::randn(d_model, d_model, std, rng),
            wo: Mat::randn(d_model, d_model, std, rng),
            n_heads,
        }
    }

    pub fn d_model(&self) -> usize {
        self.wq.rows()
    }

    pub fn head_dim(&self) -> usize {
        self.d_model() / self.n_heads
    }

    /// Summary statistics of the projection weights — part of the RL state
    /// vector w_t (paper Eq. 6): mean, variance, spectral norm per matrix.
    pub fn stats(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(9);
        for w in [&self.wq, &self.wk, &self.wv] {
            out.push(w.mean());
            out.push(w.variance());
            out.push(crate::linalg::spectral_norm_fast(w, 0x57a75));
        }
        out
    }
}

/// Project an input sequence (n×d_model) into per-head Q/K/V inputs.
pub fn project_heads(x: &Mat, w: &MhsaWeights, causal: bool) -> Vec<AttnInputs> {
    let q = matmul(x, &w.wq);
    let k = matmul(x, &w.wk);
    let v = matmul(x, &w.wv);
    let hd = w.head_dim();
    (0..w.n_heads)
        .map(|h| AttnInputs {
            q: slice_cols(&q, h * hd, (h + 1) * hd),
            k: slice_cols(&k, h * hd, (h + 1) * hd),
            v: slice_cols(&v, h * hd, (h + 1) * hd),
            causal,
        })
        .collect()
}

fn slice_cols(m: &Mat, c0: usize, c1: usize) -> Mat {
    let mut out = Mat::zeros(m.rows(), c1 - c0);
    for i in 0..m.rows() {
        out.row_mut(i).copy_from_slice(&m.row(i)[c0..c1]);
    }
    out
}

/// Merge per-head outputs (each n×head_dim) back to n×d_model and apply
/// the output projection.
pub fn merge_heads(outputs: &[Mat], w: &MhsaWeights) -> Mat {
    let mut cat = outputs[0].clone();
    for o in &outputs[1..] {
        cat = cat.hcat(o);
    }
    matmul(&cat, &w.wo)
}

/// Full-rank MHSA forward for a whole layer.
pub fn mhsa_full(x: &Mat, w: &MhsaWeights, causal: bool) -> Mat {
    let heads = project_heads(x, w, causal);
    let outs: Vec<Mat> = heads.iter().map(full_attention).collect();
    merge_heads(&outs, w)
}

/// MHSA with a per-head rank assignment (the DR-RL forward).
pub fn mhsa_lowrank(x: &Mat, w: &MhsaWeights, ranks: &[usize], causal: bool, seed: u64) -> Mat {
    assert_eq!(ranks.len(), w.n_heads, "one rank per head");
    let heads = project_heads(x, w, causal);
    let outs: Vec<Mat> = heads
        .iter()
        .zip(ranks.iter())
        .enumerate()
        .map(|(h, (inp, &r))| {
            if r >= inp.seq_len() {
                full_attention(inp)
            } else {
                lowrank_attention(inp, r, seed.wrapping_add(h as u64))
            }
        })
        .collect();
    merge_heads(&outs, w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_split_covers_d_model() {
        let mut rng = Pcg32::seeded(1);
        let w = MhsaWeights::init(32, 4, &mut rng);
        let x = Mat::randn(10, 32, 1.0, &mut rng);
        let heads = project_heads(&x, &w, false);
        assert_eq!(heads.len(), 4);
        for h in &heads {
            assert_eq!(h.q.shape(), (10, 8));
        }
    }

    #[test]
    fn full_forward_shape() {
        let mut rng = Pcg32::seeded(2);
        let w = MhsaWeights::init(16, 2, &mut rng);
        let x = Mat::randn(8, 16, 1.0, &mut rng);
        let y = mhsa_full(&x, &w, true);
        assert_eq!(y.shape(), (8, 16));
        assert!(y.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn max_rank_lowrank_matches_full() {
        let mut rng = Pcg32::seeded(3);
        let w = MhsaWeights::init(16, 2, &mut rng);
        let x = Mat::randn(8, 16, 1.0, &mut rng);
        let full = mhsa_full(&x, &w, false);
        let lr = mhsa_lowrank(&x, &w, &[8, 8], false, 9);
        assert!(full.allclose(&lr, 1e-6), "diff {}", full.max_abs_diff(&lr));
    }

    #[test]
    fn lowrank_error_shrinks_with_rank() {
        let mut rng = Pcg32::seeded(4);
        let w = MhsaWeights::init(16, 2, &mut rng);
        let x = Mat::randn(24, 16, 1.0, &mut rng);
        let full = mhsa_full(&x, &w, false);
        let e2 = (&full - &mhsa_lowrank(&x, &w, &[2, 2], false, 5)).fro_norm();
        let e12 = (&full - &mhsa_lowrank(&x, &w, &[12, 12], false, 5)).fro_norm();
        assert!(e12 < e2, "rank 12 err {e12} !< rank 2 err {e2}");
    }

    #[test]
    fn weight_stats_vector_layout() {
        let mut rng = Pcg32::seeded(5);
        let w = MhsaWeights::init(16, 4, &mut rng);
        let s = w.stats();
        assert_eq!(s.len(), 9);
        // Variances and spectral norms positive.
        assert!(s[1] > 0.0 && s[2] > 0.0);
    }

    #[test]
    #[should_panic]
    fn rank_count_mismatch_panics() {
        let mut rng = Pcg32::seeded(6);
        let w = MhsaWeights::init(16, 4, &mut rng);
        let x = Mat::randn(8, 16, 1.0, &mut rng);
        let _ = mhsa_lowrank(&x, &w, &[4, 4], false, 0);
    }
}
