//! Numerically stable softmax and causal masking helpers shared by the
//! full and low-rank attention paths.

use crate::linalg::Mat;

/// Row-wise stable softmax, in place.
pub fn softmax_rows_inplace(m: &mut Mat) {
    let cols = m.cols();
    for i in 0..m.rows() {
        let row = m.row_mut(i);
        let max = row.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        if !max.is_finite() {
            // Entire row masked: uniform over nothing → zeros.
            for v in row.iter_mut() {
                *v = 0.0;
            }
            continue;
        }
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        if sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        let _ = cols;
    }
}

/// Row-wise stable softmax (copying).
pub fn softmax_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    softmax_rows_inplace(&mut out);
    out
}

/// Apply a causal mask: positions j > i get -inf before softmax.
pub fn causal_mask_inplace(scores: &mut Mat) {
    let n = scores.rows();
    assert_eq!(n, scores.cols(), "causal mask expects square scores");
    for i in 0..n {
        let row = scores.row_mut(i);
        for v in row.iter_mut().skip(i + 1) {
            *v = f64::NEG_INFINITY;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_one() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let s = softmax_rows(&m);
        for i in 0..2 {
            let sum: f64 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn stable_for_large_logits() {
        let m = Mat::from_vec(1, 3, vec![1000.0, 1001.0, 999.0]);
        let s = softmax_rows(&m);
        assert!(s.row(0).iter().all(|v| v.is_finite()));
        let sum: f64 = s.row(0).iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn causal_structure() {
        let mut m = Mat::filled(4, 4, 1.0);
        causal_mask_inplace(&mut m);
        let s = softmax_rows(&m);
        // Upper triangle zero, rows sum to 1.
        for i in 0..4 {
            for j in 0..4 {
                if j > i {
                    assert_eq!(s[(i, j)], 0.0);
                } else {
                    assert!((s[(i, j)] - 1.0 / (i + 1) as f64).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn ordering_preserved() {
        let m = Mat::from_vec(1, 3, vec![1.0, 3.0, 2.0]);
        let s = softmax_rows(&m);
        assert!(s[(0, 1)] > s[(0, 2)] && s[(0, 2)] > s[(0, 0)]);
    }
}
