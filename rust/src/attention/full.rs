//! Reference full-rank scaled-dot-product attention (paper Eq. 1) — the
//! fidelity upper bound every approximation is scored against.

use super::softmax::{causal_mask_inplace, softmax_rows_inplace};
use crate::linalg::{matmul, matmul_bt, Mat};

/// Single-head attention inputs (one head's projected Q/K/V).
#[derive(Debug, Clone)]
pub struct AttnInputs {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
    pub causal: bool,
}

impl AttnInputs {
    pub fn seq_len(&self) -> usize {
        self.q.rows()
    }

    pub fn head_dim(&self) -> usize {
        self.q.cols()
    }
}

/// Raw (pre-softmax) attention scores  QKᵀ/√d.
pub fn attention_scores(inp: &AttnInputs) -> Mat {
    let d = inp.head_dim() as f64;
    let mut scores = matmul_bt(&inp.q, &inp.k);
    scores.scale_inplace(1.0 / d.sqrt());
    if inp.causal {
        causal_mask_inplace(&mut scores);
    }
    scores
}

/// The attention matrix A = softmax(QKᵀ/√d) (Eq. 1).
pub fn attention_matrix(inp: &AttnInputs) -> Mat {
    let mut scores = attention_scores(inp);
    softmax_rows_inplace(&mut scores);
    scores
}

/// Full attention output  Y = A·V.
pub fn full_attention(inp: &AttnInputs) -> Mat {
    let a = attention_matrix(inp);
    matmul(&a, &inp.v)
}

/// Attention output from a provided (possibly approximated) A.
pub fn apply_attention(a: &Mat, v: &Mat) -> Mat {
    matmul(a, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn inputs(n: usize, d: usize, causal: bool, seed: u64) -> AttnInputs {
        let mut rng = Pcg32::seeded(seed);
        AttnInputs {
            q: Mat::randn(n, d, 1.0, &mut rng),
            k: Mat::randn(n, d, 1.0, &mut rng),
            v: Mat::randn(n, d, 1.0, &mut rng),
            causal,
        }
    }

    #[test]
    fn attention_rows_are_distributions() {
        let a = attention_matrix(&inputs(12, 8, false, 1));
        for i in 0..12 {
            let sum: f64 = a.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-10);
            assert!(a.row(i).iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn causal_attention_lower_triangular() {
        let a = attention_matrix(&inputs(10, 4, true, 2));
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_eq!(a[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn output_shape() {
        let inp = inputs(16, 8, false, 3);
        let y = full_attention(&inp);
        assert_eq!(y.shape(), (16, 8));
    }

    #[test]
    fn uniform_keys_give_uniform_attention() {
        let mut rng = Pcg32::seeded(4);
        let inp = AttnInputs {
            q: Mat::randn(6, 4, 1.0, &mut rng),
            k: Mat::zeros(6, 4), // all scores identical
            v: Mat::randn(6, 4, 1.0, &mut rng),
            causal: false,
        };
        let a = attention_matrix(&inp);
        for i in 0..6 {
            for j in 0..6 {
                assert!((a[(i, j)] - 1.0 / 6.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn scale_invariance_of_shape_not_values() {
        // Scaling Q changes sharpness: larger scale → more peaked rows.
        let base = inputs(8, 4, false, 5);
        let sharp = AttnInputs { q: base.q.scale(10.0), ..base.clone() };
        let a0 = attention_matrix(&base);
        let a1 = attention_matrix(&sharp);
        let peak0 = a0.row(0).iter().copied().fold(0.0f64, f64::max);
        let peak1 = a1.row(0).iter().copied().fold(0.0f64, f64::max);
        assert!(peak1 > peak0);
    }
}
