//! Dense layer with manual backprop — the building block of the Rust-side
//! policy/value networks (no autograd framework exists in this build, so
//! gradients are hand-derived and covered by finite-difference tests).

use crate::linalg::{matmul, matmul_at, matmul_bt, Mat};
use crate::util::Pcg32;

/// y = x·W + b, with cached activations for the backward pass.
#[derive(Debug, Clone)]
pub struct Linear {
    pub w: Mat,
    pub b: Vec<f64>,
    pub dw: Mat,
    pub db: Vec<f64>,
    cache_x: Option<Mat>,
}

impl Linear {
    pub fn new(in_dim: usize, out_dim: usize, rng: &mut Pcg32) -> Self {
        // He/Xavier hybrid: scaled for tanh/relu nets of this size.
        let std = (2.0 / (in_dim + out_dim) as f64).sqrt();
        Linear {
            w: Mat::randn(in_dim, out_dim, std, rng),
            b: vec![0.0; out_dim],
            dw: Mat::zeros(in_dim, out_dim),
            db: vec![0.0; out_dim],
            cache_x: None,
        }
    }

    pub fn in_dim(&self) -> usize {
        self.w.rows()
    }

    pub fn out_dim(&self) -> usize {
        self.w.cols()
    }

    /// Forward; caches x for backward.
    pub fn forward(&mut self, x: &Mat) -> Mat {
        let mut y = matmul(x, &self.w);
        for i in 0..y.rows() {
            let row = y.row_mut(i);
            for (j, bj) in self.b.iter().enumerate() {
                row[j] += bj;
            }
        }
        self.cache_x = Some(x.clone());
        y
    }

    /// Inference-only forward (no cache).
    pub fn forward_inference(&self, x: &Mat) -> Mat {
        let mut y = matmul(x, &self.w);
        for i in 0..y.rows() {
            let row = y.row_mut(i);
            for (j, bj) in self.b.iter().enumerate() {
                row[j] += bj;
            }
        }
        y
    }

    /// Backward: accumulates dW, db; returns dx.
    pub fn backward(&mut self, dy: &Mat) -> Mat {
        let x = self.cache_x.as_ref().expect("forward before backward");
        self.dw.add_inplace(&matmul_at(x, dy));
        for i in 0..dy.rows() {
            for (j, d) in dy.row(i).iter().enumerate() {
                self.db[j] += d;
            }
        }
        matmul_bt(dy, &self.w) // dx = dy · Wᵀ
    }

    pub fn zero_grad(&mut self) {
        self.dw = Mat::zeros(self.w.rows(), self.w.cols());
        self.db.iter_mut().for_each(|d| *d = 0.0);
    }

    /// Flattened parameter count.
    pub fn n_params(&self) -> usize {
        self.w.rows() * self.w.cols() + self.b.len()
    }
}

/// Activation functions with derivatives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Tanh,
    Identity,
}

impl Act {
    pub fn apply(&self, x: f64) -> f64 {
        match self {
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Identity => x,
        }
    }

    /// Derivative expressed in terms of the *activation output* y.
    pub fn deriv_from_output(&self, y: f64) -> f64 {
        match self {
            Act::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Tanh => 1.0 - y * y,
            Act::Identity => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Central finite-difference check of dW for a scalar loss L = Σ y².
    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Pcg32::seeded(1);
        let mut lin = Linear::new(4, 3, &mut rng);
        let x = Mat::randn(5, 4, 1.0, &mut rng);

        let y = lin.forward(&x);
        let dy = y.scale(2.0); // dL/dy for L = Σ y²
        lin.zero_grad();
        let dx = lin.backward(&dy);

        let eps = 1e-6;
        // Check a few weight entries.
        for &(i, j) in &[(0usize, 0usize), (1, 2), (3, 1)] {
            let mut lp = lin.clone();
            lp.w[(i, j)] += eps;
            let mut lm = lin.clone();
            lm.w[(i, j)] -= eps;
            let loss_p: f64 = lp.forward_inference(&x).data().iter().map(|v| v * v).sum();
            let loss_m: f64 = lm.forward_inference(&x).data().iter().map(|v| v * v).sum();
            let fd = (loss_p - loss_m) / (2.0 * eps);
            assert!(
                (lin.dw[(i, j)] - fd).abs() < 1e-4,
                "dW[{i},{j}]: analytic {} vs fd {fd}",
                lin.dw[(i, j)]
            );
        }
        // Check dx entries.
        for &(i, j) in &[(0usize, 0usize), (4, 3)] {
            let mut xp = x.clone();
            xp[(i, j)] += eps;
            let mut xm = x.clone();
            xm[(i, j)] -= eps;
            let loss_p: f64 = lin.forward_inference(&xp).data().iter().map(|v| v * v).sum();
            let loss_m: f64 = lin.forward_inference(&xm).data().iter().map(|v| v * v).sum();
            let fd = (loss_p - loss_m) / (2.0 * eps);
            assert!((dx[(i, j)] - fd).abs() < 1e-4, "dx[{i},{j}]");
        }
        // Bias gradient: db_j = Σ_i dy_ij.
        for j in 0..3 {
            let want: f64 = (0..5).map(|i| dy[(i, j)]).sum();
            assert!((lin.db[j] - want).abs() < 1e-10);
        }
    }

    #[test]
    fn activations() {
        assert_eq!(Act::Relu.apply(-1.0), 0.0);
        assert_eq!(Act::Relu.apply(2.0), 2.0);
        assert!((Act::Tanh.apply(0.5) - 0.5f64.tanh()).abs() < 1e-12);
        assert_eq!(Act::Identity.deriv_from_output(5.0), 1.0);
        assert_eq!(Act::Relu.deriv_from_output(0.0), 0.0);
        let y = Act::Tanh.apply(0.3);
        assert!((Act::Tanh.deriv_from_output(y) - (1.0 - y * y)).abs() < 1e-12);
    }

    #[test]
    fn inference_matches_training_forward() {
        let mut rng = Pcg32::seeded(2);
        let mut lin = Linear::new(6, 2, &mut rng);
        let x = Mat::randn(3, 6, 1.0, &mut rng);
        let a = lin.forward(&x);
        let b = lin.forward_inference(&x);
        assert!(a.allclose(&b, 0.0));
    }
}
