//! AdamW optimizer for the hand-rolled networks (matches the paper's
//! training setup: AdamW with linear LR schedule).

use super::mlp::Mlp;

/// AdamW state over a flat parameter vector.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl AdamW {
    pub fn new(n_params: usize, lr: f64) -> Self {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// One update step over an MLP's accumulated gradients.
    pub fn step(&mut self, net: &mut Mlp) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (lr, b1, b2, eps, wd) = (self.lr, self.beta1, self.beta2, self.eps, self.weight_decay);
        let mut idx = 0usize;
        let m = &mut self.m;
        let v = &mut self.v;
        net.visit_params_mut(|p, g| {
            let mi = &mut m[idx];
            let vi = &mut v[idx];
            *mi = b1 * *mi + (1.0 - b1) * g;
            *vi = b2 * *vi + (1.0 - b2) * g * g;
            let mhat = *mi / bc1;
            let vhat = *vi / bc2;
            // Decoupled weight decay (AdamW).
            *p -= lr * (mhat / (vhat.sqrt() + eps) + wd * *p);
            idx += 1;
        });
        debug_assert_eq!(idx, m.len(), "param count changed under the optimizer");
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Linear LR decay from `lr0` to `lr_min` across `total` steps.
    pub fn set_linear_schedule(&mut self, lr0: f64, lr_min: f64, step: u64, total: u64) {
        let frac = (step as f64 / total.max(1) as f64).min(1.0);
        self.lr = lr0 + (lr_min - lr0) * frac;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::linear::Act;
    use crate::util::Pcg32;

    /// Train y = 2x − 1 regression; AdamW should reach near-zero loss.
    #[test]
    fn converges_on_linear_regression() {
        let mut rng = Pcg32::seeded(1);
        let mut net = Mlp::new(&[1, 16, 1], Act::Tanh, &mut rng);
        let mut opt = AdamW::new(net.n_params(), 1e-2);
        opt.weight_decay = 0.0;
        let xs: Vec<f64> = (0..32).map(|i| i as f64 / 16.0 - 1.0).collect();
        let x = Mat::from_vec(32, 1, xs.clone());
        let target = Mat::from_vec(32, 1, xs.iter().map(|v| 2.0 * v - 1.0).collect());
        let mut final_loss = f64::INFINITY;
        for _ in 0..500 {
            let y = net.forward(&x);
            let diff = &y - &target;
            final_loss = diff.data().iter().map(|d| d * d).sum::<f64>() / 32.0;
            net.zero_grad();
            net.backward(&diff.scale(2.0 / 32.0));
            opt.step(&mut net);
        }
        assert!(final_loss < 1e-3, "final loss {final_loss}");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut rng = Pcg32::seeded(2);
        let mut net = Mlp::new(&[2, 2], Act::Identity, &mut rng);
        let before: f64 = net.layers[0].w.fro_norm();
        let mut opt = AdamW::new(net.n_params(), 1e-2);
        opt.weight_decay = 0.1;
        // Zero gradients: only decay acts.
        for _ in 0..50 {
            net.zero_grad();
            opt.step(&mut net);
        }
        let after: f64 = net.layers[0].w.fro_norm();
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn linear_schedule_endpoints() {
        let mut opt = AdamW::new(4, 1.0);
        opt.set_linear_schedule(1.0, 0.1, 0, 100);
        assert!((opt.lr - 1.0).abs() < 1e-12);
        opt.set_linear_schedule(1.0, 0.1, 100, 100);
        assert!((opt.lr - 0.1).abs() < 1e-12);
        opt.set_linear_schedule(1.0, 0.1, 50, 100);
        assert!((opt.lr - 0.55).abs() < 1e-12);
    }
}
