//! Categorical distribution over discrete ranks (paper Eq. 15), with
//! action masking for the trust-region safety check and the entropy /
//! log-prob machinery PPO needs.

use crate::util::Pcg32;

/// A categorical distribution built from raw logits, with optional mask.
#[derive(Debug, Clone)]
pub struct Categorical {
    /// Normalized probabilities (masked entries are exactly 0).
    pub probs: Vec<f64>,
    /// log-probabilities (masked entries are -inf).
    pub log_probs: Vec<f64>,
}

impl Categorical {
    /// Build from logits; `mask[i] = false` forbids action i (§4.3.1).
    pub fn from_logits(logits: &[f64], mask: Option<&[bool]>) -> Self {
        assert!(!logits.is_empty());
        if let Some(m) = mask {
            assert_eq!(m.len(), logits.len());
            assert!(m.iter().any(|&b| b), "all actions masked");
        }
        let masked: Vec<f64> = logits
            .iter()
            .enumerate()
            .map(|(i, &l)| {
                if mask.map(|m| m[i]).unwrap_or(true) {
                    l
                } else {
                    f64::NEG_INFINITY
                }
            })
            .collect();
        let max = masked.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = masked.iter().map(|&l| (l - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        let probs: Vec<f64> = exps.iter().map(|&e| e / z).collect();
        let logz = z.ln() + max;
        let log_probs: Vec<f64> = masked.iter().map(|&l| l - logz).collect();
        Categorical { probs, log_probs }
    }

    pub fn n(&self) -> usize {
        self.probs.len()
    }

    /// Sample an action index.
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.next_f64();
        let mut acc = 0.0;
        for (i, &p) in self.probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        // Floating-point tail: last unmasked action.
        self.probs.iter().rposition(|&p| p > 0.0).unwrap_or(self.n() - 1)
    }

    /// Greedy argmax action.
    pub fn argmax(&self) -> usize {
        self.probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    pub fn log_prob(&self, action: usize) -> f64 {
        self.log_probs[action]
    }

    /// Shannon entropy (for PPO's exploration bonus).
    pub fn entropy(&self) -> f64 {
        -self
            .probs
            .iter()
            .filter(|&&p| p > 1e-15)
            .map(|&p| p * p.ln())
            .sum::<f64>()
    }

    /// d(-logπ(a))/d logits — the softmax-CE gradient: p_i − 1{i=a}.
    /// Masked entries get zero gradient.
    pub fn grad_nll_wrt_logits(&self, action: usize) -> Vec<f64> {
        self.probs
            .iter()
            .enumerate()
            .map(|(i, &p)| if i == action { p - 1.0 } else { p })
            .collect()
    }

    /// d entropy / d logits = -p_i (log p_i + H)... computed directly:
    /// dH/dl_i = -p_i (log p_i − Σ_j p_j log p_j) = -p_i(log p_i + H).
    pub fn grad_entropy_wrt_logits(&self) -> Vec<f64> {
        let h = self.entropy();
        self.probs
            .iter()
            .map(|&p| if p > 1e-15 { -p * (p.ln() + h) } else { 0.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probs_normalized() {
        let c = Categorical::from_logits(&[1.0, 2.0, 3.0], None);
        let sum: f64 = c.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!(c.probs[2] > c.probs[1] && c.probs[1] > c.probs[0]);
    }

    #[test]
    fn mask_zeroes_forbidden() {
        let c = Categorical::from_logits(&[5.0, 1.0, 1.0], Some(&[false, true, true]));
        assert_eq!(c.probs[0], 0.0);
        assert!((c.probs[1] - 0.5).abs() < 1e-12);
        assert!(c.log_probs[0].is_infinite());
    }

    #[test]
    #[should_panic]
    fn all_masked_panics() {
        let _ = Categorical::from_logits(&[1.0, 2.0], Some(&[false, false]));
    }

    #[test]
    fn sampling_respects_mask_and_distribution() {
        let c = Categorical::from_logits(&[0.0, 0.0, 2.0], Some(&[false, true, true]));
        let mut rng = Pcg32::seeded(1);
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[c.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[0], 0);
        let frac2 = counts[2] as f64 / 20_000.0;
        assert!((frac2 - c.probs[2]).abs() < 0.02);
    }

    #[test]
    fn entropy_bounds() {
        let uniform = Categorical::from_logits(&[0.0; 8], None);
        assert!((uniform.entropy() - (8.0f64).ln()).abs() < 1e-9);
        let peaked = Categorical::from_logits(&[100.0, 0.0, 0.0], None);
        assert!(peaked.entropy() < 1e-6);
    }

    #[test]
    fn nll_gradient_finite_difference() {
        let logits = [0.3, -0.7, 1.2, 0.1];
        let action = 2;
        let c = Categorical::from_logits(&logits, None);
        let g = c.grad_nll_wrt_logits(action);
        let eps = 1e-6;
        for i in 0..4 {
            let mut lp = logits;
            lp[i] += eps;
            let mut lm = logits;
            lm[i] -= eps;
            let fp = -Categorical::from_logits(&lp, None).log_prob(action);
            let fm = -Categorical::from_logits(&lm, None).log_prob(action);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-6, "i={i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn entropy_gradient_finite_difference() {
        let logits = [0.5, -0.2, 0.9];
        let c = Categorical::from_logits(&logits, None);
        let g = c.grad_entropy_wrt_logits();
        let eps = 1e-6;
        for i in 0..3 {
            let mut lp = logits;
            lp[i] += eps;
            let mut lm = logits;
            lm[i] -= eps;
            let fd = (Categorical::from_logits(&lp, None).entropy()
                - Categorical::from_logits(&lm, None).entropy())
                / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-6, "i={i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn argmax_greedy() {
        let c = Categorical::from_logits(&[0.1, 3.0, 0.2], None);
        assert_eq!(c.argmax(), 1);
    }
}
