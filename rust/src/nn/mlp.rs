//! Multi-layer perceptron with manual backprop — the policy and value
//! network bodies for the Rust-side PPO/BC trainer.

use super::linear::{Act, Linear};
use crate::linalg::Mat;
use crate::util::Pcg32;

/// Feed-forward network: Linear → act → … → Linear (last layer linear).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Linear>,
    pub act: Act,
    /// Cached post-activation outputs per hidden layer (for backward).
    caches: Vec<Mat>,
}

impl Mlp {
    /// `dims = [in, h1, …, out]`.
    pub fn new(dims: &[usize], act: Act, rng: &mut Pcg32) -> Self {
        assert!(dims.len() >= 2);
        let layers = dims.windows(2).map(|w| Linear::new(w[0], w[1], rng)).collect();
        Mlp { layers, act, caches: Vec::new() }
    }

    pub fn forward(&mut self, x: &Mat) -> Mat {
        self.caches.clear();
        let n = self.layers.len();
        let mut cur = x.clone();
        for (i, layer) in self.layers.iter_mut().enumerate() {
            cur = layer.forward(&cur);
            if i + 1 < n {
                cur = cur.map(|v| self.act.apply(v));
                self.caches.push(cur.clone());
            }
        }
        cur
    }

    pub fn forward_inference(&self, x: &Mat) -> Mat {
        let n = self.layers.len();
        let mut cur = x.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            cur = layer.forward_inference(&cur);
            if i + 1 < n {
                cur = cur.map(|v| self.act.apply(v));
            }
        }
        cur
    }

    /// Backward from dL/d(output); accumulates grads, returns dL/dx.
    pub fn backward(&mut self, dy: &Mat) -> Mat {
        let n = self.layers.len();
        let mut grad = dy.clone();
        for i in (0..n).rev() {
            if i + 1 < n {
                // Chain through the activation using the cached output.
                let cache = &self.caches[i];
                assert_eq!(grad.shape(), cache.shape());
                let mut g = grad.clone();
                for (gv, cv) in g.data_mut().iter_mut().zip(cache.data().iter()) {
                    *gv *= self.act.deriv_from_output(*cv);
                }
                grad = self.layers[i].backward(&g);
            } else {
                grad = self.layers[i].backward(&grad);
            }
        }
        grad
    }

    pub fn zero_grad(&mut self) {
        for l in self.layers.iter_mut() {
            l.zero_grad();
        }
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.n_params()).sum()
    }

    /// Visit (param, grad) pairs — used by the optimizer.
    pub fn visit_params_mut<F: FnMut(&mut f64, f64)>(&mut self, mut f: F) {
        for l in self.layers.iter_mut() {
            let dw = l.dw.clone();
            for (p, g) in l.w.data_mut().iter_mut().zip(dw.data().iter()) {
                f(p, *g);
            }
            let db = l.db.clone();
            for (p, g) in l.b.iter_mut().zip(db.iter()) {
                f(p, *g);
            }
        }
    }

    /// Global L2 norm of the gradient (for clipping).
    pub fn grad_norm(&self) -> f64 {
        let mut acc = 0.0;
        for l in &self.layers {
            acc += l.dw.data().iter().map(|g| g * g).sum::<f64>();
            acc += l.db.iter().map(|g| g * g).sum::<f64>();
        }
        acc.sqrt()
    }

    /// Scale all gradients (gradient clipping).
    pub fn scale_grads(&mut self, s: f64) {
        for l in self.layers.iter_mut() {
            l.dw.scale_inplace(s);
            l.db.iter_mut().for_each(|g| *g *= s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_through_network() {
        let mut rng = Pcg32::seeded(1);
        let mut mlp = Mlp::new(&[8, 16, 16, 3], Act::Tanh, &mut rng);
        let x = Mat::randn(5, 8, 1.0, &mut rng);
        let y = mlp.forward(&x);
        assert_eq!(y.shape(), (5, 3));
        assert_eq!(mlp.n_params(), 8 * 16 + 16 + 16 * 16 + 16 + 16 * 3 + 3);
    }

    #[test]
    fn full_network_gradcheck() {
        let mut rng = Pcg32::seeded(2);
        let mut mlp = Mlp::new(&[3, 7, 2], Act::Tanh, &mut rng);
        let x = Mat::randn(4, 3, 1.0, &mut rng);
        let y = mlp.forward(&x);
        let dy = y.scale(2.0); // L = Σ y²
        mlp.zero_grad();
        mlp.backward(&dy);

        let loss = |m: &Mlp, x: &Mat| -> f64 {
            m.forward_inference(x).data().iter().map(|v| v * v).sum()
        };
        let eps = 1e-6;
        // Spot-check entries in both layers.
        for layer_idx in 0..2 {
            let (i, j) = (0usize, 0usize);
            let analytic = mlp.layers[layer_idx].dw[(i, j)];
            let mut mp = mlp.clone();
            mp.layers[layer_idx].w[(i, j)] += eps;
            let mut mm = mlp.clone();
            mm.layers[layer_idx].w[(i, j)] -= eps;
            let fd = (loss(&mp, &x) - loss(&mm, &x)) / (2.0 * eps);
            assert!(
                (analytic - fd).abs() < 1e-4,
                "layer {layer_idx} dW[0,0]: {analytic} vs {fd}"
            );
        }
    }

    #[test]
    fn relu_network_gradcheck() {
        let mut rng = Pcg32::seeded(3);
        let mut mlp = Mlp::new(&[4, 8, 1], Act::Relu, &mut rng);
        let x = Mat::randn(6, 4, 1.0, &mut rng);
        let y = mlp.forward(&x);
        let dy = Mat::filled(6, 1, 1.0); // L = Σ y
        mlp.zero_grad();
        mlp.backward(&dy);
        let loss = |m: &Mlp, x: &Mat| -> f64 { m.forward_inference(x).data().iter().sum() };
        let eps = 1e-6;
        let analytic = mlp.layers[0].dw[(1, 1)];
        let mut mp = mlp.clone();
        mp.layers[0].w[(1, 1)] += eps;
        let mut mm = mlp.clone();
        mm.layers[0].w[(1, 1)] -= eps;
        let fd = (loss(&mp, &x) - loss(&mm, &x)) / (2.0 * eps);
        assert!((analytic - fd).abs() < 1e-4, "{analytic} vs {fd}");
        let _ = y;
    }

    #[test]
    fn grad_clipping() {
        let mut rng = Pcg32::seeded(4);
        let mut mlp = Mlp::new(&[2, 4, 1], Act::Tanh, &mut rng);
        let x = Mat::randn(2, 2, 1.0, &mut rng);
        let y = mlp.forward(&x);
        mlp.zero_grad();
        mlp.backward(&y.scale(100.0));
        let norm = mlp.grad_norm();
        assert!(norm > 0.0);
        mlp.scale_grads(1.0 / norm);
        assert!((mlp.grad_norm() - 1.0).abs() < 1e-9);
    }
}
