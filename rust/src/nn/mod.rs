//! Hand-rolled neural-network substrate (no autograd available offline):
//! dense layers with derived gradients, MLPs, AdamW, and the masked
//! categorical distribution used for rank actions (Eq. 15).

pub mod adam;
pub mod categorical;
pub mod linear;
pub mod mlp;

pub use adam::AdamW;
pub use categorical::Categorical;
pub use linear::{Act, Linear};
pub use mlp::Mlp;
