//! Timing helpers: scoped stopwatch and streaming latency statistics
//! (mean / p50 / p90 / p99) used by the metrics module and bench harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Reservoir of samples with summary statistics. Keeps all samples up to a
/// cap (default 1M, plenty for our benches) — exact percentiles matter
/// more here than constant memory.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    samples: Vec<f64>,
    cap: usize,
    total_count: u64,
    sum: f64,
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyStats {
    pub fn new() -> Self {
        LatencyStats { samples: Vec::new(), cap: 1_000_000, total_count: 0, sum: 0.0 }
    }

    pub fn record(&mut self, v: f64) {
        self.total_count += 1;
        self.sum += v;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        }
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64() * 1e3); // milliseconds
    }

    pub fn count(&self) -> u64 {
        self.total_count
    }

    pub fn mean(&self) -> f64 {
        if self.total_count == 0 {
            0.0
        } else {
            self.sum / self.total_count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile over retained samples (q in [0,1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        sorted[idx]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.total_count,
            self.mean(),
            self.p50(),
            self.p90(),
            self.p99(),
            if self.samples.is_empty() { 0.0 } else { self.max() }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_on_known_data() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.p50() - 50.0).abs() <= 1.0);
        assert!((s.p99() - 99.0).abs() <= 1.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }
}
