//! Lightweight leveled logger (the `log` facade is vendored but no
//! emitter is; this keeps the dependency surface at zero).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_level_from_env() {
    if let Ok(v) = std::env::var("DRRL_LOG") {
        let level = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        set_level(level);
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

#[doc(hidden)]
pub fn log_impl(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logger::log_impl($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logger::log_impl($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logger::log_impl($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logger::log_impl($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
