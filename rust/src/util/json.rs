//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Used for the artifact manifest, experiment configs and bench CSV/JSON
//! outputs. Supports the full JSON grammar minus exotic escapes; numbers
//! are parsed as f64 (sufficient for configs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation (committed artifacts like the
    /// BENCH_*.json snapshots are diffed in review, so stable layout
    /// matters).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..=depth {
                        out.push_str("  ");
                    }
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push_str("  ");
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..=depth {
                        out.push_str("  ");
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth {
                    out.push_str("  ");
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

/// Convenience builder for objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(v.get("e").unwrap().as_bool(), Some(true));
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
