//! Deterministic PRNG for the whole stack.
//!
//! The offline environment has no `rand` crate, so we implement PCG-XSH-RR
//! (O'Neill 2014) — small state, excellent statistical quality, and cheap
//! enough for the hot loop of the synthetic-corpus generators.

/// PCG-XSH-RR 64/32 generator with explicit stream selection.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value is skipped for
    /// simplicity; draw cost is negligible relative to matmuls).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weights must have positive mass");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a slice with i.i.d. normal(0, std) f32 values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f64) {
        for v in out.iter_mut() {
            *v = (self.normal() * std) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket frac {frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Pcg32::seeded(5);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..1000 {
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
        }
    }
}
