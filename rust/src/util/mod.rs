//! Zero-dependency utility substrates: PRNG, thread pool, CLI parsing,
//! JSON, logging and timing. The offline build has no tokio/clap/serde/
//! rand, so these are first-class modules with their own tests.

pub mod cli;
pub mod json;
pub mod logger;
pub mod rng;
pub mod sync;
pub mod threadpool;
pub mod timer;

pub use cli::Args;
pub use json::Json;
pub use rng::Pcg32;
pub use sync::{CondvarExt, LockExt};
pub use threadpool::{global_pool, ThreadPool};
pub use timer::{LatencyStats, Stopwatch};
