//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands; typed getters with defaults and error reporting.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand (first bare word), named options, flags
/// and remaining positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit argv (without the binary name).
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing.
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // Lookahead: value unless next token is another option.
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.opts.insert(body.to_string(), v);
                        }
                        _ => out.flags.push(body.to_string()),
                    }
                }
            } else if out.command.is_none() && out.positional.is_empty() && out.opts.is_empty() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.parse_or(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.parse_or(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.parse_or(name, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: --{name}={v} not parseable, using default");
                default
            }),
            None => default,
        }
    }

    /// Comma-separated list of usizes, e.g. `--ranks 16,32,64`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().expect("integer list"))
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --port 8080 --verbose --mode=fast tail1 tail2");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("mode"), Some("fast"));
        assert_eq!(a.positional, vec!["tail1", "tail2"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 32 --lr 0.001");
        assert_eq!(a.usize_or("n", 0), 32);
        assert!((a.f64_or("lr", 0.0) - 0.001).abs() < 1e-12);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn list_parsing() {
        let a = parse("x --ranks 16,32,64");
        assert_eq!(a.usize_list_or("ranks", &[]), vec![16, 32, 64]);
        assert_eq!(a.usize_list_or("absent", &[8]), vec![8]);
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("run --a 1 -- --not-an-opt");
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-opt"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --fast --slow");
        assert!(a.flag("fast"));
        assert!(a.flag("slow"));
    }
}
