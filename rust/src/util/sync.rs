//! Poison-recovering lock/condvar helpers.
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a cascade of
//! secondary panics in every other thread that touches the same lock —
//! the serving engine would rather keep draining tickets with the data
//! the panicking thread left behind (every guarded structure here is a
//! counter ledger or a controller whose invariants are re-checked at use
//! time). These extension traits recover the guard from a
//! [`std::sync::PoisonError`] instead of unwrapping it, and the
//! `drrl lint` pass (rule R1 `lock-unwrap`, see [`crate::analysis`])
//! forbids the raw `.lock().unwrap()` / `.lock().expect(..)` pattern
//! across all of `rust/src/` so new code cannot reintroduce the
//! cascade.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Poison-recovering [`Mutex::lock`].
pub trait LockExt<T> {
    /// Lock, recovering the guard if a previous holder panicked.
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Poison-recovering [`Condvar`] waits.
pub trait CondvarExt {
    /// [`Condvar::wait`], recovering the guard on poison.
    fn wait_unpoisoned<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T>;

    /// [`Condvar::wait_timeout`], recovering the guard on poison.
    fn wait_timeout_unpoisoned<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult);
}

impl CondvarExt for Condvar {
    fn wait_unpoisoned<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.wait(guard).unwrap_or_else(PoisonError::into_inner)
    }

    fn wait_timeout_unpoisoned<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        self.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_unpoisoned_recovers_after_panic() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.lock().is_err(), "lock should be poisoned");
        assert_eq!(*m.lock_unpoisoned(), 7);
    }

    #[test]
    fn wait_timeout_unpoisoned_times_out_normally() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = m.lock_unpoisoned();
        let (_g, res) = cv.wait_timeout_unpoisoned(g, Duration::from_millis(5));
        assert!(res.timed_out());
    }

    #[test]
    fn wait_unpoisoned_wakes_on_notify() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = m.lock_unpoisoned();
            while !*g {
                g = cv.wait_unpoisoned(g);
            }
        });
        {
            let (m, cv) = &*pair;
            *m.lock_unpoisoned() = true;
            cv.notify_all();
        }
        t.join().unwrap();
    }
}
