//! Minimal work-stealing-free thread pool (tokio/rayon are unavailable in
//! the offline build). Supports fire-and-forget jobs and a scoped
//! parallel-for used by the blocked matmul and batched SVD.

use crate::util::sync::{CondvarExt, LockExt};
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
// The pool's internal job queue, not a request-path channel surface
// (those go through coordinator/completion.rs). lint:allow(mpsc)
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True while the current thread is a pool worker executing a job.
    /// Nested parallel-for calls from inside a job run inline instead of
    /// re-entering the queue: a job that blocks on a latch while its
    /// sub-jobs sit behind other queued jobs deadlocks once every worker
    /// is blocked the same way (observed with batched per-head SVDs whose
    /// inner matmuls are themselves parallel).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Sender<Msg>,
    // Same internal queue as above. lint:allow(mpsc)
    shared_rx: Arc<Mutex<std::sync::mpsc::Receiver<Msg>>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (clamped to >= 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Msg>();
        let shared_rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&shared_rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("drrl-worker-{i}"))
                    .spawn(move || {
                        IN_POOL_WORKER.with(|f| f.set(true));
                        loop {
                            let msg = { rx.lock_unpoisoned().recv() };
                            match msg {
                                Ok(Msg::Run(job)) => job(),
                                Ok(Msg::Shutdown) | Err(_) => break,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, shared_rx, workers, size }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_for_machine() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.min(16))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Run `n` indexed chunks of work and wait for all of them.
    ///
    /// `f` is shared by reference across workers; the closure must be
    /// `Sync`. Blocks the caller until every chunk finishes.
    pub fn scoped_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        if n == 1 || self.size == 1 || IN_POOL_WORKER.with(|fl| fl.get()) {
            // Inline: trivial work, a single-worker pool, or a nested call
            // from inside a pool job (see IN_POOL_WORKER).
            for i in 0..n {
                f(i);
            }
            return;
        }
        let latch = Arc::new(Latch::new(n));
        // SAFETY: we block on the latch before returning, so `f` outlives
        // every job that borrows it.
        let f_ptr: &(dyn Fn(usize) + Send + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Send + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for i in 0..n {
            let latch = Arc::clone(&latch);
            self.execute(move || {
                f_static(i);
                latch.count_down();
            });
        }
        latch.wait();
    }

    /// Run `n` indexed tasks and collect their results in index order —
    /// the common fan-out shape of the serving pipeline's probe and
    /// apply waves. Wraps [`Self::scoped_for`] so call sites don't repeat
    /// the disjoint-slot `SendPtr` dance.
    pub fn scoped_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Send + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let ptr = SendPtr::new(&mut out);
            self.scoped_for(n, |i| {
                // SAFETY: each index writes a distinct slot, and
                // scoped_for joins every task before returning.
                unsafe { ptr.get() }[i] = Some(f(i));
            });
        }
        out.into_iter().map(|o| o.expect("slot filled")).collect()
    }

    /// Split `total` items into roughly equal chunks (one per worker) and
    /// run `f(start, end)` on each in parallel.
    pub fn chunked_for<F>(&self, total: usize, min_chunk: usize, f: F)
    where
        F: Fn(usize, usize) + Send + Sync,
    {
        if total == 0 {
            return;
        }
        let chunks = (total / min_chunk.max(1)).clamp(1, self.size * 2);
        let per = total.div_ceil(chunks);
        self.scoped_for(chunks, |c| {
            let start = c * per;
            let end = ((c + 1) * per).min(total);
            if start < end {
                f(start, end);
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Drain: workers holding the shared receiver exit on Shutdown/Err.
        let _ = &self.shared_rx;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Simple countdown latch.
pub struct Latch {
    remaining: AtomicUsize,
    mu: Mutex<()>,
    cv: Condvar,
}

impl Latch {
    pub fn new(n: usize) -> Self {
        Latch { remaining: AtomicUsize::new(n), mu: Mutex::new(()), cv: Condvar::new() }
    }

    pub fn count_down(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = self.mu.lock_unpoisoned();
            self.cv.notify_all();
        }
    }

    pub fn wait(&self) {
        let mut g = self.mu.lock_unpoisoned();
        while self.remaining.load(Ordering::Acquire) != 0 {
            g = self.cv.wait_unpoisoned(g);
        }
    }
}

/// Wrapper that lets a raw mutable pointer cross thread boundaries for
/// scoped disjoint writes (each worker touches a disjoint region).
/// Method-based access ensures closures capture the whole wrapper under
/// edition-2021 disjoint field capture.
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: &mut T) -> Self {
        SendPtr(p as *mut T)
    }

    /// # Safety
    /// Callers must guarantee disjoint access across threads and that the
    /// pointee outlives every use (the scoped_for latch provides this).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self) -> &mut T {
        &mut *self.0
    }
}

/// Global shared pool for the numeric kernels; created lazily.
pub fn global_pool() -> &'static ThreadPool {
    use std::sync::OnceLock;
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::default_for_machine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let latch = Arc::new(Latch::new(100));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let l = Arc::clone(&latch);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
                l.count_down();
            });
        }
        latch.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scoped_for_covers_every_index() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        pool.scoped_for(64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn scoped_map_returns_in_index_order() {
        let pool = ThreadPool::new(4);
        let got = pool.scoped_map(64, |i| i * i);
        let want: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(got, want);
        assert!(pool.scoped_map(0, |i| i).is_empty());
    }

    #[test]
    fn scoped_map_nested_inside_pool_job() {
        // scoped_map from inside a pool job must fall back to inline
        // execution (same IN_POOL_WORKER rule as scoped_for).
        let pool = global_pool();
        let outer = pool.size() + 2;
        let got = pool.scoped_map(outer, |i| pool.scoped_map(4, move |j| i * 4 + j));
        for (i, inner) in got.iter().enumerate() {
            assert_eq!(inner, &vec![i * 4, i * 4 + 1, i * 4 + 2, i * 4 + 3]);
        }
    }

    #[test]
    fn chunked_for_partitions_exactly() {
        let pool = ThreadPool::new(3);
        let total = 1000;
        let seen = Arc::new(Mutex::new(vec![0u8; total]));
        pool.chunked_for(total, 10, |s, e| {
            let mut g = seen.lock().unwrap();
            for i in s..e {
                g[i] += 1;
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&x| x == 1));
    }

    #[test]
    fn zero_work_is_fine() {
        let pool = ThreadPool::new(2);
        pool.scoped_for(0, |_| panic!("should not run"));
        pool.chunked_for(0, 4, |_, _| panic!("should not run"));
    }

    #[test]
    fn nested_parallel_for_does_not_deadlock() {
        // Saturate the pool with jobs that each issue a nested parallel
        // for; without the IN_POOL_WORKER inline fallback this deadlocks
        // once every worker blocks on its sub-jobs.
        let pool = global_pool();
        let outer = pool.size() * 2 + 2;
        let inner = 8;
        let hits: Vec<AtomicU64> = (0..outer * inner).map(|_| AtomicU64::new(0)).collect();
        pool.scoped_for(outer, |i| {
            pool.scoped_for(inner, |j| {
                hits[i * inner + j].fetch_add(1, Ordering::Relaxed);
            });
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn nested_chunked_for_covers_everything() {
        let pool = global_pool();
        let total = 256;
        let seen: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
        pool.chunked_for(total, 8, |s, e| {
            // Nested chunked_for inside a job must run inline and still
            // cover its full range exactly once.
            pool.chunked_for(e - s, 4, |s2, e2| {
                for i in (s + s2)..(s + e2) {
                    seen[i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        for h in &seen {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let latch = Arc::new(Latch::new(1));
        let l = Arc::clone(&latch);
        pool.execute(move || l.count_down());
        latch.wait();
        drop(pool); // must not hang
    }
}
