//! Spectral analysis substrate: Normalized Energy Ratio (Eq. 14),
//! perturbation bounds (Eq. 4/5/9/10) and the annealed trust region
//! (Eq. 11) that guards the RL agent's rank transitions.

pub mod energy;
pub mod perturbation;
pub mod trust_region;

pub use energy::{
    decay_exponent, ner, rank_for_energy, soft_threshold_rank, spectral_entropy,
    spectrum_features,
};
pub use perturbation::{
    assess_transition, final_output_bound, output_bound, qk_bound_from_mats,
    qk_residual_bound, rank_transition_perturbation, relative_transition_perturbation,
    TransitionAssessment,
};
pub use trust_region::TrustRegion;
