//! Trust-region safety guardrail (paper §4.3.1).
//!
//! A candidate rank sampled from the policy is *masked* (rejected) when
//! its predicted perturbation exceeds the annealed threshold
//! ε_t = ε₀·exp(−λt) (Eq. 11). Annealing starts permissive (exploration)
//! and tightens as the policy converges.

use super::perturbation::TransitionAssessment;

/// Annealed trust-region threshold.
#[derive(Debug, Clone)]
pub struct TrustRegion {
    /// ε₀ — initial threshold.
    pub epsilon0: f64,
    /// λ — decay rate per decision step.
    pub lambda: f64,
    /// Floor so the region never collapses to zero (keeps at least the
    /// current rank and its immediate neighbours reachable).
    pub epsilon_min: f64,
    step: u64,
    /// Rejected-action count (metrics / Fig. 5 overlay).
    pub rejections: u64,
    /// Accepted-action count.
    pub acceptances: u64,
}

impl TrustRegion {
    pub fn new(epsilon0: f64, lambda: f64) -> Self {
        TrustRegion {
            epsilon0,
            lambda,
            epsilon_min: 0.05,
            step: 0,
            rejections: 0,
            acceptances: 0,
        }
    }

    /// Paper defaults used in the experiments.
    pub fn paper_default() -> Self {
        Self::new(0.7, 5e-5)
    }

    /// Current ε_t (Eq. 11).
    pub fn epsilon(&self) -> f64 {
        (self.epsilon0 * (-self.lambda * self.step as f64).exp()).max(self.epsilon_min)
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Advance the annealing clock one decision step.
    pub fn tick(&mut self) {
        self.step += 1;
    }

    /// Is this transition inside the trust region? Does not tick.
    pub fn admits(&self, assessment: &TransitionAssessment) -> bool {
        assessment.delta_a_fro <= self.epsilon()
    }

    /// Check-and-record: returns true if admitted; updates counters.
    pub fn check(&mut self, assessment: &TransitionAssessment) -> bool {
        let ok = self.admits(assessment);
        if ok {
            self.acceptances += 1;
        } else {
            self.rejections += 1;
        }
        ok
    }

    /// Mask a whole action set: `true` entries are admissible. Rank
    /// *decreases that stay at the current rank* are always admissible
    /// (the agent can always do nothing).
    pub fn mask_actions(
        &self,
        current_rank: usize,
        assessments: &[TransitionAssessment],
    ) -> Vec<bool> {
        assessments
            .iter()
            .map(|a| a.r_to == current_rank || self.admits(a))
            .collect()
    }

    pub fn rejection_rate(&self) -> f64 {
        let total = self.rejections + self.acceptances;
        if total == 0 {
            0.0
        } else {
            self.rejections as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spectral::perturbation::assess_transition;

    fn assessment(delta: f64) -> TransitionAssessment {
        TransitionAssessment {
            r_from: 8,
            r_to: 4,
            delta_a_fro: delta,
            delta_a_spec: delta,
            output_bound: delta,
        }
    }

    #[test]
    fn epsilon_anneals_monotonically() {
        let mut tr = TrustRegion::new(1.0, 0.01);
        let mut last = f64::INFINITY;
        for _ in 0..100 {
            let e = tr.epsilon();
            assert!(e <= last);
            last = e;
            tr.tick();
        }
        assert!(tr.epsilon() < 1.0);
    }

    #[test]
    fn epsilon_floor_holds() {
        let mut tr = TrustRegion::new(0.5, 10.0);
        for _ in 0..10 {
            tr.tick();
        }
        assert!(tr.epsilon() >= tr.epsilon_min);
    }

    #[test]
    fn admits_small_rejects_large() {
        let mut tr = TrustRegion::new(0.1, 0.0);
        assert!(tr.check(&assessment(0.05)));
        assert!(!tr.check(&assessment(0.5)));
        assert_eq!(tr.acceptances, 1);
        assert_eq!(tr.rejections, 1);
        assert!((tr.rejection_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn staying_put_always_admissible() {
        let tr = TrustRegion::new(1e-9, 0.0); // essentially everything rejected
        let s = [5.0, 3.0, 1.0, 0.5];
        let assessments: Vec<_> =
            (1..=4).map(|r| assess_transition(&s, 2, r, 1.0)).collect();
        let mask = tr.mask_actions(2, &assessments);
        // r_to == 2 (index 1) must be admissible even with tiny ε.
        assert!(mask[1]);
        // A large move must be rejected.
        assert!(!mask[3]);
    }

    #[test]
    fn tightening_increases_rejections() {
        let s: Vec<f64> = (0..32).map(|i| 2.0 * (0.85f64).powi(i)).collect();
        let early = TrustRegion::new(1.0, 0.0);
        let mut late = TrustRegion::new(1.0, 0.05);
        for _ in 0..200 {
            late.tick();
        }
        let assessments: Vec<_> =
            (1..=32).map(|r| assess_transition(&s, 16, r, 1.0)).collect();
        let n_early = early.mask_actions(16, &assessments).iter().filter(|&&b| b).count();
        let n_late = late.mask_actions(16, &assessments).iter().filter(|&&b| b).count();
        assert!(n_late < n_early, "late {n_late} !< early {n_early}");
    }
}
