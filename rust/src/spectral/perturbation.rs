//! Online matrix-perturbation bounds (paper §3.3 and §4.2).
//!
//! These quantify the effect of a rank transition r → r' without
//! reconstructing attention:
//!   * Eq. 4  — exact: ‖A_{r'} − A_r‖_F = sqrt(Σ_{k∈(r,r']} σ_k²)
//!   * Eq. 5  — output: ‖Y_{r'} − Y_r‖_F ≤ σ_{r+1}·‖V‖_F
//!   * Eq. 9  — pre-softmax score bound from Q/K residual spectral norms
//!   * Eq. 10 — ‖O_{r'} − O_r‖_F ≤ ‖ΔA‖₂·‖V‖_F
//! The safety check (§4.3.1) compares these to the annealed trust-region
//! threshold in `trust_region.rs`.

use crate::linalg::{spectral_norm_fast, Mat, Svd};

/// Exact attention-matrix perturbation for a rank move r → r' given the
/// singular spectrum (Eq. 4). Symmetric in direction: moving *down* from
/// r' to r reintroduces the same band.
pub fn rank_transition_perturbation(singular_values: &[f64], r_from: usize, r_to: usize) -> f64 {
    let (lo, hi) = if r_from <= r_to { (r_from, r_to) } else { (r_to, r_from) };
    singular_values[lo.min(singular_values.len())..hi.min(singular_values.len())]
        .iter()
        .map(|s| s * s)
        .sum::<f64>()
        .sqrt()
}

/// Relative form of Eq. 4: band energy over total spectral energy,
/// in [0, 1]. Scale-free — a dense and a sparse attention matrix with the
/// same *fractional* energy move get the same score. The trust region
/// uses this form so ε means "fraction of spectral energy at stake"
/// (DESIGN.md §9; the paper's absolute bound makes ε scale-dependent).
pub fn relative_transition_perturbation(
    singular_values: &[f64],
    r_from: usize,
    r_to: usize,
) -> f64 {
    let total: f64 = singular_values.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let band = rank_transition_perturbation(singular_values, r_from, r_to);
    (band * band / total).sqrt()
}

/// Output-sensitivity bound ‖Y_{r'} − Y_r‖_F ≤ σ_{r+1}‖V‖_F (Eq. 5).
pub fn output_bound(singular_values: &[f64], r: usize, v_fro: f64) -> f64 {
    singular_values.get(r).copied().unwrap_or(0.0) * v_fro
}

/// Score-space bound from factored Q/K (Eq. 9):
/// ‖ΔA‖_F ⪅ (‖ΔQ‖₂‖K‖₂ + ‖Q‖₂‖ΔK‖₂)/√d
/// where ΔQ/ΔK are the rank-truncation residuals of Q and K.
pub fn qk_residual_bound(
    dq_spec: f64,
    k_spec: f64,
    q_spec: f64,
    dk_spec: f64,
    head_dim: usize,
) -> f64 {
    (dq_spec * k_spec + q_spec * dk_spec) / (head_dim as f64).sqrt()
}

/// Convenience: compute Eq. 9 directly from Q, K and their rank-r SVDs
/// using power-iteration spectral norms (Eq. 16; K=3 as in the paper).
pub fn qk_bound_from_mats(q: &Mat, k: &Mat, q_svd: &Svd, k_svd: &Svd, r: usize, seed: u64) -> f64 {
    let mut dq = q.clone();
    dq.sub_inplace(&q_svd.reconstruct(r));
    let mut dk = k.clone();
    dk.sub_inplace(&k_svd.reconstruct(r));
    qk_residual_bound(
        spectral_norm_fast(&dq, seed),
        spectral_norm_fast(k, seed ^ 1),
        spectral_norm_fast(q, seed ^ 2),
        spectral_norm_fast(&dk, seed ^ 3),
        q.cols(),
    )
}

/// Final-output bound ‖O_{r'} − O_r‖_F ≤ ‖ΔA‖₂‖V‖_F (Eq. 10). With the
/// exact spectrum available ‖ΔA‖₂ = σ_{min(r,r')+1}.
pub fn final_output_bound(delta_a_spec: f64, v_fro: f64) -> f64 {
    delta_a_spec * v_fro
}

/// Everything the agent needs to score one candidate transition.
#[derive(Debug, Clone, Copy)]
pub struct TransitionAssessment {
    pub r_from: usize,
    pub r_to: usize,
    /// Exact ‖ΔA‖_F from Eq. 4.
    pub delta_a_fro: f64,
    /// ‖ΔA‖₂ (leading band singular value).
    pub delta_a_spec: f64,
    /// Bound on output change (Eq. 10).
    pub output_bound: f64,
}

/// Assess a transition from the attention spectrum + ‖V‖_F. The
/// `delta_a_fro` field carries the *relative* perturbation (what the
/// trust region thresholds); `delta_a_spec`/`output_bound` stay absolute.
pub fn assess_transition(
    singular_values: &[f64],
    r_from: usize,
    r_to: usize,
    v_fro: f64,
) -> TransitionAssessment {
    let delta_a_fro = relative_transition_perturbation(singular_values, r_from, r_to);
    let lead = r_from.min(r_to);
    let delta_a_spec = singular_values.get(lead).copied().unwrap_or(0.0);
    TransitionAssessment {
        r_from,
        r_to,
        delta_a_fro,
        delta_a_spec,
        output_bound: final_output_bound(delta_a_spec, v_fro),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{svd, top_k_svd};
    use crate::util::Pcg32;

    #[test]
    fn eq4_matches_explicit_reconstruction() {
        let mut rng = Pcg32::seeded(1);
        let a = Mat::randn(20, 20, 1.0, &mut rng);
        let d = svd(&a);
        for &(r, r2) in &[(2usize, 7usize), (5, 12), (0, 20)] {
            let explicit = (&d.reconstruct(r2) - &d.reconstruct(r)).fro_norm();
            let bound = rank_transition_perturbation(&d.s, r, r2);
            assert!((explicit - bound).abs() < 1e-8, "({r},{r2}): {explicit} vs {bound}");
        }
    }

    #[test]
    fn direction_symmetry() {
        let s = [5.0, 3.0, 2.0, 1.0, 0.5];
        assert_eq!(
            rank_transition_perturbation(&s, 1, 4),
            rank_transition_perturbation(&s, 4, 1)
        );
    }

    #[test]
    fn eq5_bounds_actual_output_change() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(16, 16, 0.5, &mut rng);
        let v = Mat::randn(16, 8, 1.0, &mut rng);
        let d = svd(&a);
        for r in [2usize, 6, 10] {
            let r2 = r + 3;
            let y_r = crate::linalg::matmul(&d.reconstruct(r), &v);
            let y_r2 = crate::linalg::matmul(&d.reconstruct(r2), &v);
            let actual = (&y_r2 - &y_r).fro_norm();
            let bound = output_bound(&d.s, r, v.fro_norm());
            assert!(actual <= bound + 1e-9, "r={r}: {actual} > {bound}");
        }
    }

    #[test]
    fn eq9_is_an_upper_envelope_of_score_change() {
        // ΔQKᵀ norm must be below the triangle-inequality bound.
        let mut rng = Pcg32::seeded(3);
        let q = Mat::randn(24, 8, 1.0, &mut rng);
        let k = Mat::randn(24, 8, 1.0, &mut rng);
        let r = 3;
        let qd = top_k_svd(&q, r, 7);
        let kd = top_k_svd(&k, r, 8);
        let bound = qk_bound_from_mats(&q, &k, &qd, &kd, r, 11);
        // Actual ‖(Q_r K_rᵀ − QKᵀ)/√d‖₂ — use many power iterations for truth.
        let qr = qd.reconstruct(r);
        let kr = kd.reconstruct(r);
        let mut delta = crate::linalg::matmul_bt(&qr, &kr);
        delta.sub_inplace(&crate::linalg::matmul_bt(&q, &k));
        delta.scale_inplace(1.0 / (8.0f64).sqrt());
        let actual = crate::linalg::spectral_norm(&delta, 30, 5);
        // Power-iteration estimates converge from below; allow 1% slack.
        assert!(actual <= bound * 1.01 + 1e-9, "{actual} > {bound}");
    }

    #[test]
    fn eq10_bounds_final_output() {
        let mut rng = Pcg32::seeded(4);
        let a = Mat::randn(12, 12, 0.8, &mut rng);
        let v = Mat::randn(12, 6, 1.0, &mut rng);
        let d = svd(&a);
        let (r, r2) = (3usize, 8usize);
        let o_r = crate::linalg::matmul(&d.reconstruct(r), &v);
        let o_r2 = crate::linalg::matmul(&d.reconstruct(r2), &v);
        let actual = (&o_r2 - &o_r).fro_norm();
        let assess = assess_transition(&d.s, r, r2, v.fro_norm());
        assert!(actual <= assess.output_bound + 1e-9);
        // And the Frobenius version is even tighter than spec × fro:
        assert!(assess.delta_a_fro <= d.tail_energy(r) + 1e-9);
    }

    #[test]
    fn no_transition_no_perturbation() {
        let s = [4.0, 2.0, 1.0];
        assert_eq!(rank_transition_perturbation(&s, 2, 2), 0.0);
        let a = assess_transition(&s, 2, 2, 10.0);
        assert_eq!(a.delta_a_fro, 0.0);
    }

    #[test]
    fn out_of_range_ranks_are_safe() {
        let s = [4.0, 2.0];
        // Transition beyond spectrum length clamps gracefully.
        assert_eq!(rank_transition_perturbation(&s, 2, 10), 0.0);
        assert_eq!(output_bound(&s, 5, 3.0), 0.0);
    }
}
