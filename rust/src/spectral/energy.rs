//! Spectral-energy statistics of attention matrices.
//!
//! The Normalized Energy Ratio (paper Eq. 14) is both a state feature for
//! the policy and the decision rule of the Adaptive-SVD baseline.

/// Normalized Energy Ratio: fraction of squared spectral mass retained by
/// the top-r singular values (Eq. 14).
pub fn ner(singular_values: &[f64], r: usize) -> f64 {
    let total: f64 = singular_values.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 1.0; // zero matrix: any rank retains "everything"
    }
    let head: f64 = singular_values.iter().take(r).map(|s| s * s).sum();
    (head / total).clamp(0.0, 1.0)
}

/// Smallest rank whose NER reaches `threshold` (Adaptive-SVD rule).
pub fn rank_for_energy(singular_values: &[f64], threshold: f64) -> usize {
    let total: f64 = singular_values.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 1;
    }
    let mut acc = 0.0;
    for (i, s) in singular_values.iter().enumerate() {
        acc += s * s;
        if acc / total >= threshold {
            return i + 1;
        }
    }
    singular_values.len()
}

/// Soft-thresholding rank rule (SoftLMs, arXiv:2411.10543): keep every
/// singular value whose soft-thresholded magnitude `σ_i − τ·σ_0` stays
/// positive, where σ_0 is the spectral norm. A relative threshold makes
/// the rule scale-invariant: `τ = 0` keeps the full numerical rank,
/// `τ → 1` collapses to rank 1. Always returns at least 1 so downstream
/// low-rank kernels get a usable rank.
pub fn soft_threshold_rank(singular_values: &[f64], tau: f64) -> usize {
    let sigma0 = singular_values.first().copied().unwrap_or(0.0);
    if sigma0 <= 0.0 {
        return 1;
    }
    let cut = tau * sigma0;
    singular_values.iter().filter(|&&s| s - cut > 0.0).count().max(1)
}

/// Spectral-decay summary features fed into the RL state: NER at a few
/// probe ranks, the decay exponent estimate, and entropy of the σ² mass.
pub fn spectrum_features(singular_values: &[f64], probes: &[usize]) -> Vec<f64> {
    let mut out: Vec<f64> = probes.iter().map(|&r| ner(singular_values, r)).collect();
    out.push(decay_exponent(singular_values));
    out.push(spectral_entropy(singular_values));
    out
}

/// Least-squares slope of log σ_i vs log i — a one-number summary of how
/// compressible the matrix is (steeper decay → lower usable rank).
pub fn decay_exponent(singular_values: &[f64]) -> f64 {
    let pts: Vec<(f64, f64)> = singular_values
        .iter()
        .enumerate()
        .filter(|(_, &s)| s > 1e-12)
        .map(|(i, &s)| (((i + 1) as f64).ln(), s.ln()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        0.0
    } else {
        (n * sxy - sx * sy) / denom
    }
}

/// Shannon entropy of the normalized σ² distribution; high entropy ⇒ flat
/// spectrum ⇒ high intrinsic rank.
pub fn spectral_entropy(singular_values: &[f64]) -> f64 {
    let total: f64 = singular_values.iter().map(|s| s * s).sum();
    if total <= 0.0 {
        return 0.0;
    }
    -singular_values
        .iter()
        .map(|s| s * s / total)
        .filter(|&p| p > 1e-15)
        .map(|p| p * p.ln())
        .sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ner_monotone_in_rank() {
        let s = [4.0, 2.0, 1.0, 0.5];
        let mut last = 0.0;
        for r in 0..=4 {
            let e = ner(&s, r);
            assert!(e >= last);
            last = e;
        }
        assert!((ner(&s, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ner_values_exact() {
        let s = [3.0, 4.0]; // squared: 9, 16, total 25 (unsorted on purpose)
        assert!((ner(&s, 1) - 9.0 / 25.0).abs() < 1e-12);
    }

    #[test]
    fn rank_for_energy_thresholds() {
        let s = [10.0, 1.0, 0.1, 0.01];
        assert_eq!(rank_for_energy(&s, 0.90), 1);
        assert_eq!(rank_for_energy(&s, 0.999), 2);
        assert_eq!(rank_for_energy(&s, 1.0), 4);
    }

    #[test]
    fn decay_exponent_sign() {
        // Geometric decay → strongly negative slope.
        let s: Vec<f64> = (0..16).map(|i| (0.5f64).powi(i)).collect();
        assert!(decay_exponent(&s) < -1.0);
        // Flat spectrum → slope ~0.
        let flat = vec![1.0; 16];
        assert!(decay_exponent(&flat).abs() < 1e-9);
    }

    #[test]
    fn entropy_extremes() {
        let peaked = [1.0, 0.0, 0.0, 0.0];
        assert!(spectral_entropy(&peaked).abs() < 1e-12);
        let flat = [1.0, 1.0, 1.0, 1.0];
        assert!((spectral_entropy(&flat) - (4.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn zero_spectrum_defaults() {
        assert_eq!(ner(&[], 3), 1.0);
        assert_eq!(rank_for_energy(&[0.0, 0.0], 0.9), 1);
        assert_eq!(spectral_entropy(&[]), 0.0);
    }

    #[test]
    fn soft_threshold_counts_surviving_sigmas() {
        let s = [10.0, 6.0, 3.0, 0.5];
        // cut = 0.5·10 = 5 → σ ∈ {10, 6} survive.
        assert_eq!(soft_threshold_rank(&s, 0.5), 2);
        // τ = 0 keeps everything above zero.
        assert_eq!(soft_threshold_rank(&s, 0.0), 4);
        // τ ≥ 1 collapses to the floor of 1 (σ_0 − σ_0 is not > 0).
        assert_eq!(soft_threshold_rank(&s, 1.0), 1);
    }

    #[test]
    fn soft_threshold_monotone_in_tau() {
        let s: Vec<f64> = (0..32).map(|i| (0.85f64).powi(i)).collect();
        let mut last = usize::MAX;
        for i in 0..=10 {
            let r = soft_threshold_rank(&s, i as f64 / 10.0);
            assert!(r <= last, "rank must shrink as τ grows");
            assert!(r >= 1);
            last = r;
        }
    }

    #[test]
    fn soft_threshold_zero_spectrum_floor() {
        assert_eq!(soft_threshold_rank(&[], 0.3), 1);
        assert_eq!(soft_threshold_rank(&[0.0, 0.0], 0.3), 1);
    }

    #[test]
    fn features_vector_shape() {
        let s: Vec<f64> = (0..32).map(|i| (0.8f64).powi(i)).collect();
        let f = spectrum_features(&s, &[4, 8, 16]);
        assert_eq!(f.len(), 5);
    }
}
