//! Lightweight local type map: enough typing to resolve method calls
//! through non-`self` receivers, without a real type system.
//!
//! The PR 9 call graph resolves `helper(..)` and `self.helper(..)` by
//! name and stops dead at any other receiver — `other.helper()`,
//! `self.field.method()`, `param.dispatch(f)` — so lock-set and taint
//! propagation silently ends there. This module harvests four kinds of
//! purely local, annotation-level type facts from the token stream:
//!
//! * **struct fields** — `struct S { field: Arc<T>, … }` records
//!   `S.field : T` (deref wrappers `Arc`/`Rc`/`Box` are unwrapped,
//!   because method calls auto-deref through them);
//! * **impl membership** — every fn whose body sits directly inside
//!   `impl T { … }` / `impl Trait for T { … }` belongs to `T`, which
//!   both types `self` and populates the crate-wide method table;
//! * **fn params** — `fn f(other: &Helper)` types `other` inside `f`;
//! * **typed lets** — `let x: T = …`, `let x = T::new(…)`,
//!   `let x = T { … }` type `x` from its binding site forward (the
//!   nearest preceding binding wins, so shadowing re-types).
//!
//! What deliberately stays untyped: method-call initializers
//! (`let g = mu.lock_unpoisoned()` — guard types need generics),
//! `dyn`/`impl Trait`, closures, collection elements (`xs[i].m()` drops
//! the index, so a `Vec<T>` receiver resolves to `Vec`, which no crate
//! impl claims), and `Self::…` paths. An unresolved receiver produces
//! *no* edge — exactly the pre-type-map behavior — so the map can only
//! add recall, never change the meaning of an existing edge.

use std::collections::BTreeMap;

use super::callgraph::FnId;
use super::lexer::{Lexed, TokKind};
use super::model::FileModel;

/// Containers that auto-deref method calls to their payload type.
const DEREF_WRAPPERS: [&str; 3] = ["Arc", "Rc", "Box"];

/// One `let`-bound variable with a recovered type.
#[derive(Debug, Clone)]
pub struct LetBind {
    pub name: String,
    /// Head type name (path tail, wrappers unwrapped).
    pub ty: String,
    /// Token index of the bound name (scoping: the binding types uses
    /// *after* this token).
    pub tok: usize,
}

/// Per-file type facts harvested from one [`FileModel`].
#[derive(Debug, Default)]
pub struct FileTypes {
    /// struct name → field name → field head type.
    pub fields: BTreeMap<String, BTreeMap<String, String>>,
    /// fn index (into [`FileModel::fns`]) → self type of its `impl`.
    pub impl_of: BTreeMap<usize, String>,
    /// fn index → param name → param head type.
    pub params: BTreeMap<usize, BTreeMap<String, String>>,
    /// Typed `let` bindings in token order.
    pub lets: Vec<LetBind>,
}

impl FileTypes {
    pub fn build(m: &FileModel) -> FileTypes {
        let lx = &m.lexed;
        let mut ft = FileTypes::default();
        harvest_structs(lx, m, &mut ft);
        harvest_impls(lx, m, &mut ft);
        harvest_params(lx, m, &mut ft);
        harvest_lets(lx, &mut ft);
        ft
    }

    /// Type of variable `name` as seen at token `pos` inside fn `fi`:
    /// the nearest preceding typed `let` in that fn's body wins, else
    /// the fn's param annotation.
    pub fn var_type(&self, m: &FileModel, fi: usize, name: &str, pos: usize) -> Option<&str> {
        let f = &m.fns[fi];
        let mut best: Option<&LetBind> = None;
        for l in &self.lets {
            if l.name == name && l.tok > f.open && l.tok < f.close && l.tok < pos {
                best = Some(l);
            }
        }
        if let Some(l) = best {
            return Some(&l.ty);
        }
        self.params.get(&fi)?.get(name).map(String::as_str)
    }
}

/// Crate-wide method and field tables, merged across files.
pub struct TypeMap {
    /// type name → method name → every non-test fn defined in an
    /// `impl` block for that type (several same-named impls merge, the
    /// same over-approximation name resolution makes for free fns).
    pub methods: BTreeMap<String, BTreeMap<String, Vec<FnId>>>,
    /// struct name → field name → field head type.
    pub fields: BTreeMap<String, BTreeMap<String, String>>,
}

impl TypeMap {
    pub fn build(models: &[&FileModel], types: &[FileTypes]) -> TypeMap {
        let mut methods: BTreeMap<String, BTreeMap<String, Vec<FnId>>> = BTreeMap::new();
        let mut fields: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
        for (mi, ft) in types.iter().enumerate() {
            for (&k, ty) in &ft.impl_of {
                let f = &models[mi].fns[k];
                if !f.is_test {
                    methods
                        .entry(ty.clone())
                        .or_default()
                        .entry(f.name.clone())
                        .or_default()
                        .push((mi, k));
                }
            }
            for (sname, fs) in &ft.fields {
                let merged = fields.entry(sname.clone()).or_default();
                for (fname, fty) in fs {
                    merged.entry(fname.clone()).or_insert_with(|| fty.clone());
                }
            }
        }
        TypeMap { methods, fields }
    }

    /// The fns named `callee` in any `impl` block for type `ty`.
    pub fn method_targets(&self, ty: &str, callee: &str) -> Option<&Vec<FnId>> {
        self.methods.get(ty)?.get(callee)
    }
}

/// Resolve a method call's receiver chain to a type name: the head is
/// `self` (the enclosing impl's type), a typed local or a typed param;
/// each later segment is a struct field looked up crate-wide. `None`
/// whenever any link is untyped — the caller must then produce no edge.
pub fn resolve_receiver(
    tm: &TypeMap,
    ft: &FileTypes,
    m: &FileModel,
    fi: usize,
    path: &[String],
    pos: usize,
) -> Option<String> {
    let mut it = path.iter();
    let head = it.next()?;
    let mut ty: String = if head == "self" {
        ft.impl_of.get(&fi)?.clone()
    } else {
        ft.var_type(m, fi, head, pos)?.to_string()
    };
    for seg in it {
        ty = tm.fields.get(&ty)?.get(seg.as_str())?.clone();
    }
    Some(ty)
}

/// Token index of the `>` matching the `<` at `open`. `->` arrows are
/// skipped (their `>` is preceded by `-`); nested `>>` closes two
/// levels one punct at a time, which is exactly right.
fn matching_angle(lx: &Lexed, open: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut j = open;
    while j < lx.tokens.len() {
        if lx.punct(j, '<') {
            depth += 1;
        } else if lx.punct(j, '>') && !(j >= 1 && lx.punct(j - 1, '-')) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

/// Token index of the `close` matching the `open` bracket at `at`.
fn matching(lx: &Lexed, open: char, close: char, at: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut j = at;
    while j < lx.tokens.len() {
        if lx.punct(j, open) {
            depth += 1;
        } else if lx.punct(j, close) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

/// Head type name of the type tokens in `lo..hi`: strip `&`, lifetimes
/// and `mut`, follow the path to its final segment, and unwrap deref
/// containers. `dyn`/`impl` types and non-path types yield `None`.
fn type_head(lx: &Lexed, lo: usize, hi: usize) -> Option<String> {
    let mut j = lo;
    while j < hi
        && (lx.punct(j, '&') || lx.tokens[j].kind == TokKind::Lifetime || lx.ident(j) == Some("mut"))
    {
        j += 1;
    }
    if j >= hi {
        return None;
    }
    if matches!(lx.ident(j), Some("dyn") | Some("impl")) {
        return None;
    }
    // Follow the path `a::b::C` to its final segment.
    let mut last: Option<&str> = None;
    while j < hi {
        if lx.punct(j, ':') {
            j += 1; // leading `::`
            continue;
        }
        match lx.ident(j) {
            Some(name) => {
                last = Some(name);
                j += 1;
                if j + 1 < hi && lx.punct(j, ':') && lx.punct(j + 1, ':') {
                    j += 2;
                    continue;
                }
                break;
            }
            None => break,
        }
    }
    let head = last?;
    if DEREF_WRAPPERS.contains(&head) && j < hi && lx.punct(j, '<') {
        let close = matching_angle(lx, j)?;
        return type_head(lx, j + 1, close.min(hi));
    }
    if head.starts_with(|c: char| c.is_ascii_uppercase()) {
        Some(head.to_string())
    } else {
        None
    }
}

/// `struct S { field: Type, … }` → `S.field : head(Type)`. Unit and
/// tuple structs carry no named fields and are skipped.
fn harvest_structs(lx: &Lexed, m: &FileModel, ft: &mut FileTypes) {
    let n = lx.tokens.len();
    for i in 0..n {
        if lx.ident(i) != Some("struct") {
            continue;
        }
        let Some(name) = lx.ident(i + 1) else { continue };
        let mut j = i + 2;
        if lx.punct(j, '<') {
            match matching_angle(lx, j) {
                Some(c) => j = c + 1,
                None => continue,
            }
        }
        // Skip a possible `where` clause between generics and the body.
        while j < n && !lx.punct(j, '{') && !lx.punct(j, ';') && !lx.punct(j, '(') {
            j += 1;
        }
        if j >= n || !lx.punct(j, '{') {
            continue;
        }
        let Some(close) = m.close_of[j] else { continue };
        let fields = ft.fields.entry(name.to_string()).or_default();
        let mut k = j + 1;
        while k < close {
            // Skip field attributes and visibility.
            if lx.punct(k, '#') && lx.punct(k + 1, '[') {
                match matching(lx, '[', ']', k + 1) {
                    Some(c) => k = c + 1,
                    None => break,
                }
                continue;
            }
            if lx.ident(k) == Some("pub") {
                k += 1;
                if lx.punct(k, '(') {
                    match matching(lx, '(', ')', k) {
                        Some(c) => k = c + 1,
                        None => break,
                    }
                }
                continue;
            }
            let fname = match lx.ident(k) {
                Some(f) if lx.punct(k + 1, ':') && !lx.punct(k + 2, ':') => f,
                _ => {
                    k += 1;
                    continue;
                }
            };
            // Field type runs to the next top-level comma or the `}`.
            let lo = k + 2;
            let mut depth = 0i64;
            let mut hi = lo;
            while hi < close {
                if lx.punct(hi, '<') || lx.punct(hi, '(') || lx.punct(hi, '[') || lx.punct(hi, '{')
                {
                    depth += 1;
                } else if lx.punct(hi, ')') || lx.punct(hi, ']') || lx.punct(hi, '}') {
                    depth -= 1;
                } else if lx.punct(hi, '>') && !lx.punct(hi - 1, '-') {
                    depth -= 1;
                } else if depth == 0 && lx.punct(hi, ',') {
                    break;
                }
                hi += 1;
            }
            if let Some(ty) = type_head(lx, lo, hi) {
                fields.insert(fname.to_string(), ty);
            }
            k = hi + 1;
        }
    }
}

/// `impl T { … }` / `impl Trait for T { … }` → every fn whose body sits
/// directly inside the impl braces belongs to `T`.
fn harvest_impls(lx: &Lexed, m: &FileModel, ft: &mut FileTypes) {
    let n = lx.tokens.len();
    for i in 0..n {
        if lx.ident(i) != Some("impl") {
            continue;
        }
        let mut j = i + 1;
        if lx.punct(j, '<') {
            match matching_angle(lx, j) {
                Some(c) => j = c + 1,
                None => continue,
            }
        }
        // The self type is the last angle-depth-0 path segment before
        // the body; a `for` resets (everything before it was the trait).
        let mut target: Option<&str> = None;
        let mut depth = 0i64;
        let mut open = None;
        while j < n {
            if lx.punct(j, '<') {
                depth += 1;
            } else if lx.punct(j, '>') && !(j >= 1 && lx.punct(j - 1, '-')) {
                depth -= 1;
            } else if depth == 0 {
                if lx.punct(j, '{') {
                    open = Some(j);
                    break;
                }
                match lx.ident(j) {
                    Some("for") => target = None,
                    Some("where") => {
                        // Self type is fixed by now; skip to the body.
                        while j < n && !lx.punct(j, '{') {
                            j += 1;
                        }
                        continue;
                    }
                    Some(name) => target = Some(name),
                    None => {}
                }
            }
            j += 1;
        }
        let (Some(target), Some(open)) = (target, open) else { continue };
        let Some(close) = m.close_of[open] else { continue };
        for (k, f) in m.fns.iter().enumerate() {
            if f.open > open && f.close < close && m.enclosing_open[f.open] == Some(open) {
                ft.impl_of.insert(k, target.to_string());
            }
        }
    }
}

/// `fn f(other: &Helper, mut n: usize)` → `other : Helper` inside `f`.
/// `self` receivers and destructuring patterns are skipped.
fn harvest_params(lx: &Lexed, m: &FileModel, ft: &mut FileTypes) {
    for (k, f) in m.fns.iter().enumerate() {
        // Param list: the `(` after the fn name (generics may intervene).
        let mut j = f.sig + 2;
        if lx.punct(j, '<') {
            match matching_angle(lx, j) {
                Some(c) => j = c + 1,
                None => continue,
            }
        }
        if !lx.punct(j, '(') {
            continue;
        }
        let Some(close) = matching(lx, '(', ')', j) else { continue };
        let mut params: BTreeMap<String, String> = BTreeMap::new();
        // Split on top-level commas; angles count as depth so the comma
        // in `Vec<(A, B)>` does not split.
        let mut lo = j + 1;
        let mut depth = 0i64;
        let mut at = j + 1;
        while at <= close {
            if at == close || (depth == 0 && lx.punct(at, ',')) {
                param_entry(lx, lo, at, &mut params);
                lo = at + 1;
            } else if lx.punct(at, '<') || lx.punct(at, '(') || lx.punct(at, '[') {
                depth += 1;
            } else if lx.punct(at, ')') || lx.punct(at, ']') {
                depth -= 1;
            } else if lx.punct(at, '>') && !lx.punct(at - 1, '-') {
                depth -= 1;
            }
            at += 1;
        }
        if !params.is_empty() {
            ft.params.insert(k, params);
        }
    }
}

/// One `name: Type` param element (skips `self`, patterns, `mut`).
fn param_entry(lx: &Lexed, lo: usize, hi: usize, out: &mut BTreeMap<String, String>) {
    let mut j = lo;
    if lx.ident(j) == Some("mut") {
        j += 1;
    }
    let Some(name) = lx.ident(j) else { return };
    if name == "self" || !lx.punct(j + 1, ':') || lx.punct(j + 2, ':') {
        return;
    }
    if let Some(ty) = type_head(lx, j + 2, hi) {
        out.insert(name.to_string(), ty);
    }
}

/// Typed `let` bindings: explicit ascription, `Type::ctor(..)`,
/// `Type { .. }` and `Type(..)` initializers.
fn harvest_lets(lx: &Lexed, ft: &mut FileTypes) {
    let n = lx.tokens.len();
    for i in 0..n {
        if lx.ident(i) != Some("let") {
            continue;
        }
        let mut j = i + 1;
        if lx.ident(j) == Some("mut") {
            j += 1;
        }
        let Some(name) = lx.ident(j) else { continue };
        let name_tok = j;
        let ty = if lx.punct(j + 1, ':') && !lx.punct(j + 2, ':') {
            // `let x: Type = …` — the annotation runs to `=` or `;`.
            let lo = j + 2;
            let mut hi = lo;
            let mut depth = 0i64;
            while hi < n {
                if lx.punct(hi, '<') || lx.punct(hi, '(') || lx.punct(hi, '[') {
                    depth += 1;
                } else if lx.punct(hi, ')') || lx.punct(hi, ']') {
                    depth -= 1;
                } else if lx.punct(hi, '>') && !lx.punct(hi - 1, '-') {
                    depth -= 1;
                } else if depth == 0 && (lx.punct(hi, '=') || lx.punct(hi, ';')) {
                    break;
                }
                hi += 1;
            }
            type_head(lx, lo, hi)
        } else if lx.punct(j + 1, '=') {
            init_type(lx, j + 2)
        } else {
            None
        };
        if let Some(ty) = ty {
            ft.lets.push(LetBind { name: name.to_string(), ty, tok: name_tok });
        }
    }
}

/// Type of a constructor-shaped `let` initializer: `Type::ctor(..)`
/// (last uppercase-initial segment before the fn), `Type { .. }` and
/// `Type(..)`. Anything else — method calls, field reads, literals —
/// yields `None`: the binding stays untyped rather than guessed.
fn init_type(lx: &Lexed, lo: usize) -> Option<String> {
    let mut segs: Vec<&str> = Vec::new();
    let mut j = lo;
    loop {
        let name = lx.ident(j)?;
        segs.push(name);
        if lx.punct(j + 1, ':') && lx.punct(j + 2, ':') {
            j += 3;
        } else {
            break;
        }
    }
    let last = *segs.last()?;
    let upper = |s: &str| s.starts_with(|c: char| c.is_ascii_uppercase());
    if lx.punct(j + 1, '{') {
        return if upper(last) { Some(last.to_string()) } else { None };
    }
    if !lx.punct(j + 1, '(') {
        return None;
    }
    if upper(last) {
        // `Type(..)` tuple-struct constructor.
        return Some(last.to_string());
    }
    // `Type::ctor(..)`: the last uppercase segment before the fn name.
    segs.iter().rev().skip(1).find(|s| upper(s)).map(|s| s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn built(src: &str) -> (FileModel, FileTypes) {
        let m = FileModel::build(src);
        let ft = FileTypes::build(&m);
        (m, ft)
    }

    #[test]
    fn struct_fields_record_head_types() {
        let (_, ft) = built(concat!(
            "pub struct Shared {\n",
            "    pub metrics: Arc<Metrics>,\n",
            "    #[allow(dead_code)]\n",
            "    pub(crate) pool: util::threadpool::ThreadPool,\n",
            "    count: usize,\n",
            "    hook: Box<dyn Fn() -> usize>,\n",
            "}\n",
        ));
        let f = &ft.fields["Shared"];
        assert_eq!(f.get("metrics").map(String::as_str), Some("Metrics"));
        assert_eq!(f.get("pool").map(String::as_str), Some("ThreadPool"));
        // Lowercase head types and `dyn` are not recorded.
        assert!(!f.contains_key("count"));
        assert!(!f.contains_key("hook"));
    }

    #[test]
    fn impl_membership_types_self_including_trait_impls() {
        let (m, ft) = built(concat!(
            "struct Engine;\n",
            "impl Engine { fn run(&self) {} }\n",
            "impl<T> LockExt<T> for Mutex<T> { fn lock_unpoisoned(&self) {} }\n",
            "fn free() {}\n",
        ));
        let by_name: BTreeMap<&str, usize> =
            m.fns.iter().enumerate().map(|(k, f)| (f.name.as_str(), k)).collect();
        assert_eq!(ft.impl_of.get(&by_name["run"]).map(String::as_str), Some("Engine"));
        assert_eq!(
            ft.impl_of.get(&by_name["lock_unpoisoned"]).map(String::as_str),
            Some("Mutex")
        );
        assert!(!ft.impl_of.contains_key(&by_name["free"]));
    }

    #[test]
    fn params_and_lets_type_variables() {
        let (m, ft) = built(concat!(
            "fn f(other: &Helper, mut n: usize, pair: (A, B)) {\n",
            "    let a: Arc<Ctl> = make();\n",
            "    let b = Helper::new(7);\n",
            "    let c = Config { n: 1 };\n",
            "    let d = some_fn();\n",
            "    let e = mu.lock_unpoisoned();\n",
            "}\n",
        ));
        assert_eq!(ft.var_type(&m, 0, "other", usize::MAX), Some("Helper"));
        // Lowercase param types and destructuring patterns stay untyped.
        assert_eq!(ft.var_type(&m, 0, "n", usize::MAX), None);
        assert_eq!(ft.var_type(&m, 0, "pair", usize::MAX), None);
        assert_eq!(ft.var_type(&m, 0, "a", usize::MAX), Some("Ctl"));
        assert_eq!(ft.var_type(&m, 0, "b", usize::MAX), Some("Helper"));
        assert_eq!(ft.var_type(&m, 0, "c", usize::MAX), Some("Config"));
        assert_eq!(ft.var_type(&m, 0, "d", usize::MAX), None);
        assert_eq!(ft.var_type(&m, 0, "e", usize::MAX), None);
    }

    #[test]
    fn let_shadowing_retypes_from_the_binding_forward() {
        let src = "fn f() { let x = A::new(); use1(); let x = B::new(); use2(); }";
        let (m, ft) = built(src);
        let lx = &m.lexed;
        let use1 = (0..lx.tokens.len()).find(|&i| lx.ident(i) == Some("use1")).unwrap();
        let use2 = (0..lx.tokens.len()).find(|&i| lx.ident(i) == Some("use2")).unwrap();
        assert_eq!(ft.var_type(&m, 0, "x", use1), Some("A"));
        assert_eq!(ft.var_type(&m, 0, "x", use2), Some("B"));
    }

    #[test]
    fn receiver_chains_resolve_through_fields_crate_wide() {
        let a = FileModel::build(concat!(
            "struct Shared { metrics: Arc<Metrics> }\n",
            "fn f(shared: &Shared) { shared.metrics.record(); }\n",
        ));
        let b = FileModel::build("struct Metrics; impl Metrics { fn record(&self) {} }");
        let fts = [FileTypes::build(&a), FileTypes::build(&b)];
        let models = [&a, &b];
        let tm = TypeMap::build(&models, &fts);
        let path = vec!["shared".to_string(), "metrics".to_string()];
        let fi = a.fns.iter().position(|f| f.name == "f").unwrap();
        let ty = resolve_receiver(&tm, &fts[0], &a, fi, &path, usize::MAX);
        assert_eq!(ty.as_deref(), Some("Metrics"));
        let targets = tm.method_targets("Metrics", "record").unwrap();
        assert_eq!(targets.len(), 1);
        assert_eq!(targets[0].0, 1);
    }

    #[test]
    fn self_resolves_through_the_enclosing_impl() {
        let (m, ft) = built(concat!(
            "struct Ctl { inner: Arc<State> }\n",
            "struct State;\n",
            "impl Ctl { fn go(&self) { self.inner.step(); } }\n",
            "impl State { fn step(&self) {} }\n",
        ));
        let models = [&m];
        let fts = [ft];
        let tm = TypeMap::build(&models, &fts);
        let fi = m.fns.iter().position(|f| f.name == "go").unwrap();
        let path = vec!["self".to_string(), "inner".to_string()];
        assert_eq!(
            resolve_receiver(&tm, &fts[0], &m, fi, &path, usize::MAX).as_deref(),
            Some("State")
        );
    }
}
