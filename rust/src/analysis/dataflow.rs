//! Fixed-point fact propagation over the crate call graph.
//!
//! The engine is rule-agnostic: a rule seeds each fn with the *direct*
//! facts its body establishes (locks it acquires, blocking operations
//! it performs, …), and `propagate` folds every fn's facts into its
//! callers along resolved, non-detached call edges until nothing
//! changes. Each propagated fact carries the call chain that reaches
//! its origin, so a diagnostic at a call site can print the complete
//! path (`h1() at file:12 -> h2() at file:40 -> state acquired at
//! file:77`) instead of a bare lock name.
//!
//! `depth` controls how many call hops a fact may travel when it is
//! finally consumed at a call site:
//!
//! * `Some(1)` reproduces the PR 8 analyzer exactly — a call site sees
//!   only the callee's *direct* facts (zero propagation rounds, one
//!   hop at the site). The regression tests use this to prove the
//!   fixed-point engine catches cycles the one-level analyzer missed.
//! * `None` runs to a fixed point (bounded by the node count, the
//!   longest possible acyclic chain), which is what `drrl lint` ships.
//!
//! Facts are keyed: one fn keeps at most one fact per key, and a fact
//! already present never gets replaced. That makes the iteration
//! monotone (it terminates even on recursive call graphs) and keeps
//! the recorded chain the *shortest* one found, since facts arriving
//! in earlier rounds win.
//!
//! Two fact kinds ride this engine today: lock-set / blocking facts
//! (R4, R8 — keyed by lock or blocking ident, run over the full
//! graph) and determinism-taint facts (R13, R14 — keyed by source
//! kind, run over a restricted copy of the graph that keeps only
//! unambiguous call edges out of value-returning fns; see
//! `rules::r13_r14_nondet_taint` for why value taint is stricter than
//! side-effect reachability). The engine itself is identical for both;
//! only the seeding and the consuming graph differ.

use std::collections::BTreeMap;

use super::callgraph::{CallGraph, FnId};

/// One call hop on the path from a fn's body to a fact's origin:
/// `callee` was called at `file`:`line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hop {
    pub callee: String,
    /// File index (into the model slice the graph was built from).
    pub file: usize,
    pub line: usize,
}

/// A dataflow fact attributed to a fn: directly seeded, or reached
/// through `chain` (outermost call first).
#[derive(Debug, Clone)]
pub struct Fact {
    /// Stable identity (e.g. the lock name, the blocking ident). One
    /// fact per key per fn.
    pub key: String,
    /// File index of the originating site.
    pub file: usize,
    /// Source line of the originating site.
    pub line: usize,
    /// Call chain from the owning fn's body to the origin; empty for
    /// direct facts.
    pub chain: Vec<Hop>,
}

/// Per-fn fact sets, keyed for monotone merging.
pub type FactMap = BTreeMap<FnId, BTreeMap<String, Fact>>;

/// Seed `facts` with a direct fact of `fn_id` (first key wins).
pub fn seed(facts: &mut FactMap, fn_id: FnId, key: &str, file: usize, line: usize) {
    facts.entry(fn_id).or_default().entry(key.to_string()).or_insert(Fact {
        key: key.to_string(),
        file,
        line,
        chain: Vec::new(),
    });
}

/// Propagate facts up the call graph. See the module docs for the
/// `depth` contract (`Some(1)` = legacy one-level, `None` = fixed
/// point).
pub fn propagate(graph: &CallGraph, seeds: &FactMap, depth: Option<usize>) -> FactMap {
    let rounds = match depth {
        // One hop happens at the consuming call site; `depth - 1`
        // rounds happen here.
        Some(d) => d.saturating_sub(1),
        // An acyclic chain visits each fn at most once.
        None => graph.nodes.len().saturating_add(1),
    };
    let mut facts = seeds.clone();
    for _ in 0..rounds {
        let prev = facts.clone();
        let mut changed = false;
        for calls in graph.calls_from.values() {
            for rc in calls {
                if rc.detached {
                    continue;
                }
                let Some(callee_facts) = prev.get(&rc.callee) else { continue };
                for f in callee_facts.values() {
                    let entry = facts.entry(rc.caller).or_default();
                    if entry.contains_key(&f.key) {
                        continue;
                    }
                    let mut chain = Vec::with_capacity(f.chain.len() + 1);
                    chain.push(Hop {
                        callee: rc.callee_name.clone(),
                        file: rc.caller.0,
                        line: rc.line,
                    });
                    chain.extend(f.chain.iter().cloned());
                    entry.insert(
                        f.key.clone(),
                        Fact { key: f.key.clone(), file: f.file, line: f.line, chain },
                    );
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    facts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::FileModel;

    /// caller -> h1 -> h2 -> h3 (h3 acquires the lock).
    fn three_deep() -> Vec<FileModel> {
        vec![FileModel::build(concat!(
            "fn caller() { h1(); }\n",
            "fn h1() { h2(); }\n",
            "fn h2() { h3(); }\n",
            "fn h3() { let g = state.lock_unpoisoned(); }\n",
        ))]
    }

    fn seeds_of(ms: &[FileModel]) -> (CallGraph, FactMap) {
        let refs: Vec<&FileModel> = ms.iter().collect();
        let g = CallGraph::build(&refs);
        let mut s: FactMap = FactMap::new();
        for (mi, m) in ms.iter().enumerate() {
            for l in &m.locks {
                if l.detached || m.in_test(l.tok) {
                    continue;
                }
                if let Some(k) = m
                    .fns
                    .iter()
                    .enumerate()
                    .filter(|(_, f)| f.open < l.tok && l.tok < f.close)
                    .min_by_key(|(_, f)| f.close - f.open)
                    .map(|(k, _)| k)
                {
                    seed(&mut s, (mi, k), &l.name, mi, l.line);
                }
            }
        }
        (g, s)
    }

    #[test]
    fn fixed_point_reaches_three_deep_fact_with_chain() {
        let ms = three_deep();
        let (g, s) = seeds_of(&ms);
        let full = propagate(&g, &s, None);
        // caller is fn index 0; its summary must contain h3's lock.
        let caller = full.get(&(0, 0)).expect("caller has propagated facts");
        let fact = caller.get("state").expect("state lock reaches caller");
        let hops: Vec<&str> = fact.chain.iter().map(|h| h.callee.as_str()).collect();
        assert_eq!(hops, vec!["h1", "h2", "h3"]);
        assert_eq!(fact.line, 4);
    }

    #[test]
    fn depth_one_sees_only_direct_facts() {
        let ms = three_deep();
        let (g, s) = seeds_of(&ms);
        let legacy = propagate(&g, &s, Some(1));
        // Zero rounds: summaries equal the seeds, so caller/h1/h2 stay
        // empty and only h3 carries its own lock. This is exactly why
        // the one-level analyzer missed transitive cycles.
        assert!(legacy.get(&(0, 0)).is_none());
        assert!(legacy.get(&(0, 1)).is_none());
        assert!(legacy.get(&(0, 2)).is_none());
        assert!(legacy.get(&(0, 3)).is_some());
    }

    #[test]
    fn recursion_terminates_and_keeps_shortest_chain() {
        let ms = vec![FileModel::build(concat!(
            "fn a() { b(); }\n",
            "fn b() { a(); let g = mu.lock_unpoisoned(); }\n",
        ))];
        let (g, s) = seeds_of(&ms);
        let full = propagate(&g, &s, None);
        let a = full.get(&(0, 0)).unwrap();
        assert_eq!(a.get("mu").unwrap().chain.len(), 1);
        let b = full.get(&(0, 1)).unwrap();
        // b's own fact stays direct (chain empty), not the a->b loop.
        assert!(b.get("mu").unwrap().chain.is_empty());
    }

    #[test]
    fn detached_edges_do_not_carry_facts() {
        let ms = vec![FileModel::build(concat!(
            "fn a() { pool.execute(|| { locker(); }); }\n",
            "fn locker() { let g = mu.lock_unpoisoned(); }\n",
        ))];
        let (g, s) = seeds_of(&ms);
        let full = propagate(&g, &s, None);
        assert!(full.get(&(0, 0)).is_none(), "detached call must not join a's summary");
    }
}
