//! Token-level lexer for Rust source.
//!
//! The line-oriented scanner this subsystem replaced could be fooled by
//! a `lock()` inside a string literal, a brace inside a raw string, or a
//! nested block comment — anything where text and token disagree. This
//! lexer produces a real token stream so the rules in
//! [`crate::analysis::rules`] match *code*, never prose:
//!
//! * line comments and **nested** block comments are captured separately
//!   (comments carry the `lint:allow(rule)` annotations, so they are
//!   kept, just out of the token stream);
//! * string, byte-string, raw-string (`r#"…"#`, any number of `#`s) and
//!   char literals become single [`TokKind::Literal`] tokens — their
//!   contents can never match a rule pattern;
//! * lifetimes (`'a`) are distinguished from char literals (`'a'`) by
//!   lookahead, and raw identifiers (`r#type`) from raw strings
//!   (`r#"…"#`) by the byte after the `#`s;
//! * everything else is an [`TokKind::Ident`] or a one-character
//!   [`TokKind::Punct`], each tagged with its 1-based source line.
//!
//! The lexer is intentionally lossy in ways the rules never observe
//! (literal contents are kept only for diagnostics, numeric suffixes are
//! not split) and total: any byte sequence lexes without panicking.

/// Token classification. `Punct` tokens are single characters; multi-
/// character operators (`::`, `->`) appear as consecutive `Punct`s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Literal,
    Punct,
}

/// One token with its 1-based source line and byte-accurate span.
///
/// Span invariant (checked by `rules::verify_spans` and the R12 rule):
/// `text == String::from_utf8_lossy(&src[start..end])`, `line` is
/// 1 + the number of newlines before `start`, and `col` is the 1-based
/// byte column of `start` on that line. Prefixed tokens narrow the span
/// to the part `text` keeps: a lifetime `'a` spans just the `a`, a raw
/// identifier `r#type` spans just `type`.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
    /// Byte offset of the first byte of `text` in the source.
    pub start: usize,
    /// Byte offset one past the last byte of `text`.
    pub end: usize,
    /// 1-based byte column of `start` on `line`.
    pub col: usize,
}

impl Token {
    /// Is this the identifier `s`?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == c.len_utf8() && self.text.starts_with(c)
    }
}

/// A comment (line or block) with the source lines it spans.
#[derive(Debug, Clone)]
pub struct Comment {
    /// First line of the comment, 1-based.
    pub line: usize,
    /// Last line (equal to `line` for line comments).
    pub end_line: usize,
    pub text: String,
}

/// Lexed source: the code token stream plus the comments beside it.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

impl Lexed {
    /// The identifier text at token index `i`, if it is an identifier.
    pub fn ident(&self, i: usize) -> Option<&str> {
        let t = self.tokens.get(i)?;
        (t.kind == TokKind::Ident).then_some(t.text.as_str())
    }

    /// Is token `i` the punctuation `c`?
    pub fn punct(&self, i: usize, c: char) -> bool {
        self.tokens.get(i).is_some_and(|t| t.is_punct(c))
    }
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src` into tokens + comments. Total: never panics, any input.
pub fn lex(src: &str) -> Lexed {
    Lexer { b: src.as_bytes(), i: 0, line: 1, line_start: 0, out: Lexed::default() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: usize,
    /// Byte offset where the current line begins (columns are 1-based
    /// offsets from here).
    line_start: usize,
    out: Lexed,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Lexed {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                    self.line_start = self.i;
                }
                c if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(),
                b'\'' => self.char_or_lifetime(),
                b'r' | b'b' if self.raw_or_byte_prefix() => {}
                c if is_ident_start(c) => self.ident(),
                c if c.is_ascii_digit() => self.number(),
                _ => self.punct_or_utf8(),
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn push(
        &mut self,
        kind: TokKind,
        text: String,
        line: usize,
        start: usize,
        end: usize,
        col: usize,
    ) {
        self.out.tokens.push(Token { kind, text, line, start, end, col });
    }

    /// 1-based byte column of byte offset `at` on the current line.
    /// Call *before* consuming any newline the token may contain.
    fn col_of(&self, at: usize) -> usize {
        at - self.line_start + 1
    }

    fn line_comment(&mut self) {
        let start = self.i;
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.out.comments.push(Comment { line: self.line, end_line: self.line, text });
    }

    /// Block comment with Rust's nesting semantics (`/* /* */ */`).
    fn block_comment(&mut self) {
        let (start, start_line) = (self.i, self.line);
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            match (self.b[self.i], self.peek(1)) {
                (b'/', Some(b'*')) => {
                    depth += 1;
                    self.i += 2;
                }
                (b'*', Some(b'/')) => {
                    depth -= 1;
                    self.i += 2;
                }
                (b'\n', _) => {
                    self.line += 1;
                    self.i += 1;
                    self.line_start = self.i;
                }
                _ => self.i += 1,
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.out.comments.push(Comment { line: start_line, end_line: self.line, text });
    }

    /// `"…"` with escapes; newlines inside are legal and counted.
    fn string(&mut self) {
        let (start, start_line, start_col) = (self.i, self.line, self.col_of(self.i));
        self.i += 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => self.i += 2.min(self.b.len() - self.i),
                b'"' => {
                    self.i += 1;
                    break;
                }
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                    self.line_start = self.i;
                }
                _ => self.i += 1,
            }
        }
        let end = self.i.min(self.b.len());
        let text = String::from_utf8_lossy(&self.b[start..end]).into_owned();
        self.push(TokKind::Literal, text, start_line, start, end, start_col);
    }

    /// Handle `r"…"`, `r#"…"#`, `br"…"`, `b"…"`, `b'…'`, and raw idents
    /// `r#ident`. Returns true (via the caller's guard) only when the
    /// prefix really starts one of those; plain idents starting with
    /// `r`/`b` fall through to `ident()`.
    fn raw_or_byte_prefix(&mut self) -> bool {
        let c = self.b[self.i];
        // b"…" / b'…'
        if c == b'b' {
            match self.peek(1) {
                Some(b'"') => {
                    self.i += 1;
                    self.string();
                    return true;
                }
                Some(b'\'') => {
                    self.i += 1;
                    self.char_or_lifetime();
                    return true;
                }
                Some(b'r') => {
                    // br#"…"# — delegate to the raw-string scan below.
                    if self.raw_string_at(self.i + 2) {
                        return true;
                    }
                    return false;
                }
                _ => return false,
            }
        }
        // r"…" / r#"…"# / r#ident
        let mut j = self.i + 1;
        let mut hashes = 0usize;
        while self.b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
        match self.b.get(j) {
            Some(b'"') => self.raw_string_at(self.i + 1),
            Some(&c2) if hashes == 1 && is_ident_start(c2) => {
                // Raw identifier r#type: token is the bare ident.
                self.i = j;
                self.ident();
                true
            }
            _ => false,
        }
    }

    /// Scan a raw string whose `#`s begin at byte `from` (i.e. `from`
    /// points just past the `r`). Returns false if there is no raw
    /// string there.
    fn raw_string_at(&mut self, from: usize) -> bool {
        let mut j = from;
        let mut hashes = 0usize;
        while self.b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
        if self.b.get(j) != Some(&b'"') {
            return false;
        }
        let (start, start_line, start_col) = (self.i, self.line, self.col_of(self.i));
        j += 1;
        // No escapes in raw strings: scan for `"` + hashes `#`s.
        'scan: while j < self.b.len() {
            if self.b[j] == b'\n' {
                self.line += 1;
                j += 1;
                self.line_start = j;
                continue;
            }
            if self.b[j] == b'"' {
                let mut k = 0usize;
                while k < hashes && self.b.get(j + 1 + k) == Some(&b'#') {
                    k += 1;
                }
                if k == hashes {
                    j += 1 + hashes;
                    break 'scan;
                }
            }
            j += 1;
        }
        let end = j.min(self.b.len());
        let text = String::from_utf8_lossy(&self.b[start..end]).into_owned();
        self.i = j;
        self.push(TokKind::Literal, text, start_line, start, end, start_col);
        true
    }

    /// `'a'` (char literal) vs `'a` (lifetime): a quote two bytes out
    /// (or an escape) means char literal; otherwise lifetime.
    fn char_or_lifetime(&mut self) {
        let start_line = self.line;
        let start = self.i;
        let start_col = self.col_of(start);
        match self.peek(1) {
            Some(b'\\') => {
                // Escaped char literal: consume the escaped scalar (so
                // '\'' terminates on the right quote), then scan to the
                // closing quote (covers multi-byte escapes like \u{7f}).
                self.i += 2;
                if self.i < self.b.len() {
                    self.i += 1;
                }
                while self.i < self.b.len() && self.b[self.i] != b'\'' {
                    self.i += 1;
                }
                self.i = (self.i + 1).min(self.b.len());
                let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
                self.push(TokKind::Literal, text, start_line, start, self.i, start_col);
            }
            Some(c) if is_ident_start(c) => {
                // 'a' is a char, 'a / 'static are lifetimes. A char
                // literal's payload is one scalar, so find where the
                // ident run ends and check for a closing quote.
                let mut j = self.i + 1;
                while self.b.get(j).copied().is_some_and(is_ident_cont) {
                    j += 1;
                }
                if self.b.get(j) == Some(&b'\'') {
                    self.i = j + 1;
                    let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
                    self.push(TokKind::Literal, text, start_line, start, self.i, start_col);
                } else {
                    // Span covers the ident only (past the quote), so
                    // text == source slice holds for lifetimes too.
                    let text = String::from_utf8_lossy(&self.b[start + 1..j]).into_owned();
                    self.i = j;
                    self.push(TokKind::Lifetime, text, start_line, start + 1, j, start_col + 1);
                }
            }
            Some(_) => {
                // Char literal of a non-ident scalar ('{', '\u{…}'
                // handled above, multibyte UTF-8, …): scan to close.
                self.i += 1;
                while self.i < self.b.len() && self.b[self.i] != b'\'' {
                    if self.b[self.i] == b'\n' {
                        self.line += 1;
                        self.line_start = self.i + 1;
                    }
                    self.i += 1;
                }
                self.i = (self.i + 1).min(self.b.len());
                let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
                self.push(TokKind::Literal, text, start_line, start, self.i, start_col);
            }
            None => {
                self.i += 1;
                self.push(TokKind::Punct, "'".into(), start_line, start, self.i, start_col);
            }
        }
    }

    fn ident(&mut self) {
        let start = self.i;
        let start_col = self.col_of(start);
        while self.i < self.b.len() && is_ident_cont(self.b[self.i]) {
            self.i += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(TokKind::Ident, text, self.line, start, self.i, start_col);
    }

    /// Numeric literal: digits, `_`, hex/suffix letters, a decimal point
    /// followed by a digit, and a sign directly after an exponent `e`.
    fn number(&mut self) {
        let start = self.i;
        let start_col = self.col_of(start);
        self.i += 1;
        while self.i < self.b.len() {
            let c = self.b[self.i];
            if c.is_ascii_alphanumeric() || c == b'_' {
                self.i += 1;
            } else if c == b'.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                self.i += 1;
            } else if (c == b'+' || c == b'-')
                && matches!(self.b[self.i - 1], b'e' | b'E')
                && self.b[start].is_ascii_digit()
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.push(TokKind::Literal, text, self.line, start, self.i, start_col);
    }

    fn punct_or_utf8(&mut self) {
        let c = self.b[self.i];
        let start = self.i;
        let start_col = self.col_of(start);
        if c < 0x80 {
            self.i += 1;
            self.push(TokKind::Punct, (c as char).to_string(), self.line, start, self.i, start_col);
        } else {
            // One UTF-8 scalar as a punct token (only reachable from
            // non-ASCII code points outside strings/comments — rare).
            let s = &self.b[self.i..];
            let len = match std::str::from_utf8(s) {
                Ok(t) => t.chars().next().map(|c| c.len_utf8()).unwrap_or(1),
                Err(e) if e.valid_up_to() > 0 => {
                    let t = std::str::from_utf8(&s[..e.valid_up_to()]).unwrap_or("?");
                    t.chars().next().map(|c| c.len_utf8()).unwrap_or(1)
                }
                Err(_) => 1,
            };
            let text = String::from_utf8_lossy(&s[..len]).into_owned();
            self.i += len;
            self.push(TokKind::Punct, text, self.line, start, self.i, start_col);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn basic_tokens_carry_lines() {
        let lx = lex("fn f() {\n    x.lock()\n}\n");
        let lock = lx.tokens.iter().find(|t| t.is_ident("lock")).unwrap();
        assert_eq!(lock.line, 2);
        assert!(lx.tokens.iter().any(|t| t.is_punct('{')));
        assert!(lx.tokens.iter().any(|t| t.is_punct('}')));
    }

    #[test]
    fn line_and_nested_block_comments_leave_no_tokens() {
        let src = "// x.lock().unwrap()\n/* outer /* inner */ x.lock() */ real\n";
        let lx = lex(src);
        assert_eq!(idents(src), vec!["real"]);
        assert_eq!(lx.comments.len(), 2);
        assert_eq!(lx.comments[0].line, 1);
        assert!(lx.comments[1].text.contains("inner"));
    }

    #[test]
    fn block_comment_spans_lines() {
        let lx = lex("/* a\nb\nc */ after\n");
        assert_eq!(lx.comments[0].line, 1);
        assert_eq!(lx.comments[0].end_line, 3);
        assert_eq!(lx.tokens[0].line, 3);
    }

    #[test]
    fn string_contents_never_tokenize() {
        // The adversarial cases the old line scanner got wrong: code-like
        // text inside string literals.
        let src = r#"let s = "x.lock().unwrap() { } // not a comment";"#;
        let names = idents(src);
        assert_eq!(names, vec!["let", "s"]);
        let lx = lex(src);
        assert!(lx.comments.is_empty());
        assert!(!lx.tokens.iter().any(|t| t.is_punct('{')));
    }

    #[test]
    fn raw_strings_with_hashes_and_braces() {
        let src = "let s = r#\"contains lock() and \"quotes\" and { braces }\"#; done();";
        let names = idents(src);
        assert_eq!(names, vec!["let", "s", "done"]);
        // Multi-hash raw string containing a single-hash terminator.
        let src2 = "let t = r##\"inner \"# still open\"##; fin();";
        assert_eq!(idents(src2), vec!["let", "t", "fin"]);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let src = "let a = b\"lock()\"; let c = b'x'; let r = br#\"raw { }\"#; ok();";
        assert_eq!(idents(src), vec!["let", "a", "let", "c", "let", "r", "ok"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let lx = lex("fn f<'a>(x: &'a str) { let c = 'a'; let n = '\\n'; let brace = '{'; }");
        let lifetimes: Vec<_> =
            lx.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2, "{lifetimes:?}");
        assert!(lifetimes.iter().all(|t| t.text == "a"));
        // '{' must be a char literal, not an open brace: only the fn
        // body's open brace survives.
        let opens = lx.tokens.iter().filter(|t| t.is_punct('{')).count();
        assert_eq!(opens, 1);
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Lifetime));
    }

    #[test]
    fn static_lifetime_and_multichar() {
        let lx = lex("&'static str");
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "static"));
    }

    #[test]
    fn raw_identifiers() {
        let lx = lex("let r#type = 1;");
        assert!(lx.tokens.iter().any(|t| t.is_ident("type")));
        assert!(!lx.tokens.iter().any(|t| t.kind == TokKind::Literal));
    }

    #[test]
    fn numbers_with_exponents_and_ranges() {
        let lx = lex("for i in 0..10 { let x = 1.5e-3; let h = 0xFF_u32; }");
        let lits: Vec<_> = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Literal)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lits, vec!["0", "10", "1.5e-3", "0xFF_u32"]);
        // The range dots survive as puncts.
        assert_eq!(lx.tokens.iter().filter(|t| t.is_punct('.')).count(), 2);
    }

    #[test]
    fn multiline_strings_count_lines() {
        let lx = lex("let s = \"a\nb\"; after();");
        let after = lx.tokens.iter().find(|t| t.is_ident("after")).unwrap();
        assert_eq!(after.line, 2);
    }

    #[test]
    fn spans_are_byte_accurate() {
        let src = "fn f() {\n    x.lock()\n}\nlet s = \"a\nb\"; fin();\n";
        let lx = lex(src);
        for t in &lx.tokens {
            assert_eq!(
                t.text,
                String::from_utf8_lossy(&src.as_bytes()[t.start..t.end]),
                "span of {t:?} does not reproduce its text"
            );
            let before = &src.as_bytes()[..t.start];
            let line = 1 + before.iter().filter(|&&b| b == b'\n').count();
            assert_eq!(t.line, line, "{t:?}");
            let line_start =
                before.iter().rposition(|&b| b == b'\n').map(|p| p + 1).unwrap_or(0);
            assert_eq!(t.col, t.start - line_start + 1, "{t:?}");
        }
        let lock = lx.tokens.iter().find(|t| t.is_ident("lock")).unwrap();
        assert_eq!((lock.line, lock.col), (2, 7));
        // `fin` comes after a multi-line string: line/col must resync.
        let fin = lx.tokens.iter().find(|t| t.is_ident("fin")).unwrap();
        assert_eq!((fin.line, fin.col), (5, 5));
    }

    #[test]
    fn lifetime_and_raw_ident_spans_cover_their_text() {
        let src = "&'a str; let r#type = 1;";
        let lx = lex(src);
        let lt = lx.tokens.iter().find(|t| t.kind == TokKind::Lifetime).unwrap();
        assert_eq!(&src[lt.start..lt.end], "a");
        let raw = lx.tokens.iter().find(|t| t.is_ident("type")).unwrap();
        assert_eq!(&src[raw.start..raw.end], "type");
    }

    #[test]
    fn total_on_garbage() {
        // Unterminated everything — must not panic or loop.
        for src in ["\"abc", "r#\"abc", "/* abc", "'", "b'", "let x = 'a", "é ident"] {
            let _ = lex(src);
        }
    }
}
