//! The seven static rules, matched over the structural model.
//!
//! | Rule | Contract |
//! |---|---|
//! | R1 `lock-unwrap` | no poisoning `.lock().unwrap()` / `.expect(…)` (or condvar-wait equivalents) — shed poison via `util::sync` |
//! | R2 `instant-in-decide` | no `Instant::now()` in decide-critical sections: anywhere in `rank_controller.rs`, or while a shard-lock guard is live (crate-wide) |
//! | R3 `raw-mpsc` | no `std::sync::mpsc` outside `coordinator/completion.rs` |
//! | R4 `lock-order` | the lock-acquisition graph (lock taken while another guard is live, propagated one level through the call graph) must be acyclic |
//! | R5 `nondet-iter` | no `HashMap`/`HashSet` iteration in bit-identity-critical modules (`coordinator/`, `linalg/`, `conformance/`) |
//! | R6 `panic-in-worker` | no `unwrap()` / `expect(…)` / `panic!` inside thread-pool closures or worker-loop fns (non-test) |
//! | R7 `pool-shape-partition` | no pool-size / thread-count reads inside `linalg/` — chunk partitions are pure functions of problem shape |
//!
//! Every rule skips test code (`#[cfg(test)]` items, `#[test]` fns) and
//! honors a `lint:allow(<rule>)` annotation in a comment on the flagged
//! line or in the contiguous comment block directly above it.

use super::model::{receiver_path, FileModel, LockAcq};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintViolation {
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub text: String,
}

impl fmt::Display for LintViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.text.trim())
    }
}

/// Catalogue entry for one rule (drives `--json` output and docs).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub name: &'static str,
    pub contract: &'static str,
}

/// The rule catalogue, R1–R7 in order.
pub const RULES: [RuleInfo; 7] = [
    RuleInfo {
        name: "lock-unwrap",
        contract: "no poisoning .lock()/.read()/.write()/.wait(..) unwrap/expect on sync \
                   primitives; shed poison via util::sync::{LockExt, CondvarExt}",
    },
    RuleInfo {
        name: "instant-in-decide",
        contract: "no Instant::now() in decide-critical sections (rank_controller.rs, or \
                   while a shard-lock guard is live anywhere in the crate)",
    },
    RuleInfo {
        name: "raw-mpsc",
        contract: "no std::sync::mpsc outside coordinator/completion.rs; annotated \
                   exceptions only",
    },
    RuleInfo {
        name: "lock-order",
        contract: "the crate-wide lock acquisition graph (lock B taken while guard A is \
                   live, one level of call propagation) must have no cycles",
    },
    RuleInfo {
        name: "nondet-iter",
        contract: "no HashMap/HashSet iteration inside bit-identity-critical modules \
                   (coordinator/, linalg/, conformance/)",
    },
    RuleInfo {
        name: "panic-in-worker",
        contract: "no unwrap()/expect(..)/panic! inside thread-pool closures or worker \
                   loops (non-test code)",
    },
    RuleInfo {
        name: "pool-shape-partition",
        contract: "no pool-size/thread-count reads inside linalg/; chunk partitions are \
                   pure functions of problem shape",
    },
];

/// Analysis context for one file.
struct Ctx {
    path: PathBuf,
    model: FileModel,
    lines: Vec<String>,
}

impl Ctx {
    fn new(path: PathBuf, source: &str) -> Ctx {
        Ctx { model: FileModel::build(source), lines: source.lines().map(String::from).collect(), path }
    }

    fn file_name(&self) -> &str {
        self.path.file_name().and_then(|n| n.to_str()).unwrap_or("")
    }

    /// Is the file inside `rust/src/<module>/` (by path component)?
    fn in_module(&self, module: &str) -> bool {
        self.path.components().any(|c| c.as_os_str() == module)
    }

    fn line_text(&self, line: usize) -> String {
        self.lines.get(line.saturating_sub(1)).cloned().unwrap_or_default()
    }

    /// Is `lint:allow(<rule>)` present on `line` or in the contiguous
    /// comment block directly above it? `aliases` supplements the rule
    /// name (e.g. the legacy `lint:allow(mpsc)` spelling).
    fn allowed(&self, line: usize, rule: &str, aliases: &[&str]) -> bool {
        let mut markers: Vec<String> = vec![format!("lint:allow({rule})")];
        markers.extend(aliases.iter().map(|a| format!("lint:allow({a})")));
        let has_marker =
            |text: &str| markers.iter().any(|m| text.contains(m.as_str()));
        // Same-line trailing comment.
        for c in &self.model.lexed.comments {
            if c.line <= line && line <= c.end_line && has_marker(&c.text) {
                return true;
            }
        }
        // Contiguous comment block ending directly above `line`: walk the
        // chain of comments whose spans stack without gaps.
        let mut want_end = line - 1;
        loop {
            let Some(c) = self.model.lexed.comments.iter().find(|c| c.end_line == want_end)
            else {
                return false;
            };
            if has_marker(&c.text) {
                return true;
            }
            if c.line == 0 {
                return false;
            }
            want_end = c.line - 1;
        }
    }

    fn push(&self, out: &mut Vec<LintViolation>, line: usize, rule: &'static str, text: String) {
        out.push(LintViolation { file: self.path.clone(), line, rule, text });
    }

    fn flag_line(
        &self,
        out: &mut Vec<LintViolation>,
        line: usize,
        rule: &'static str,
        aliases: &[&str],
    ) {
        if !self.allowed(line, rule, aliases) {
            self.push(out, line, rule, self.line_text(line));
        }
    }
}

/// Analyze one standalone source file (all file-local rules plus any
/// lock-order cycles visible within the file).
pub fn analyze_source(path: &Path, source: &str) -> Vec<LintViolation> {
    analyze_crate(&[(path.to_path_buf(), source.to_string())])
}

/// Analyze a set of files as one crate: every file-local rule per file,
/// plus the crate-wide lock-order graph (R4).
pub fn analyze_crate(files: &[(PathBuf, String)]) -> Vec<LintViolation> {
    let ctxs: Vec<Ctx> =
        files.iter().map(|(p, s)| Ctx::new(p.clone(), s)).collect();
    let mut out = Vec::new();
    for ctx in &ctxs {
        r1_lock_unwrap(ctx, &mut out);
        r2_instant_in_decide(ctx, &mut out);
        r3_raw_mpsc(ctx, &mut out);
        r5_nondet_iter(ctx, &mut out);
        r6_panic_in_worker(ctx, &mut out);
        r7_pool_shape_partition(ctx, &mut out);
    }
    r4_lock_order(&ctxs, &mut out);
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// Skip-matching over a balanced `(…)` group starting at open paren `i`;
/// returns the index of the matching `)`.
fn matching_paren(m: &FileModel, i: usize) -> Option<usize> {
    let lx = &m.lexed;
    let mut depth = 0i64;
    let mut j = i;
    while j < lx.tokens.len() {
        if lx.punct(j, '(') {
            depth += 1;
        } else if lx.punct(j, ')') {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

/// R1 — poisoning unwrap/expect on lock, rwlock and condvar-wait
/// results, crate-wide outside test code.
fn r1_lock_unwrap(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let m = &ctx.model;
    let lx = &m.lexed;
    for i in 1..lx.tokens.len() {
        if m.in_test(i) || !lx.punct(i - 1, '.') {
            continue;
        }
        let Some(name) = lx.ident(i) else { continue };
        let poisoning_tail = |after: usize| -> bool {
            lx.punct(after, '.')
                && ((lx.ident(after + 1) == Some("unwrap")
                    && lx.punct(after + 2, '(')
                    && lx.punct(after + 3, ')'))
                    || (lx.ident(after + 1) == Some("expect") && lx.punct(after + 2, '(')))
        };
        let hit = match name {
            // `.lock().unwrap()` and friends: empty argument lists.
            "lock" | "read" | "write" | "try_lock" => {
                lx.punct(i + 1, '(') && lx.punct(i + 2, ')') && poisoning_tail(i + 3)
            }
            // `.wait(guard).unwrap()` / `.wait_timeout(guard, d).expect(…)`.
            "wait" | "wait_timeout" => lx.punct(i + 1, '(')
                && matching_paren(m, i + 1).is_some_and(|close| poisoning_tail(close + 1)),
            _ => false,
        };
        if hit {
            ctx.flag_line(out, lx.tokens[i].line, "lock-unwrap", &[]);
        }
    }
}

/// Token index sequence of `Instant::now`.
fn is_instant_now(m: &FileModel, i: usize) -> bool {
    let lx = &m.lexed;
    lx.ident(i) == Some("Instant")
        && lx.punct(i + 1, ':')
        && lx.punct(i + 2, ':')
        && lx.ident(i + 3) == Some("now")
}

/// R2 — wall-clock reads in decide-critical sections: any non-test
/// `Instant::now` in `rank_controller.rs`, or — crate-wide — one
/// evaluated while a shard-lock guard is live.
fn r2_instant_in_decide(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let m = &ctx.model;
    let whole_file = ctx.file_name() == "rank_controller.rs";
    for i in 0..m.lexed.tokens.len() {
        if m.in_test(i) || !is_instant_now(m, i) {
            continue;
        }
        let in_shard_guard = m
            .live_guards_at(i)
            .iter()
            .any(|g| g.name.contains("shard") || g.path.contains("shard"));
        if whole_file || in_shard_guard {
            ctx.flag_line(out, m.lexed.tokens[i].line, "instant-in-decide", &[]);
        }
    }
}

/// R3 — raw std channels outside the completion layer, crate-wide.
fn r3_raw_mpsc(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if ctx.file_name() == "completion.rs" {
        return;
    }
    let m = &ctx.model;
    let mut last_line = 0usize;
    for i in 0..m.lexed.tokens.len() {
        if m.in_test(i) || m.lexed.ident(i) != Some("mpsc") {
            continue;
        }
        let line = m.lexed.tokens[i].line;
        if line == last_line {
            continue; // one violation per line, as the old scanner did
        }
        last_line = line;
        ctx.flag_line(out, line, "raw-mpsc", &["mpsc"]);
    }
}

/// One edge of the lock-order graph.
#[derive(Debug, Clone)]
struct LockEdge {
    from: String,
    to: String,
    file: PathBuf,
    line: usize,
    /// Set when the edge came from one level of call propagation.
    via: Option<String>,
}

/// R4 — cycles in the lock-acquisition order graph.
///
/// Nodes are lock identities (the receiver chain's final field name).
/// A direct edge `A → B` is recorded when `B` is acquired while a guard
/// of `A` is live in the same fn; a propagated edge when a fn is called
/// with `A` held and the callee (matched by name anywhere in the crate)
/// directly acquires `B`. Any cycle — including a self-loop, i.e.
/// re-acquiring a lock of the same identity while it is held — is a
/// potential deadlock under some thread interleaving.
fn r4_lock_order(ctxs: &[Ctx], out: &mut Vec<LintViolation>) {
    // fn name → (ctx idx, fn idx) for call propagation.
    let mut fns_by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        for (fi, f) in ctx.model.fns.iter().enumerate() {
            if !f.is_test {
                fns_by_name.entry(f.name.as_str()).or_default().push((ci, fi));
            }
        }
    }
    // Direct, non-detached acquisitions of one fn (the callee summary).
    fn direct_acqs<'a>(ctx: &'a Ctx, fi: usize) -> Vec<&'a LockAcq> {
        let f = &ctx.model.fns[fi];
        ctx.model
            .locks
            .iter()
            .filter(|l| f.open < l.tok && l.tok < f.close && !l.detached)
            .filter(|l| !ctx.model.in_test(l.tok))
            .collect()
    }

    let mut edges: Vec<LockEdge> = Vec::new();
    for ctx in ctxs {
        let m = &ctx.model;
        // Direct edges: acquisition under a live guard.
        for a in &m.locks {
            if m.in_test(a.tok) || ctx.allowed(a.line, "lock-order", &[]) {
                continue;
            }
            for g in m.live_guards_at(a.tok) {
                edges.push(LockEdge {
                    from: g.name.clone(),
                    to: a.name.clone(),
                    file: ctx.path.clone(),
                    line: a.line,
                    via: None,
                });
            }
        }
        // Propagated edges: call made under a live guard, callee locks.
        for c in &m.calls {
            if m.in_test(c.tok) || ctx.allowed(c.line, "lock-order", &[]) {
                continue;
            }
            // Name matching cannot type-resolve method receivers, so only
            // free-function calls and `self.` method calls propagate —
            // `g.queue.len()` must not alias some other type's `len`.
            if c.tok > 0 && m.lexed.punct(c.tok - 1, '.') {
                let recv = receiver_path(&m.lexed, c.tok - 1);
                if recv != ["self"] {
                    continue;
                }
            }
            let held = m.live_guards_at(c.tok);
            if held.is_empty() {
                continue;
            }
            let Some(targets) = fns_by_name.get(c.callee.as_str()) else { continue };
            for &(ci, fi) in targets {
                for a in direct_acqs(&ctxs[ci], fi) {
                    if ctxs[ci].allowed(a.line, "lock-order", &[]) {
                        continue;
                    }
                    for g in &held {
                        edges.push(LockEdge {
                            from: g.name.clone(),
                            to: a.name.clone(),
                            file: ctx.path.clone(),
                            line: c.line,
                            via: Some(format!("{}() at {}:{}", c.callee,
                                ctxs[ci].file_name(), a.line)),
                        });
                    }
                }
            }
        }
    }

    // Dedup to one representative edge per (from, to).
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    let mut rep: BTreeMap<(&str, &str), &LockEdge> = BTreeMap::new();
    for e in &edges {
        adj.entry(e.from.as_str()).or_default().insert(e.to.as_str());
        rep.entry((e.from.as_str(), e.to.as_str())).or_insert(e);
    }

    // For every edge A→B, a path B→…→A closes a cycle. Self-loops are
    // the degenerate case. Report each distinct node set once.
    let mut reported: BTreeSet<Vec<&str>> = BTreeSet::new();
    for (&(from, to), _) in rep.iter() {
        let Some(path) = find_path(&adj, to, from) else { continue };
        // Cycle nodes: from → to → … (the path ends back at `from`; drop
        // that duplicate so the wrap-around edge closes the cycle).
        let mut nodes: Vec<&str> = Vec::with_capacity(path.len() + 1);
        nodes.push(from);
        nodes.extend(path.iter().copied());
        if nodes.len() > 1 && nodes.last() == Some(&from) {
            nodes.pop();
        }
        let mut key: Vec<&str> = nodes.clone();
        key.sort_unstable();
        key.dedup();
        if !reported.insert(key) {
            continue;
        }
        // Describe the cycle edge by edge with sites.
        let mut desc = String::from("lock-order cycle: ");
        for w in 0..nodes.len() {
            let a = nodes[w];
            let b = nodes[(w + 1) % nodes.len()];
            if w > 0 {
                desc.push_str(" -> ");
            }
            if let Some(e) = rep.get(&(a, b)) {
                let via = e.via.as_deref().map(|v| format!(" via {v}")).unwrap_or_default();
                desc.push_str(&format!(
                    "{a} ({}:{}{via})",
                    e.file.file_name().and_then(|n| n.to_str()).unwrap_or("?"),
                    e.line
                ));
            } else {
                desc.push_str(a);
            }
        }
        desc.push_str(" — potential deadlock");
        let first = rep[&(from, to)];
        out.push(LintViolation {
            file: first.file.clone(),
            line: first.line,
            rule: "lock-order",
            text: desc,
        });
    }
}

/// BFS path from `start` to `goal` over the adjacency map. Returns the
/// node sequence `[start, …, goal]` (singleton when `start == goal` and
/// a self-loop exists is handled by the caller's edge iteration).
fn find_path<'a>(
    adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    start: &'a str,
    goal: &str,
) -> Option<Vec<&'a str>> {
    let mut prev: BTreeMap<&str, &str> = BTreeMap::new();
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(start);
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    seen.insert(start);
    while let Some(n) = queue.pop_front() {
        if n == goal {
            let mut path = vec![n];
            let mut cur = n;
            while let Some(&p) = prev.get(cur) {
                path.push(p);
                cur = p;
            }
            path.reverse();
            return Some(path);
        }
        for &nx in adj.get(n).into_iter().flatten() {
            if seen.insert(nx) {
                prev.insert(nx, n);
                queue.push_back(nx);
            }
        }
    }
    None
}

/// Methods whose call iterates an unordered container.
const ITER_METHODS: [&str; 10] = [
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain", "retain",
    "into_keys", "into_values",
];

/// R5 — unordered-container iteration in bit-identity-critical modules.
fn r5_nondet_iter(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if !(ctx.in_module("coordinator") || ctx.in_module("linalg") || ctx.in_module("conformance")) {
        return;
    }
    let m = &ctx.model;
    let lx = &m.lexed;
    let n = lx.tokens.len();

    // Names bound to HashMap/HashSet in this file: `name: HashMap<…>`
    // (let ascription or struct field) and `let name = HashMap::…`.
    let mut unordered: BTreeSet<String> = BTreeSet::new();
    for i in 0..n {
        let Some(ty) = lx.ident(i) else { continue };
        if ty != "HashMap" && ty != "HashSet" {
            continue;
        }
        if i >= 2 && lx.punct(i - 1, ':') && !lx.punct(i - 2, ':') {
            if let Some(name) = lx.ident(i - 2) {
                unordered.insert(name.to_string());
            }
        }
        if i >= 2 && lx.punct(i - 1, '=') {
            if let Some(name) = lx.ident(i - 2) {
                unordered.insert(name.to_string());
            }
        }
    }
    if unordered.is_empty() {
        return;
    }

    for i in 0..n {
        if m.in_test(i) {
            continue;
        }
        // `name.iter()` / `.keys()` / `.drain()` … on a tracked name.
        if let Some(name) = lx.ident(i) {
            if unordered.contains(name) && lx.punct(i + 1, '.') {
                if let Some(meth) = lx.ident(i + 2) {
                    if ITER_METHODS.contains(&meth) && lx.punct(i + 3, '(') {
                        ctx.flag_line(out, lx.tokens[i].line, "nondet-iter", &[]);
                        continue;
                    }
                }
            }
        }
        // `for pat in [&][mut] name {` — direct iteration of the map.
        if lx.ident(i) == Some("for") {
            let mut j = i + 1;
            let mut depth = 0i64;
            while j < n && !(depth == 0 && lx.ident(j) == Some("in")) && !lx.punct(j, '{') {
                if lx.punct(j, '(') {
                    depth += 1;
                } else if lx.punct(j, ')') {
                    depth -= 1;
                }
                j += 1;
            }
            if j >= n || !matches!(lx.ident(j), Some("in")) {
                continue;
            }
            let mut k = j + 1;
            while k < n && (lx.punct(k, '&') || lx.ident(k) == Some("mut") || lx.punct(k, '(')) {
                k += 1;
            }
            if let Some(name) = lx.ident(k) {
                if unordered.contains(name) && (lx.punct(k + 1, '{') || lx.punct(k + 1, ')')) {
                    ctx.flag_line(out, lx.tokens[k].line, "nondet-iter", &[]);
                }
            }
        }
    }
}

/// R6 — panics inside worker contexts (thread-pool closures, worker-loop
/// fns), non-test code.
fn r6_panic_in_worker(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let m = &ctx.model;
    let lx = &m.lexed;
    for &(start, end) in &m.worker_regions {
        for i in start..=end.min(lx.tokens.len().saturating_sub(1)) {
            if m.in_test(i) {
                continue;
            }
            let Some(name) = lx.ident(i) else { continue };
            let hit = match name {
                "unwrap" => {
                    i >= 1 && lx.punct(i - 1, '.') && lx.punct(i + 1, '(') && lx.punct(i + 2, ')')
                }
                "expect" => i >= 1 && lx.punct(i - 1, '.') && lx.punct(i + 1, '('),
                "panic" | "todo" | "unimplemented" => lx.punct(i + 1, '!'),
                _ => false,
            };
            if hit {
                ctx.flag_line(out, lx.tokens[i].line, "panic-in-worker", &[]);
            }
        }
    }
}

/// Identifiers whose mere appearance in `linalg/` reads a pool size or
/// thread count.
const POOL_SIZE_IDENTS: [&str; 5] =
    ["available_parallelism", "n_threads", "num_threads", "pool_threads", "n_workers"];

/// R7 — pool-size / thread-count reads inside `linalg/`: partitions must
/// be pure functions of problem shape (CONFORMANCE.md, PR 7 contract).
fn r7_pool_shape_partition(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if !ctx.in_module("linalg") {
        return;
    }
    let m = &ctx.model;
    let lx = &m.lexed;
    for i in 0..lx.tokens.len() {
        if m.in_test(i) {
            continue;
        }
        let Some(name) = lx.ident(i) else { continue };
        let hit = POOL_SIZE_IDENTS.contains(&name)
            || (name == "size"
                && i >= 1
                && lx.punct(i - 1, '.')
                && lx.punct(i + 1, '(')
                && lx.punct(i + 2, ')')
                && receiver_path(lx, i - 1).iter().any(|p| p.to_lowercase().contains("pool")));
        if hit {
            ctx.flag_line(out, lx.tokens[i].line, "pool-shape-partition", &[]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(file: &str, src: &str) -> Vec<LintViolation> {
        analyze_source(Path::new(file), src)
    }

    // ---- R1 (migrated from the line scanner, now token-exact) ----

    #[test]
    fn r1_flags_poisoning_lock_unwraps() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let v = scan("rust/src/coordinator/engine.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "lock-unwrap");
        assert_eq!(v[0].line, 2);

        let ok = "fn f() {\n    let g = state.lock_unpoisoned();\n}\n";
        assert!(scan("rust/src/coordinator/engine.rs", ok).is_empty());
    }

    #[test]
    fn r1_flags_condvar_unwraps_but_not_ticket_waits() {
        let bad = "fn f() { let g = cv.wait(guard).unwrap(); }\n";
        assert_eq!(scan("rust/src/coordinator/engine.rs", bad).len(), 1);
        // Ticket::wait returns a plain result the caller may handle.
        let ok = "fn f() { let r = ticket.wait(); r.ok(); }\n";
        assert!(scan("rust/src/coordinator/engine.rs", ok).is_empty());
    }

    #[test]
    fn r1_is_not_fooled_by_strings_or_comments() {
        // The cases the old line-oriented scanner could not distinguish.
        let src = concat!(
            "fn f() {\n",
            "    // state.lock().unwrap() — do not resurrect\n",
            "    let msg = \"state.lock().unwrap()\";\n",
            "    let raw = r#\"cv.wait(g).unwrap()\"#;\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/engine.rs", src).is_empty());
    }

    #[test]
    fn r1_skips_test_code() {
        let src = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() { let g = m.lock().unwrap(); }\n",
            "}\n",
        );
        assert!(scan("rust/src/util/threadpool.rs", src).is_empty());
    }

    // ---- R2 ----

    #[test]
    fn r2_flags_instant_now_anywhere_in_rank_controller() {
        let src = "fn decide() {\n    let t = Instant::now();\n}\n";
        let v = scan("rust/src/coordinator/rank_controller.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "instant-in-decide");
        // Same text outside any decide-critical scope is fine.
        assert!(scan("rust/src/coordinator/batcher.rs", src).is_empty());
    }

    #[test]
    fn r2_tracks_shard_guard_regions_anywhere() {
        let bad = concat!(
            "fn decide_stage() {\n",
            "    {\n",
            "        let mut shard = shared.shards[layer].lock_unpoisoned();\n",
            "        let t = Instant::now();\n",
            "    }\n",
            "    let after = Instant::now();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", bad);
        assert_eq!(v.len(), 1, "only the in-guard read is critical: {v:?}");
        assert_eq!(v[0].line, 4);
        // The guard-region rule is crate-wide now, not pipeline-only.
        let v2 = scan("rust/src/runtime/host.rs", bad);
        assert_eq!(v2.len(), 1);
    }

    // ---- R3 ----

    #[test]
    fn r3_flags_raw_mpsc_unless_annotated() {
        let bad = "use std::sync::mpsc;\n";
        let v = scan("rust/src/runtime/worker.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "raw-mpsc");

        let allowed = concat!(
            "// PJRT literals are not Send; a thread-local channel is the\n",
            "// sanctioned escape hatch here. lint:allow(mpsc)\n",
            "use std::sync::mpsc;\n",
        );
        assert!(scan("rust/src/runtime/worker.rs", allowed).is_empty());

        // A blank line breaks the annotation's contiguous block.
        let broken = "// lint:allow(mpsc)\n\nuse std::sync::mpsc;\n";
        assert_eq!(scan("rust/src/runtime/worker.rs", broken).len(), 1);

        // completion.rs owns the channel surface.
        assert!(scan("rust/src/coordinator/completion.rs", bad).is_empty());
    }

    #[test]
    fn r3_accepts_rule_scoped_allow_spelling() {
        let allowed = "// internal queue. lint:allow(raw-mpsc)\nuse std::sync::mpsc;\n";
        assert!(scan("rust/src/util/threadpool.rs", allowed).is_empty());
    }

    // ---- R4 ----

    #[test]
    fn r4_detects_two_lock_order_inversion() {
        let src = concat!(
            "fn forward(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn backward(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        let cycles: Vec<_> = v.iter().filter(|v| v.rule == "lock-order").collect();
        assert_eq!(cycles.len(), 1, "{v:?}");
        assert!(cycles[0].text.contains("alpha"));
        assert!(cycles[0].text.contains("beta"));
    }

    #[test]
    fn r4_consistent_order_is_clean() {
        let src = concat!(
            "fn one(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn two(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/sched.rs", src).is_empty());
    }

    #[test]
    fn r4_propagates_one_call_level() {
        let src = concat!(
            "fn outer(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    helper(s);\n",
            "}\n",
            "fn helper(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn inverted(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        let cycles: Vec<_> = v.iter().filter(|v| v.rule == "lock-order").collect();
        assert_eq!(cycles.len(), 1, "{v:?}");
        assert!(cycles[0].text.contains("helper"), "{}", cycles[0].text);
    }

    #[test]
    fn r4_self_relock_is_a_cycle() {
        let src = concat!(
            "fn f(s: &S) {\n",
            "    let a = s.table.lock_unpoisoned();\n",
            "    let b = s.table.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        assert_eq!(v.iter().filter(|v| v.rule == "lock-order").count(), 1);
    }

    #[test]
    fn r4_detached_closures_do_not_edge() {
        // The guard is NOT held inside an execute() closure — no edge.
        let src = concat!(
            "fn f(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    pool.execute(move || {\n",
            "        let b = s.beta.lock_unpoisoned();\n",
            "    });\n",
            "}\n",
            "fn g(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/sched.rs", src).is_empty());
    }

    #[test]
    fn r4_allow_suppresses_the_edge() {
        let src = concat!(
            "fn forward(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn backward(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    // audited: disjoint shard index sets. lint:allow(lock-order)\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/sched.rs", src).is_empty());
    }

    // ---- R5 ----

    #[test]
    fn r5_flags_hashmap_iteration_in_critical_modules() {
        let src = concat!(
            "use std::collections::HashMap;\n",
            "fn reduce(xs: &[f64]) -> f64 {\n",
            "    let mut acc: HashMap<usize, f64> = HashMap::new();\n",
            "    for (i, x) in xs.iter().enumerate() { *acc.entry(i % 4).or_insert(0.0) += x; }\n",
            "    let mut total = 0.0;\n",
            "    for (_, v) in &acc { total += v; }\n",
            "    total\n",
            "}\n",
        );
        let v = scan("rust/src/linalg/reduce.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "nondet-iter");
        assert_eq!(v[0].line, 6);
        // Outside the critical modules the same code is fine.
        assert!(scan("rust/src/rl/replay.rs", src).is_empty());
    }

    #[test]
    fn r5_flags_iter_method_chains() {
        let src = concat!(
            "fn f() {\n",
            "    let seen = HashSet::new();\n",
            "    let total: usize = seen.iter().count();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/track.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn r5_btreemap_is_fine() {
        let src = concat!(
            "fn f() {\n",
            "    let mut m: BTreeMap<usize, f64> = BTreeMap::new();\n",
            "    for (k, v) in &m { use_it(k, v); }\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/track.rs", src).is_empty());
    }

    #[test]
    fn r5_lookup_without_iteration_is_fine() {
        let src = concat!(
            "fn f() {\n",
            "    let m: HashMap<usize, f64> = HashMap::new();\n",
            "    let x = m.get(&3).copied().unwrap_or(0.0);\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/track.rs", src).is_empty());
    }

    // ---- R6 ----

    #[test]
    fn r6_flags_unwrap_in_pool_closures() {
        let src = concat!(
            "fn submit(pool: &ThreadPool, rx: Receiver<J>) {\n",
            "    pool.execute(move || {\n",
            "        let job = rx.recv().unwrap();\n",
            "        job.run();\n",
            "    });\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/jobs.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "panic-in-worker");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn r6_flags_panic_in_worker_loop_fns() {
        let src = concat!(
            "fn device_worker_loop(rx: &R) {\n",
            "    loop {\n",
            "        let Some(cmd) = rx.next() else { panic!(\"torn queue\") };\n",
            "        cmd.run().expect(\"cmd\");\n",
            "    }\n",
            "}\n",
        );
        let v = scan("rust/src/runtime/dev.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|v| v.rule == "panic-in-worker"));
    }

    #[test]
    fn r6_ignores_unwrap_outside_worker_contexts() {
        let src = "fn setup() { let cfg = load().unwrap(); }\n";
        assert!(scan("rust/src/coordinator/jobs.rs", src).is_empty());
    }

    #[test]
    fn r6_allow_annotation() {
        let src = concat!(
            "fn submit(pool: &ThreadPool) {\n",
            "    pool.execute(move || {\n",
            "        // invariant: slot filled by construction. lint:allow(panic-in-worker)\n",
            "        let v = slot.take().unwrap();\n",
            "    });\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/jobs.rs", src).is_empty());
    }

    // ---- R7 ----

    #[test]
    fn r7_flags_pool_size_reads_in_linalg() {
        let src = concat!(
            "fn partition(total: usize, pool: &ThreadPool) -> usize {\n",
            "    let n_chunks = (total / 64).max(pool.size());\n",
            "    n_chunks\n",
            "}\n",
        );
        let v = scan("rust/src/linalg/split.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "pool-shape-partition");
        // The same read outside linalg/ is not this rule's business.
        assert!(scan("rust/src/util/threadpool.rs", src).is_empty());
    }

    #[test]
    fn r7_flags_available_parallelism() {
        let src = "fn chunks() -> usize { std::thread::available_parallelism().unwrap().get() }\n";
        let v = scan("rust/src/linalg/split.rs", src);
        assert!(v.iter().any(|v| v.rule == "pool-shape-partition"), "{v:?}");
    }

    #[test]
    fn r7_shape_derived_partition_is_clean() {
        let src = concat!(
            "const K_CHUNK: usize = 64;\n",
            "fn partition(k: usize) -> usize { k.div_ceil(K_CHUNK) }\n",
        );
        assert!(scan("rust/src/linalg/split.rs", src).is_empty());
    }
}
