//! The fourteen static rules, matched over the structural model and
//! the crate-wide dataflow summaries.
//!
//! | Rule | Contract |
//! |---|---|
//! | R1 `lock-unwrap` | no poisoning `.lock().unwrap()` / `.expect(…)` (or condvar-wait equivalents) — shed poison via `util::sync` |
//! | R2 `instant-in-decide` | no `Instant::now()` in decide-critical sections: anywhere in `rank_controller.rs`, or while a shard-lock guard is live (crate-wide) |
//! | R3 `raw-mpsc` | no `std::sync::mpsc` outside `coordinator/completion.rs` |
//! | R4 `lock-order` | the lock-acquisition graph (lock taken while another guard is live, propagated to a fixed point over the crate call graph) must be acyclic |
//! | R5 `nondet-iter` | no `HashMap`/`HashSet` iteration in bit-identity-critical modules (`coordinator/`, `linalg/`, `conformance/`) |
//! | R6 `panic-in-worker` | no `unwrap()` / `expect(…)` / `panic!` inside thread-pool closures or worker-loop fns (advisory in test code) |
//! | R7 `pool-shape-partition` | no pool-size / thread-count reads inside `linalg/` — chunk partitions are pure functions of problem shape |
//! | R8 `blocking-under-lock` | no blocking operation (condvar/ticket wait, channel recv, sleep, pool dispatch, blocking IO) reachable — directly or through resolved calls — while a shard guard is live |
//! | R9 `charge-at-bucket` | FLOPs-ledger charge widths must derive from `rank_bucket(..)` (the PR 5 `Fixed(40)` → 48 bug class) |
//! | R10 `ticket-resolve` | a fn that binds a reply handle must resolve or move it before any `?` / `return` early exit |
//! | R11 `allow-rationale` | every `lint:allow(<rule>)` marker carries a non-empty rationale in its comment block |
//! | R12 `span-fidelity` | every diagnostic span is byte-accurate (engine self-check via [`verify_spans`]) |
//! | R13 `nondet-partition` | no nondeterministic value (wall clock, pool size/worker index, unordered iteration, racing channel receive) may shape chunk-partition arithmetic or a scoped dispatch wave in `coordinator/`, `linalg/`, `conformance/` |
//! | R14 `nondet-decide` | no nondeterministic value may flow into a `decide_step(..)` argument, crate-wide |
//!
//! Severity: findings in `rust/src/` are [`Level::Error`]; findings in
//! test, bench and example files are [`Level::Advisory`], as are R6
//! findings inside `#[cfg(test)]` code (the only rule that still runs
//! there — everything else skips Src test code, while in tests/benches/
//! examples files the test mask is ignored or the whole file would be
//! silenced). Every rule honors a `lint:allow(<rule>)` annotation in a
//! comment on the flagged line or in the contiguous comment block
//! directly above it; R11 polices the markers themselves.
//!
//! The interprocedural rules (R4, R8, R13, R14) seed per-fn facts from
//! each file's structural model and run
//! [`dataflow::propagate`](super::dataflow::propagate) over the
//! [`CallGraph`] to a fixed point ([`AnalysisOptions::lock_depth`]
//! caps the depth; `Some(1)` reproduces the PR 8 one-level analyzer
//! for regression tests). Diagnostics from propagated facts print the
//! complete call chain with file:line spans.
//!
//! By default the call graph is built with type-aware receiver
//! resolution ([`AnalysisOptions::receiver_types`]): non-`self`
//! receivers (`other.helper()`, `self.field.method()`,
//! `param.dispatch()`) resolve through the
//! [`types`](super::types) map, so lock-set and taint facts flow
//! through edges the name-only PR 9 graph could not see. Setting the
//! flag to `false` restores the name-only graph — the regression
//! fixtures use the contrast to prove the added recall.

use super::callgraph::{innermost_fn, CallGraph};
use super::dataflow::{propagate, seed, Fact, FactMap};
use super::model::{receiver_path, FileModel, FnInfo, SCOPED_CLOSURE_METHODS};
use super::types::{FileTypes, TypeMap};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

/// Finding severity. Errors gate CI; advisories are informational.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Advisory,
    Error,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Advisory => "advisory",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What kind of source tree a scanned file belongs to, by path
/// component. Non-`Src` files run every rule at advisory level with
/// the `#[cfg(test)]` mask ignored (a `tests/*.rs` file is all test
/// code; masking it would silence the scan entirely).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileKind {
    Src,
    Tests,
    Benches,
    Examples,
}

impl FileKind {
    pub fn of(path: &Path) -> FileKind {
        for c in path.components() {
            let c = c.as_os_str();
            if c == "tests" {
                return FileKind::Tests;
            }
            if c == "benches" {
                return FileKind::Benches;
            }
            if c == "examples" {
                return FileKind::Examples;
            }
        }
        FileKind::Src
    }
}

/// One rule violation at a source location.
///
/// Span invariant (policed by R12): `snippet` equals the source bytes
/// `byte_start..byte_end`, `line` is 1 + the number of newlines before
/// `byte_start`, and `col` is the 1-based byte column of `byte_start`
/// on that line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintViolation {
    pub file: PathBuf,
    /// 1-based line number of the span start.
    pub line: usize,
    /// 1-based byte column of the span start.
    pub col: usize,
    /// Byte offset where the flagged span starts.
    pub byte_start: usize,
    /// Byte offset one past the flagged span's end.
    pub byte_end: usize,
    /// The exact source text of the span.
    pub snippet: String,
    pub rule: &'static str,
    pub level: Level,
    pub text: String,
    /// Mechanical replacement for the span, when one exists.
    pub suggestion: Option<String>,
}

impl fmt::Display for LintViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: {} [{}] {}",
            self.file.display(),
            self.line,
            self.col,
            self.level,
            self.rule,
            self.text.trim()
        )
    }
}

/// Catalogue entry for one rule. One table drives the `--json` rule
/// list, the SARIF `rules` metadata, and `drrl lint --explain <rule>`,
/// so the three renderings cannot drift.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    pub name: &'static str,
    pub contract: &'static str,
    /// A minimal violating snippet (shown by `--explain` and as the
    /// SARIF `fullDescription`).
    pub example: &'static str,
    /// How to suppress a justified exception (shown by `--explain` and
    /// as the SARIF `help` text). Every marker needs an R11 rationale.
    pub suppression: &'static str,
}

/// The rule catalogue, R1–R14 in order.
pub const RULES: [RuleInfo; 14] = [
    RuleInfo {
        name: "lock-unwrap",
        contract: "no poisoning .lock()/.read()/.write()/.wait(..) unwrap/expect on sync \
                   primitives; shed poison via util::sync::{LockExt, CondvarExt}",
        example: "let g = self.state.lock().unwrap();",
        suppression: "// <why poisoning is acceptable here>. lint:allow(lock-unwrap)",
    },
    RuleInfo {
        name: "instant-in-decide",
        contract: "no Instant::now() in decide-critical sections (rank_controller.rs, or \
                   while a shard-lock guard is live anywhere in the crate)",
        example: "let t0 = Instant::now(); // inside rank_controller.rs",
        suppression: "// <why this read cannot reach a decision>. \
                      lint:allow(instant-in-decide)",
    },
    RuleInfo {
        name: "raw-mpsc",
        contract: "no std::sync::mpsc outside coordinator/completion.rs; annotated \
                   exceptions only",
        example: "use std::sync::mpsc; // outside coordinator/completion.rs",
        suppression: "// <why completion.rs cannot own this channel>. lint:allow(raw-mpsc)",
    },
    RuleInfo {
        name: "lock-order",
        contract: "the crate-wide lock acquisition graph (lock B taken while guard A is \
                   live, propagated to a fixed point over the call graph) must have no \
                   cycles",
        example: "fn a() { let g = x.lock(); y.lock(); } fn b() { let g = y.lock(); \
                  x.lock(); }",
        suppression: "// <why these orders cannot interleave>. lint:allow(lock-order) — \
                      prefer fixing the order",
    },
    RuleInfo {
        name: "nondet-iter",
        contract: "no HashMap/HashSet iteration inside bit-identity-critical modules \
                   (coordinator/, linalg/, conformance/)",
        example: "for (k, v) in map.iter() { merge(k, v); } // map: HashMap, in linalg/",
        suppression: "// <why order cannot reach an output>. lint:allow(nondet-iter) — \
                      or switch to BTreeMap",
    },
    RuleInfo {
        name: "panic-in-worker",
        contract: "no unwrap()/expect(..)/panic! inside thread-pool closures or worker \
                   loops (advisory in test code)",
        example: "pool.execute(move || { job.run().unwrap(); });",
        suppression: "// <why a poisoned worker is preferable>. lint:allow(panic-in-worker)",
    },
    RuleInfo {
        name: "pool-shape-partition",
        contract: "no pool-size/thread-count reads inside linalg/; chunk partitions are \
                   pure functions of problem shape",
        example: "let chunk = rows.len() / pool.size(); // inside linalg/",
        suppression: "// <why the result stays shape-pure>. lint:allow(pool-shape-partition)",
    },
    RuleInfo {
        name: "blocking-under-lock",
        contract: "no blocking operation (condvar/ticket wait, channel recv, sleep, pool \
                   dispatch, blocking IO) reachable while a shard-lock guard is live, \
                   through any depth of resolved calls",
        example: "let g = shard.lock_unpoisoned(); rx.recv(); // or any call that recvs",
        suppression: "// <why the wait cannot deadlock the shard>. \
                      lint:allow(blocking-under-lock)",
    },
    RuleInfo {
        name: "charge-at-bucket",
        contract: "every FLOPs-ledger charge site derives its width argument from \
                   rank_bucket(..), never from a raw rank",
        example: "ledger.charge_probe(rank, seq); // rank not derived from rank_bucket(..)",
        suppression: "// <why this width is already bucketed>. lint:allow(charge-at-bucket)",
    },
    RuleInfo {
        name: "ticket-resolve",
        contract: "a fn that binds a reply handle resolves or moves it before any ?/return \
                   early exit, so ticket outcomes stay explicit on every path",
        example: "let ticket = queue.submit(job); let cfg = load()?; ticket.resolve(cfg);",
        suppression: "// <who resolves the ticket on the early path>. \
                      lint:allow(ticket-resolve)",
    },
    RuleInfo {
        name: "allow-rationale",
        contract: "every lint:allow(<rule>) marker carries a non-empty rationale in its \
                   comment block",
        example: "// lint:allow(nondet-iter)  <- marker with no stated reason",
        suppression: "not suppressible — write the rationale instead",
    },
    RuleInfo {
        name: "span-fidelity",
        contract: "every diagnostic carries a byte-accurate span (snippet, line and col \
                   agree with the source bytes); self-check emitted by the engine",
        example: "an emitted finding whose snippet != source[byte_start..byte_end]",
        suppression: "not suppressible — an R12 finding is an analyzer bug; file it",
    },
    RuleInfo {
        name: "nondet-partition",
        contract: "no nondeterministic value (wall clock, pool size/worker index, \
                   HashMap/HashSet iteration, racing channel receive) may shape \
                   chunk-partition arithmetic or a scoped dispatch wave in coordinator/, \
                   linalg/ or conformance/ — partitions are pure functions of problem \
                   shape",
        example: "let lanes = pool.size(); for w in work.chunks(lanes) { .. }",
        suppression: "// <why the partition stays bit-identical across pool shapes>. \
                      lint:allow(nondet-partition)",
    },
    RuleInfo {
        name: "nondet-decide",
        contract: "no nondeterministic value (wall clock, pool size/worker index, \
                   HashMap/HashSet iteration, racing channel receive) may flow into a \
                   decide_step(..) argument — rank decisions must replay bit-identically \
                   across worker counts and schedules",
        example: "let budget = t0.elapsed(); ctl.decide_step(ctx, budget);",
        suppression: "// <why the input cannot alter the decision>. \
                      lint:allow(nondet-decide)",
    },
];

/// Knobs for [`analyze_crate_with`].
#[derive(Debug, Clone, Copy)]
pub struct AnalysisOptions {
    /// How many call hops a lock/blocking fact may travel: `None`
    /// (default) runs the dataflow engine to a fixed point; `Some(1)`
    /// reproduces the PR 8 one-level analyzer (regression tests use it
    /// to prove what the old analyzer missed).
    pub lock_depth: Option<usize>,
    /// Resolve non-`self` receivers through the type map (default).
    /// `false` restores the PR 9 name-only call graph; the planted
    /// cross-receiver fixtures use the contrast to prove the typed
    /// graph's added recall.
    pub receiver_types: bool,
}

impl Default for AnalysisOptions {
    fn default() -> AnalysisOptions {
        AnalysisOptions { lock_depth: None, receiver_types: true }
    }
}

/// Analysis context for one file.
struct Ctx {
    path: PathBuf,
    kind: FileKind,
    model: FileModel,
    src: String,
    lines: Vec<String>,
}

impl Ctx {
    fn new(path: PathBuf, source: &str) -> Ctx {
        Ctx {
            kind: FileKind::of(&path),
            model: FileModel::build(source),
            src: source.to_string(),
            lines: source.lines().map(String::from).collect(),
            path,
        }
    }

    fn file_name(&self) -> &str {
        self.path.file_name().and_then(|n| n.to_str()).unwrap_or("")
    }

    /// Is the file inside `rust/src/<module>/` (by path component)?
    fn in_module(&self, module: &str) -> bool {
        self.path.components().any(|c| c.as_os_str() == module)
    }

    fn line_text(&self, line: usize) -> String {
        self.lines.get(line.saturating_sub(1)).cloned().unwrap_or_default()
    }

    /// Byte offset where 1-based `line` begins.
    fn line_start_byte(&self, line: usize) -> usize {
        if line <= 1 {
            return 0;
        }
        let mut seen = 1usize;
        for (off, b) in self.src.bytes().enumerate() {
            if b == b'\n' {
                seen += 1;
                if seen == line {
                    return off + 1;
                }
            }
        }
        self.src.len()
    }

    /// Test-masked for rule gating. Only meaningful in `Src` files — in
    /// tests/benches/examples everything is test code and the file
    /// already runs at advisory level, so masking there would silence
    /// the scan entirely.
    fn masked(&self, i: usize) -> bool {
        self.kind == FileKind::Src && self.model.in_test(i)
    }

    fn base_level(&self) -> Level {
        if self.kind == FileKind::Src {
            Level::Error
        } else {
            Level::Advisory
        }
    }

    /// Is `lint:allow(<rule>)` present on `line` or in the contiguous
    /// comment block directly above it? `aliases` supplements the rule
    /// name (e.g. the legacy `lint:allow(mpsc)` spelling).
    fn allowed(&self, line: usize, rule: &str, aliases: &[&str]) -> bool {
        let mut markers: Vec<String> = vec![format!("lint:allow({rule})")];
        markers.extend(aliases.iter().map(|a| format!("lint:allow({a})")));
        let has_marker = |text: &str| markers.iter().any(|m| text.contains(m.as_str()));
        // Same-line trailing comment.
        for c in &self.model.lexed.comments {
            if c.line <= line && line <= c.end_line && has_marker(&c.text) {
                return true;
            }
        }
        // Contiguous comment block ending directly above `line`: walk the
        // chain of comments whose spans stack without gaps.
        let mut want_end = line - 1;
        loop {
            let Some(c) = self.model.lexed.comments.iter().find(|c| c.end_line == want_end)
            else {
                return false;
            };
            if has_marker(&c.text) {
                return true;
            }
            if c.line == 0 {
                return false;
            }
            want_end = c.line - 1;
        }
    }

    /// Push a violation spanning tokens `i..=j` (no allow check).
    #[allow(clippy::too_many_arguments)]
    fn push_span(
        &self,
        out: &mut Vec<LintViolation>,
        i: usize,
        j: usize,
        rule: &'static str,
        level: Level,
        text: String,
        suggestion: Option<String>,
    ) {
        let lx = &self.model.lexed;
        let t = &lx.tokens[i];
        let end = lx.tokens[j.min(lx.tokens.len() - 1)].end.max(t.end);
        out.push(LintViolation {
            file: self.path.clone(),
            line: t.line,
            col: t.col,
            byte_start: t.start,
            byte_end: end,
            snippet: self.src.get(t.start..end).unwrap_or("").to_string(),
            rule,
            level,
            text,
            suggestion,
        });
    }

    /// Flag tokens `i..=j` unless an allow marker covers the line.
    /// `text` of `None` uses the trimmed source line.
    #[allow(clippy::too_many_arguments)]
    fn flag(
        &self,
        out: &mut Vec<LintViolation>,
        i: usize,
        j: usize,
        rule: &'static str,
        aliases: &[&str],
        level: Level,
        text: Option<String>,
        suggestion: Option<String>,
    ) {
        let line = self.model.lexed.tokens[i].line;
        if self.allowed(line, rule, aliases) {
            return;
        }
        let text = text.unwrap_or_else(|| self.line_text(line).trim().to_string());
        self.push_span(out, i, j, rule, level, text, suggestion);
    }

    fn flag_tok(&self, out: &mut Vec<LintViolation>, i: usize, rule: &'static str, aliases: &[&str]) {
        self.flag(out, i, i, rule, aliases, self.base_level(), None, None);
    }
}

/// Analyze one standalone source file (all file-local rules plus any
/// lock-order cycles visible within the file).
pub fn analyze_source(path: &Path, source: &str) -> Vec<LintViolation> {
    analyze_crate(&[(path.to_path_buf(), source.to_string())])
}

/// Analyze a set of files as one crate with default options (dataflow
/// to a fixed point).
pub fn analyze_crate(files: &[(PathBuf, String)]) -> Vec<LintViolation> {
    analyze_crate_with(files, AnalysisOptions::default())
}

/// Analyze a set of files as one crate: every file-local rule per file,
/// plus the interprocedural rules (R4, R8, R13, R14) over the crate
/// call graph, plus the R12 span self-check over everything emitted.
pub fn analyze_crate_with(files: &[(PathBuf, String)], opts: AnalysisOptions) -> Vec<LintViolation> {
    let ctxs: Vec<Ctx> = files.iter().map(|(p, s)| Ctx::new(p.clone(), s)).collect();
    let models: Vec<&FileModel> = ctxs.iter().map(|c| &c.model).collect();
    let graph = if opts.receiver_types {
        let types: Vec<FileTypes> = models.iter().map(|m| FileTypes::build(m)).collect();
        let type_map = TypeMap::build(&models, &types);
        CallGraph::build_with(&models, Some((&types, &type_map)))
    } else {
        CallGraph::build(&models)
    };
    let mut out = Vec::new();
    for ctx in &ctxs {
        r1_lock_unwrap(ctx, &mut out);
        r2_instant_in_decide(ctx, &mut out);
        r3_raw_mpsc(ctx, &mut out);
        r5_nondet_iter(ctx, &mut out);
        r6_panic_in_worker(ctx, &mut out);
        r7_pool_shape_partition(ctx, &mut out);
        r9_charge_at_bucket(ctx, &mut out);
        r10_ticket_resolve(ctx, &mut out);
        r11_allow_rationale(ctx, &mut out);
    }
    r4_lock_order(&ctxs, &graph, opts, &mut out);
    r8_blocking_under_lock(&ctxs, &graph, opts, &mut out);
    r13_r14_nondet_taint(&ctxs, &graph, opts, &mut out);
    let fidelity = verify_spans(files, &out);
    out.extend(fidelity);
    out.sort_by(|a, b| (&a.file, a.line, a.col, a.rule).cmp(&(&b.file, b.line, b.col, b.rule)));
    out
}

/// Skip-matching over a balanced `(…)` group starting at open paren `i`;
/// returns the index of the matching `)`.
fn matching_paren(m: &FileModel, i: usize) -> Option<usize> {
    let lx = &m.lexed;
    let mut depth = 0i64;
    let mut j = i;
    while j < lx.tokens.len() {
        if lx.punct(j, '(') {
            depth += 1;
        } else if lx.punct(j, ')') {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j += 1;
    }
    None
}

/// R1 — poisoning unwrap/expect on lock, rwlock and condvar-wait
/// results, crate-wide outside test code. Carries a mechanical fix
/// where `util::sync` has the drop-in unpoisoned variant.
fn r1_lock_unwrap(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let m = &ctx.model;
    let lx = &m.lexed;
    for i in 1..lx.tokens.len() {
        if ctx.masked(i) || !lx.punct(i - 1, '.') {
            continue;
        }
        let Some(name) = lx.ident(i) else { continue };
        // Last token of a `.unwrap()` / `.expect(…)` tail after `after`.
        let poisoning_tail = |after: usize| -> Option<usize> {
            if !lx.punct(after, '.') {
                return None;
            }
            if lx.ident(after + 1) == Some("unwrap")
                && lx.punct(after + 2, '(')
                && lx.punct(after + 3, ')')
            {
                return Some(after + 3);
            }
            if lx.ident(after + 1) == Some("expect") && lx.punct(after + 2, '(') {
                return matching_paren(m, after + 2);
            }
            None
        };
        let hit: Option<(usize, Option<String>)> = match name {
            // `.lock().unwrap()` and friends: empty argument lists.
            "lock" | "read" | "write" | "try_lock" => {
                if lx.punct(i + 1, '(') && lx.punct(i + 2, ')') {
                    poisoning_tail(i + 3).map(|end| {
                        let fix = (name == "lock" && lx.ident(i + 4) == Some("unwrap"))
                            .then(|| "lock_unpoisoned()".to_string());
                        (end, fix)
                    })
                } else {
                    None
                }
            }
            // `.wait(guard).unwrap()` / `.wait_timeout(guard, d).expect(…)`.
            "wait" | "wait_timeout" => {
                if lx.punct(i + 1, '(') {
                    matching_paren(m, i + 1).and_then(|close| {
                        poisoning_tail(close + 1).map(|end| {
                            let fix = (lx.ident(close + 2) == Some("unwrap")).then(|| {
                                let args = ctx
                                    .src
                                    .get(lx.tokens[i + 1].start..lx.tokens[close].end)
                                    .unwrap_or("(..)");
                                format!("{name}_unpoisoned{args}")
                            });
                            (end, fix)
                        })
                    })
                } else {
                    None
                }
            }
            _ => None,
        };
        if let Some((end, suggestion)) = hit {
            ctx.flag(out, i, end, "lock-unwrap", &[], ctx.base_level(), None, suggestion);
        }
    }
}

/// Token index sequence of `Instant::now`.
fn is_instant_now(m: &FileModel, i: usize) -> bool {
    let lx = &m.lexed;
    lx.ident(i) == Some("Instant")
        && lx.punct(i + 1, ':')
        && lx.punct(i + 2, ':')
        && lx.ident(i + 3) == Some("now")
}

/// R2 — wall-clock reads in decide-critical sections: any non-test
/// `Instant::now` in `rank_controller.rs`, or — crate-wide — one
/// evaluated while a shard-lock guard is live.
fn r2_instant_in_decide(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let m = &ctx.model;
    let whole_file = ctx.file_name() == "rank_controller.rs";
    for i in 0..m.lexed.tokens.len() {
        if ctx.masked(i) || !is_instant_now(m, i) {
            continue;
        }
        let in_shard_guard = m
            .live_guards_at(i)
            .iter()
            .any(|g| g.name.contains("shard") || g.path.contains("shard"));
        if whole_file || in_shard_guard {
            ctx.flag(out, i, i + 3, "instant-in-decide", &[], ctx.base_level(), None, None);
        }
    }
}

/// R3 — raw std channels outside the completion layer, crate-wide.
fn r3_raw_mpsc(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if ctx.file_name() == "completion.rs" {
        return;
    }
    let m = &ctx.model;
    let mut last_line = 0usize;
    for i in 0..m.lexed.tokens.len() {
        if ctx.masked(i) || m.lexed.ident(i) != Some("mpsc") {
            continue;
        }
        let line = m.lexed.tokens[i].line;
        if line == last_line {
            continue; // one violation per line, as the old scanner did
        }
        last_line = line;
        ctx.flag_tok(out, i, "raw-mpsc", &["mpsc"]);
    }
}

/// One edge of the lock-order graph.
#[derive(Debug, Clone)]
struct LockEdge {
    from: String,
    to: String,
    /// Ctx index and token index of the site that created the edge.
    ci: usize,
    tok: usize,
    line: usize,
    /// Call chain rendering, when the edge came from propagation.
    via: Option<String>,
}

/// Render the call chain from a consumed call site to a propagated
/// fact's origin: `callee() -> hop() at file:line -> … -> <what> at
/// file:line`.
fn render_chain(callee: &str, fact: &Fact, what: &str, ctxs: &[Ctx]) -> String {
    let mut s = format!("{callee}()");
    for h in &fact.chain {
        s.push_str(&format!(" -> {}() at {}:{}", h.callee, ctxs[h.file].file_name(), h.line));
    }
    s.push_str(&format!(" -> {what} at {}:{}", ctxs[fact.file].file_name(), fact.line));
    s
}

/// R4 — cycles in the lock-acquisition order graph.
///
/// Nodes are lock identities (the receiver chain's final field name).
/// A direct edge `A → B` is recorded when `B` is acquired while a guard
/// of `A` is live in the same fn; a propagated edge when a fn is called
/// with `A` held and the callee's *transitive* summary (fixed-point
/// dataflow over the crate call graph, capped by
/// [`AnalysisOptions::lock_depth`]) acquires `B`. Any cycle — including
/// a self-loop, i.e. re-acquiring a lock of the same identity while it
/// is held — is a potential deadlock under some thread interleaving.
fn r4_lock_order(ctxs: &[Ctx], graph: &CallGraph, opts: AnalysisOptions, out: &mut Vec<LintViolation>) {
    // Seed each fn with its direct, non-detached, non-test, non-allowed
    // acquisitions, then let the dataflow engine fold them upward.
    let mut seeds: FactMap = FactMap::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.kind != FileKind::Src {
            continue;
        }
        let m = &ctx.model;
        for l in &m.locks {
            if l.detached || m.in_test(l.tok) || ctx.allowed(l.line, "lock-order", &[]) {
                continue;
            }
            let Some(fi) = innermost_fn(m, l.tok) else { continue };
            if m.fns[fi].is_test {
                continue;
            }
            seed(&mut seeds, (ci, fi), &l.name, ci, l.line);
        }
    }
    let summaries = propagate(graph, &seeds, opts.lock_depth);

    let mut edges: Vec<LockEdge> = Vec::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.kind != FileKind::Src {
            continue;
        }
        let m = &ctx.model;
        // Direct edges: acquisition under a live guard.
        for a in &m.locks {
            if m.in_test(a.tok) || ctx.allowed(a.line, "lock-order", &[]) {
                continue;
            }
            for g in m.live_guards_at(a.tok) {
                edges.push(LockEdge {
                    from: g.name.clone(),
                    to: a.name.clone(),
                    ci,
                    tok: a.tok,
                    line: a.line,
                    via: None,
                });
            }
        }
        // Propagated edges: resolved call made under a live guard whose
        // transitive summary acquires. The graph's edges carry the
        // resolution (name-matched free/`self.` calls, plus typed
        // receivers when `opts.receiver_types` is on), so iterating
        // them — instead of re-resolving `m.calls` by name — lets lock
        // facts flow through `other.helper()`-shaped calls too.
        // One edge per (call site, lock key) regardless of how many
        // same-named targets the site resolved to.
        let mut seen_keys: BTreeSet<(usize, String)> = BTreeSet::new();
        for (&(emi, _efi), ecalls) in &graph.calls_from {
            if emi != ci {
                continue;
            }
            for rc in ecalls {
                if ctx.allowed(rc.line, "lock-order", &[]) {
                    continue;
                }
                let held = m.live_guards_at(rc.tok);
                if held.is_empty() {
                    continue;
                }
                let Some(facts) = summaries.get(&rc.callee) else { continue };
                for f in facts.values() {
                    if !seen_keys.insert((rc.tok, f.key.clone())) {
                        continue;
                    }
                    let via =
                        render_chain(&rc.callee_name, f, &format!("{} acquired", f.key), ctxs);
                    for g in &held {
                        edges.push(LockEdge {
                            from: g.name.clone(),
                            to: f.key.clone(),
                            ci,
                            tok: rc.tok,
                            line: rc.line,
                            via: Some(via.clone()),
                        });
                    }
                }
            }
        }
    }

    // Dedup to one representative edge per (from, to).
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    let mut rep: BTreeMap<(&str, &str), &LockEdge> = BTreeMap::new();
    for e in &edges {
        adj.entry(e.from.as_str()).or_default().insert(e.to.as_str());
        rep.entry((e.from.as_str(), e.to.as_str())).or_insert(e);
    }

    // For every edge A→B, a path B→…→A closes a cycle. Self-loops are
    // the degenerate case. Report each distinct node set once.
    let mut reported: BTreeSet<Vec<&str>> = BTreeSet::new();
    for (&(from, to), _) in rep.iter() {
        let Some(path) = find_path(&adj, to, from) else { continue };
        // Cycle nodes: from → to → … (the path ends back at `from`; drop
        // that duplicate so the wrap-around edge closes the cycle).
        let mut nodes: Vec<&str> = Vec::with_capacity(path.len() + 1);
        nodes.push(from);
        nodes.extend(path.iter().copied());
        if nodes.len() > 1 && nodes.last() == Some(&from) {
            nodes.pop();
        }
        let mut key: Vec<&str> = nodes.clone();
        key.sort_unstable();
        key.dedup();
        if !reported.insert(key) {
            continue;
        }
        // Describe the cycle edge by edge with sites.
        let mut desc = String::from("lock-order cycle: ");
        for w in 0..nodes.len() {
            let a = nodes[w];
            let b = nodes[(w + 1) % nodes.len()];
            if w > 0 {
                desc.push_str(" -> ");
            }
            if let Some(e) = rep.get(&(a, b)) {
                let via = e.via.as_deref().map(|v| format!(" via {v}")).unwrap_or_default();
                desc.push_str(&format!("{a} ({}:{}{via})", ctxs[e.ci].file_name(), e.line));
            } else {
                desc.push_str(a);
            }
        }
        desc.push_str(" — potential deadlock");
        let first = rep[&(from, to)];
        ctxs[first.ci].push_span(
            out,
            first.tok,
            first.tok,
            "lock-order",
            Level::Error,
            desc,
            None,
        );
    }
}

/// BFS path from `start` to `goal` over the adjacency map. Returns the
/// node sequence `[start, …, goal]` (singleton when `start == goal` and
/// a self-loop exists is handled by the caller's edge iteration).
fn find_path<'a>(
    adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    start: &'a str,
    goal: &str,
) -> Option<Vec<&'a str>> {
    let mut prev: BTreeMap<&str, &str> = BTreeMap::new();
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(start);
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    seen.insert(start);
    while let Some(n) = queue.pop_front() {
        if n == goal {
            let mut path = vec![n];
            let mut cur = n;
            while let Some(&p) = prev.get(cur) {
                path.push(p);
                cur = p;
            }
            path.reverse();
            return Some(path);
        }
        for &nx in adj.get(n).into_iter().flatten() {
            if seen.insert(nx) {
                prev.insert(nx, n);
                queue.push_back(nx);
            }
        }
    }
    None
}

/// Methods whose call iterates an unordered container.
const ITER_METHODS: [&str; 10] = [
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain", "retain",
    "into_keys", "into_values",
];

/// Names bound to `HashMap`/`HashSet` in this file: `name: HashMap<…>`
/// (let ascription or struct field) and `let name = HashMap::…`.
/// Shared by R5 (iteration bans) and the R13/R14 taint sources.
fn unordered_names(m: &FileModel) -> BTreeSet<String> {
    let lx = &m.lexed;
    let mut unordered: BTreeSet<String> = BTreeSet::new();
    for i in 0..lx.tokens.len() {
        let Some(ty) = lx.ident(i) else { continue };
        if ty != "HashMap" && ty != "HashSet" {
            continue;
        }
        if i >= 2 && lx.punct(i - 1, ':') && !lx.punct(i - 2, ':') {
            if let Some(name) = lx.ident(i - 2) {
                unordered.insert(name.to_string());
            }
        }
        if i >= 2 && lx.punct(i - 1, '=') {
            if let Some(name) = lx.ident(i - 2) {
                unordered.insert(name.to_string());
            }
        }
    }
    unordered
}

/// R5 — unordered-container iteration in bit-identity-critical modules.
fn r5_nondet_iter(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if !(ctx.in_module("coordinator") || ctx.in_module("linalg") || ctx.in_module("conformance")) {
        return;
    }
    let m = &ctx.model;
    let lx = &m.lexed;
    let n = lx.tokens.len();

    let unordered = unordered_names(m);
    if unordered.is_empty() {
        return;
    }

    for i in 0..n {
        if ctx.masked(i) {
            continue;
        }
        // `name.iter()` / `.keys()` / `.drain()` … on a tracked name.
        if let Some(name) = lx.ident(i) {
            if unordered.contains(name) && lx.punct(i + 1, '.') {
                if let Some(meth) = lx.ident(i + 2) {
                    if ITER_METHODS.contains(&meth) && lx.punct(i + 3, '(') {
                        ctx.flag_tok(out, i, "nondet-iter", &[]);
                        continue;
                    }
                }
            }
        }
        // `for pat in [&][mut] name {` — direct iteration of the map.
        if lx.ident(i) == Some("for") {
            let mut j = i + 1;
            let mut depth = 0i64;
            while j < n && !(depth == 0 && lx.ident(j) == Some("in")) && !lx.punct(j, '{') {
                if lx.punct(j, '(') {
                    depth += 1;
                } else if lx.punct(j, ')') {
                    depth -= 1;
                }
                j += 1;
            }
            if j >= n || !matches!(lx.ident(j), Some("in")) {
                continue;
            }
            let mut k = j + 1;
            while k < n && (lx.punct(k, '&') || lx.ident(k) == Some("mut") || lx.punct(k, '(')) {
                k += 1;
            }
            if let Some(name) = lx.ident(k) {
                if unordered.contains(name) && (lx.punct(k + 1, '{') || lx.punct(k + 1, ')')) {
                    ctx.flag_tok(out, k, "nondet-iter", &[]);
                }
            }
        }
    }
}

/// R6 — panics inside worker contexts (thread-pool closures, worker-loop
/// fns). The only rule that still fires in test code — at advisory
/// level (a panicking test worker hangs the suite less politely than a
/// failing assert, but that's the test's business).
fn r6_panic_in_worker(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let m = &ctx.model;
    let lx = &m.lexed;
    for &(start, end) in &m.worker_regions {
        for i in start..=end.min(lx.tokens.len().saturating_sub(1)) {
            let Some(name) = lx.ident(i) else { continue };
            let hit_end = match name {
                "unwrap" => (i >= 1
                    && lx.punct(i - 1, '.')
                    && lx.punct(i + 1, '(')
                    && lx.punct(i + 2, ')'))
                .then_some(i + 2),
                "expect" => (i >= 1 && lx.punct(i - 1, '.') && lx.punct(i + 1, '('))
                    .then(|| matching_paren(m, i + 1).unwrap_or(i + 1)),
                "panic" | "todo" | "unimplemented" => lx.punct(i + 1, '!').then_some(i + 1),
                _ => None,
            };
            if let Some(j) = hit_end {
                let level =
                    if ctx.masked(i) { Level::Advisory } else { ctx.base_level() };
                ctx.flag(out, i, j, "panic-in-worker", &[], level, None, None);
            }
        }
    }
}

/// Identifiers whose mere appearance in `linalg/` reads a pool size or
/// thread count.
const POOL_SIZE_IDENTS: [&str; 5] =
    ["available_parallelism", "n_threads", "num_threads", "pool_threads", "n_workers"];

/// R7 — pool-size / thread-count reads inside `linalg/`: partitions must
/// be pure functions of problem shape (CONFORMANCE.md, PR 7 contract).
fn r7_pool_shape_partition(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if !ctx.in_module("linalg") {
        return;
    }
    let m = &ctx.model;
    let lx = &m.lexed;
    for i in 0..lx.tokens.len() {
        if ctx.masked(i) {
            continue;
        }
        let Some(name) = lx.ident(i) else { continue };
        let hit = POOL_SIZE_IDENTS.contains(&name)
            || (name == "size"
                && i >= 1
                && lx.punct(i - 1, '.')
                && lx.punct(i + 1, '(')
                && lx.punct(i + 2, ')')
                && receiver_path(lx, i - 1).iter().any(|p| p.to_lowercase().contains("pool")));
        if hit {
            ctx.flag_tok(out, i, "pool-shape-partition", &[]);
        }
    }
}

/// Identifiers that block the calling thread when invoked as a call:
/// condvar/ticket waits, channel receives, sleeps, pool dispatch
/// (scoped waves block until the pool drains; `execute`/`spawn` queue
/// behind a contended pool), and blocking IO. `join` and `flush` are
/// deliberately absent — `Path::join`/`slice::join` and formatter
/// `flush` collide with the names at token level.
const BLOCKING_IDENTS: [&str; 17] = [
    "wait",
    "wait_timeout",
    "wait_unpoisoned",
    "wait_timeout_unpoisoned",
    "recv",
    "recv_timeout",
    "recv_deadline",
    "sleep",
    "park",
    "execute",
    "spawn",
    "scoped_for",
    "scoped_map",
    "chunked_for",
    "read_to_string",
    "read_line",
    "write_all",
];

/// Is token `i` a call of a blocking identifier (`name(…)`, not a
/// definition `fn name(…)`)?
fn is_blocking_call(m: &FileModel, i: usize) -> bool {
    let lx = &m.lexed;
    let Some(name) = lx.ident(i) else { return false };
    BLOCKING_IDENTS.contains(&name)
        && lx.punct(i + 1, '(')
        && !(i >= 1 && lx.ident(i - 1) == Some("fn"))
}

/// R8 — blocking operations reachable while a shard-lock guard is live:
/// directly in the guard region, or transitively through resolved calls
/// (fixed-point dataflow, same engine and depth cap as R4). A decide
/// shard is the pipeline's serialization point — anything that parks
/// the thread there stalls every request on the shard.
fn r8_blocking_under_lock(
    ctxs: &[Ctx],
    graph: &CallGraph,
    opts: AnalysisOptions,
    out: &mut Vec<LintViolation>,
) {
    let shard_guard_live = |m: &FileModel, i: usize| {
        m.live_guards_at(i)
            .iter()
            .any(|g| g.name.contains("shard") || g.path.contains("shard"))
    };
    // Direct sites + per-fn seeds.
    let mut seeds: FactMap = FactMap::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.kind != FileKind::Src {
            continue;
        }
        let m = &ctx.model;
        let lx = &m.lexed;
        for i in 0..lx.tokens.len() {
            if m.in_test(i) || !is_blocking_call(m, i) {
                continue;
            }
            let name = lx.ident(i).unwrap_or_default();
            if shard_guard_live(m, i) && !ctx.allowed(lx.tokens[i].line, "blocking-under-lock", &[])
            {
                let text = format!(
                    "blocking `{name}(..)` while a shard guard is live: {}",
                    ctx.line_text(lx.tokens[i].line).trim()
                );
                ctx.push_span(
                    out,
                    i,
                    i,
                    "blocking-under-lock",
                    ctx.base_level(),
                    text,
                    None,
                );
            }
            // Seed the owning fn unless the op runs on a detached thread
            // (an execute/spawn closure body blocks its worker, not the
            // fn's caller — but the dispatch call itself, which sits
            // outside the closure body, still seeds).
            if m.detached_regions.iter().any(|&(s, e)| s <= i && i <= e) {
                continue;
            }
            if let Some(fi) = innermost_fn(m, i) {
                if !m.fns[fi].is_test {
                    seed(&mut seeds, (ci, fi), name, ci, lx.tokens[i].line);
                }
            }
        }
    }
    let summaries = propagate(graph, &seeds, opts.lock_depth);
    // Transitive sites: a resolved call under a live shard guard whose
    // callee summary contains a blocking fact. The graph's edges carry
    // the resolution (including typed non-`self` receivers), so the
    // facts reach sites like `other.helper()` too.
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.kind != FileKind::Src {
            continue;
        }
        let m = &ctx.model;
        let mut flagged: BTreeSet<(usize, String)> = BTreeSet::new();
        for (&(emi, _efi), ecalls) in &graph.calls_from {
            if emi != ci {
                continue;
            }
            for rc in ecalls {
                if !shard_guard_live(m, rc.tok)
                    || ctx.allowed(rc.line, "blocking-under-lock", &[])
                {
                    continue;
                }
                let Some(facts) = summaries.get(&rc.callee) else { continue };
                for f in facts.values() {
                    if !flagged.insert((rc.line, f.key.clone())) {
                        continue;
                    }
                    let text = format!(
                        "blocking `{}(..)` reachable while a shard guard is live: {}",
                        f.key,
                        render_chain(&rc.callee_name, f, &format!("{} blocks", f.key), ctxs)
                    );
                    ctx.push_span(
                        out,
                        rc.tok,
                        rc.tok,
                        "blocking-under-lock",
                        ctx.base_level(),
                        text,
                        None,
                    );
                }
            }
        }
    }
}

/// Worker-identity idents: reading *which* worker you are is as
/// nondeterministic as reading how many there are.
const WORKER_IDENT_IDENTS: [&str; 2] = ["worker_index", "worker_id"];

/// Channel receives that race: which message lands inside the window
/// depends on thread scheduling. Plain `recv()` is deliberately absent
/// — a single-consumer FIFO receive is ordered.
const RACING_RECV_METHODS: [&str; 3] = ["try_recv", "recv_timeout", "recv_deadline"];

/// Callees whose arguments carve chunk boundaries or partition a
/// dispatch wave (the R13 sinks).
const PARTITION_CALLEES: [&str; 5] =
    ["div_ceil", "split_at", "split_at_mut", "chunks", "chunks_exact"];

fn is_partition_callee(name: &str) -> bool {
    PARTITION_CALLEES.contains(&name)
        || name.contains("chunk")
        || name.contains("partition")
        || SCOPED_CLOSURE_METHODS.contains(&name)
}

/// Is token `i` a nondeterministic source? Returns the source kind.
///
/// * `wall-clock` — `Instant::now()`, `.elapsed()`;
/// * `pool-shape` — pool-size / thread-count / worker-identity reads
///   (the same surface R7 bans inside `linalg/`, here tracked as a
///   taint source crate-wide);
/// * `unordered-iter` — `ITER_METHODS` on a name bound to
///   `HashMap`/`HashSet` (shared harvest with R5);
/// * `channel-race` — `try_recv`/`recv_timeout`/`recv_deadline`.
fn taint_source_at(
    m: &FileModel,
    unordered: &BTreeSet<String>,
    i: usize,
) -> Option<&'static str> {
    let lx = &m.lexed;
    if is_instant_now(m, i) {
        return Some("wall-clock");
    }
    let name = lx.ident(i)?;
    if name == "elapsed" && i >= 1 && lx.punct(i - 1, '.') && lx.punct(i + 1, '(') {
        return Some("wall-clock");
    }
    if POOL_SIZE_IDENTS.contains(&name) || WORKER_IDENT_IDENTS.contains(&name) {
        return Some("pool-shape");
    }
    if name == "size"
        && i >= 1
        && lx.punct(i - 1, '.')
        && lx.punct(i + 1, '(')
        && lx.punct(i + 2, ')')
        && receiver_path(lx, i - 1).iter().any(|p| p.to_lowercase().contains("pool"))
    {
        return Some("pool-shape");
    }
    if RACING_RECV_METHODS.contains(&name) && lx.punct(i + 1, '(') {
        return Some("channel-race");
    }
    if ITER_METHODS.contains(&name) && i >= 2 && lx.punct(i - 1, '.') && lx.punct(i + 1, '(') {
        if let Some(head) = lx.ident(i - 2) {
            if unordered.contains(head) {
                return Some("unordered-iter");
            }
        }
    }
    None
}

/// Does `f`'s signature declare a return type? Scans from the close of
/// its parameter list to the body brace for a `->` (a `Fn() -> _` bound
/// in a where clause over-approximates — harmless, it only widens which
/// fns *may* export taint).
fn fn_has_return(m: &FileModel, f: &FnInfo) -> bool {
    let lx = &m.lexed;
    let mut j = f.sig + 2;
    if lx.punct(j, '<') {
        let mut depth = 0i64;
        while j < f.open {
            if lx.punct(j, '<') {
                depth += 1;
            } else if lx.punct(j, '>') && !lx.punct(j - 1, '-') {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
    }
    if !lx.punct(j, '(') {
        return false;
    }
    let Some(close) = matching_paren(m, j) else { return false };
    (close + 1..f.open).any(|k| lx.punct(k, '-') && lx.punct(k + 1, '>'))
}

/// One tainted let-binding: where the nondeterminism came from,
/// rendered for the finding text (`wall-clock source \`elapsed\` at
/// pipeline.rs:31`, or a propagated call chain).
#[derive(Debug, Clone)]
struct Taint {
    origin: String,
}

/// Why an initializer token range is tainted, if it is.
fn init_taint(
    ctx: &Ctx,
    lo: usize,
    hi: usize,
    unordered: &BTreeSet<String>,
    taints: &BTreeMap<String, Taint>,
    by_tok: &BTreeMap<usize, Vec<(String, Fact)>>,
    ctxs: &[Ctx],
) -> Option<Taint> {
    let m = &ctx.model;
    let lx = &m.lexed;
    for j in lo..hi {
        if let Some(kind) = taint_source_at(m, unordered, j) {
            return Some(Taint {
                origin: format!(
                    "{kind} source `{}` at {}:{}",
                    lx.tokens[j].text,
                    ctx.file_name(),
                    lx.tokens[j].line
                ),
            });
        }
        if let Some(id) = lx.ident(j) {
            if let Some(t) = taints.get(id) {
                return Some(t.clone());
            }
        }
        if let Some(hits) = by_tok.get(&j) {
            let (callee, fact) = &hits[0];
            return Some(Taint {
                origin: render_chain(callee, fact, &format!("{} source", fact.key), ctxs),
            });
        }
    }
    None
}

/// The tainted let-bindings of `f`'s body, to a local fixed point.
///
/// Taint enters through a source token, an already-tainted name, or a
/// call whose resolved callee's summary exports taint; it propagates
/// through `let name [: T] = init;` only (simple bindings — tuple and
/// struct patterns are not tracked). Fn-wide, not flow-sensitive: a
/// binding tainted anywhere in the body taints every use of the name.
fn fn_taints(
    ctx: &Ctx,
    f: &FnInfo,
    unordered: &BTreeSet<String>,
    by_tok: &BTreeMap<usize, Vec<(String, Fact)>>,
    ctxs: &[Ctx],
) -> BTreeMap<String, Taint> {
    let m = &ctx.model;
    let lx = &m.lexed;
    let mut taints: BTreeMap<String, Taint> = BTreeMap::new();
    loop {
        let mut changed = false;
        let mut i = f.open + 1;
        while i < f.close {
            if lx.ident(i) != Some("let") {
                i += 1;
                continue;
            }
            let mut j = i + 1;
            if lx.ident(j) == Some("mut") {
                j += 1;
            }
            // Simple bindings only: skip `let Some(x)` / `let (a, b)`.
            let Some(name) = lx.ident(j) else {
                i = j + 1;
                continue;
            };
            if name.starts_with(|ch: char| ch.is_ascii_uppercase()) || name == "_" {
                i = j + 1;
                continue;
            }
            if !(lx.punct(j + 1, '=') || (lx.punct(j + 1, ':') && !lx.punct(j + 2, ':'))) {
                i = j + 1;
                continue;
            }
            // Skip an optional `: Type` ascription to the `=`.
            let mut k = j + 1;
            let mut depth = 0i64;
            while k < f.close {
                if lx.punct(k, '(') || lx.punct(k, '[') || lx.punct(k, '<') {
                    depth += 1;
                } else if lx.punct(k, ')') || lx.punct(k, ']') {
                    depth -= 1;
                } else if lx.punct(k, '>') && !lx.punct(k - 1, '-') {
                    depth -= 1;
                } else if depth <= 0 && (lx.punct(k, '=') || lx.punct(k, ';') || lx.punct(k, '{'))
                {
                    break;
                }
                k += 1;
            }
            if !lx.punct(k, '=') {
                i = k + 1;
                continue;
            }
            // Initializer runs to the statement's `;` at depth 0.
            let lo = k + 1;
            let mut hi = lo;
            let mut d2 = 0i64;
            while hi < f.close {
                if lx.punct(hi, '(') || lx.punct(hi, '[') || lx.punct(hi, '{') {
                    d2 += 1;
                } else if lx.punct(hi, ')') || lx.punct(hi, ']') || lx.punct(hi, '}') {
                    d2 -= 1;
                    if d2 < 0 {
                        break;
                    }
                } else if d2 == 0 && lx.punct(hi, ';') {
                    break;
                }
                hi += 1;
            }
            if !taints.contains_key(name) {
                if let Some(t) = init_taint(ctx, lo, hi, unordered, &taints, by_tok, ctxs) {
                    taints.insert(name.to_string(), t);
                    changed = true;
                }
            }
            // Resume *inside* the initializer: block initializers
            // (`let x = { let t = now(); t };`) carry their own lets.
            i = k + 1;
        }
        if !changed {
            return taints;
        }
    }
}

/// R13 `nondet-partition` / R14 `nondet-decide` — determinism-taint
/// dataflow on the shared fixed-point engine.
///
/// This is *value* taint, not the lock rules' side-effect reachability,
/// and the difference drives three deliberate restrictions:
///
/// * only the value-like source kinds seed interprocedural facts
///   (`wall-clock`, `channel-race`). Pool-shape reads and unordered
///   iteration taint locally (a fn that *mentions* `n_workers` does not
///   make every caller's result nondeterministic — but a let bound to
///   it does);
/// * facts travel only through call sites that resolved to exactly ONE
///   fn. Name-fallback aliasing (every `new` in the crate) is the safe
///   over-approximation for lock side effects and exactly the wrong one
///   for values — `Vec::new()` must not launder a same-named
///   constructor's clock read;
/// * a fn exports its callees' facts only if its signature declares a
///   return type (nothing flows out of `fn f(..) { .. }` by value).
///
/// Seeds: every non-test `Src` fn with a return type whose body contains
/// a value-like source; [`propagate`] folds those over the restricted
/// graph. Locally, taint flows through simple let chains
/// ([`fn_taints`]). Sinks: partition arithmetic / scoped dispatch in
/// `coordinator/`, `linalg/`, `conformance/` (R13) and `decide_step(..)`
/// arguments crate-wide (R14) — a sink fires when a non-closure
/// argument contains a source token, a tainted name, or a call into an
/// exporting fn. Closure arguments are work bodies, not partition
/// arithmetic; their internals are analyzed at their own call sites.
fn r13_r14_nondet_taint(
    ctxs: &[Ctx],
    graph: &CallGraph,
    opts: AnalysisOptions,
    out: &mut Vec<LintViolation>,
) {
    let mut seeds: FactMap = FactMap::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        if ctx.kind != FileKind::Src {
            continue;
        }
        let m = &ctx.model;
        let unordered = unordered_names(m);
        for (fi, f) in m.fns.iter().enumerate() {
            if f.is_test || !fn_has_return(m, f) {
                continue;
            }
            for i in f.open + 1..f.close {
                if m.in_test(i) {
                    continue;
                }
                if let Some(kind @ ("wall-clock" | "channel-race")) =
                    taint_source_at(m, &unordered, i)
                {
                    seed(&mut seeds, (ci, fi), kind, ci, m.lexed.tokens[i].line);
                }
            }
        }
    }
    // Per call site: how many fns it resolved to. Value taint only
    // trusts unambiguous sites.
    let mut site_targets: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    for (&(cf, _), ecalls) in &graph.calls_from {
        for rc in ecalls {
            *site_targets.entry((cf, rc.tok)).or_default() += 1;
        }
    }
    let mut taint_edges: BTreeMap<super::callgraph::FnId, Vec<super::callgraph::ResolvedCall>> =
        BTreeMap::new();
    for (&caller, ecalls) in &graph.calls_from {
        let (cf, cfi) = caller;
        if !fn_has_return(&ctxs[cf].model, &ctxs[cf].model.fns[cfi]) {
            continue;
        }
        let kept: Vec<_> = ecalls
            .iter()
            .filter(|rc| site_targets.get(&(cf, rc.tok)) == Some(&1))
            .cloned()
            .collect();
        if !kept.is_empty() {
            taint_edges.insert(caller, kept);
        }
    }
    let taint_graph = CallGraph {
        nodes: graph.nodes.clone(),
        fns_by_name: graph.fns_by_name.clone(),
        calls_from: taint_edges,
    };
    let summaries = propagate(&taint_graph, &seeds, opts.lock_depth);

    for (ci, ctx) in ctxs.iter().enumerate() {
        let m = &ctx.model;
        let lx = &m.lexed;
        let unordered = unordered_names(m);
        let r13_scope = ctx.in_module("coordinator")
            || ctx.in_module("linalg")
            || ctx.in_module("conformance");

        // Call-site token → taint facts its resolved callee exports
        // (unambiguous, non-detached sites only).
        let mut by_tok: BTreeMap<usize, Vec<(String, Fact)>> = BTreeMap::new();
        for (&(emi, _efi), ecalls) in &graph.calls_from {
            if emi != ci {
                continue;
            }
            for rc in ecalls {
                if rc.detached || site_targets.get(&(ci, rc.tok)) != Some(&1) {
                    continue;
                }
                let Some(facts) = summaries.get(&rc.callee) else { continue };
                for f in facts.values() {
                    by_tok.entry(rc.tok).or_default().push((rc.callee_name.clone(), f.clone()));
                }
            }
        }

        for (fi, f) in m.fns.iter().enumerate() {
            let taints = fn_taints(ctx, f, &unordered, &by_tok, ctxs);
            for c in &m.calls {
                if c.tok <= f.open || c.tok >= f.close || ctx.masked(c.tok) {
                    continue;
                }
                if innermost_fn(m, c.tok) != Some(fi) {
                    continue;
                }
                let is_r14 = c.callee == "decide_step";
                let is_r13 = r13_scope && !is_r14 && is_partition_callee(&c.callee);
                if !is_r13 && !is_r14 {
                    continue;
                }
                let rule = if is_r14 { "nondet-decide" } else { "nondet-partition" };
                let Some(close) = matching_paren(m, c.tok + 1) else { continue };
                // First tainted argument: a source token, a tainted
                // name, or a call into a taint-exporting fn. Receivers
                // are deliberately not checked — `pool.scoped_for(n, f)`
                // partitions by `n`, not by the pool object, and every
                // pool traces back to a machine-sized constructor.
                let mut hit: Option<(String, String)> = None;
                'args: for (lo, hi) in split_args(m, c.tok + 1, close) {
                    // Closure arguments (`|i| work(i)`) are the work
                    // body, not a partition value; the calls inside
                    // them are scanned at their own sites.
                    let body = if lx.ident(lo) == Some("move") { lo + 1 } else { lo };
                    if lx.punct(body, '|') {
                        continue;
                    }
                    for j in lo..hi {
                        if let Some(kind) = taint_source_at(m, &unordered, j) {
                            hit = Some((
                                format!("`{}`", lx.tokens[j].text),
                                format!(
                                    "{kind} source at {}:{}",
                                    ctx.file_name(),
                                    lx.tokens[j].line
                                ),
                            ));
                            break 'args;
                        }
                        if let Some(t) = lx.ident(j).and_then(|id| taints.get(id)) {
                            hit = Some((format!("`{}`", lx.tokens[j].text), t.origin.clone()));
                            break 'args;
                        }
                        if let Some(hits) = by_tok.get(&j) {
                            let (callee, fact) = &hits[0];
                            hit = Some((
                                format!("`{callee}(..)`"),
                                render_chain(callee, fact, &format!("{} source", fact.key), ctxs),
                            ));
                            break 'args;
                        }
                    }
                }
                let Some((what, origin)) = hit else { continue };
                let text = if is_r14 {
                    format!("nondeterministic input {what} flows into decide_step(..): {origin}")
                } else {
                    format!(
                        "nondeterministic value {what} shapes a chunk partition via `{}(..)`: {origin}",
                        c.callee
                    )
                };
                ctx.flag(out, c.tok, close, rule, &[], ctx.base_level(), Some(text), None);
            }
        }
    }
}

/// FLOPs-ledger charge fns and the (0-based) argument positions that
/// carry a rank width. The width at a charge site must be a bucket
/// (`rank_bucket(..)` output), never a raw decided rank — the PR 5
/// `Fixed(40)` policy bug charged 40 while the kernel ran the 48-wide
/// bucket, and the ledger conservation check only caught it at runtime.
const CHARGE_FNS: [(&str, &[usize]); 3] = [
    ("lowrank_attention_flops", &[2]),
    ("partial_svd_flops", &[2]),
    ("incremental_svd_flops", &[2, 3]),
];

/// Split the argument list of a call (open paren at `open`, matching
/// close at `close`) into half-open token ranges, one per argument.
fn split_args(m: &FileModel, open: usize, close: usize) -> Vec<(usize, usize)> {
    let lx = &m.lexed;
    let mut args = Vec::new();
    let mut depth = 0i64;
    let mut start = open + 1;
    for j in open + 1..close {
        if lx.punct(j, '(') || lx.punct(j, '[') || lx.punct(j, '{') {
            depth += 1;
        } else if lx.punct(j, ')') || lx.punct(j, ']') || lx.punct(j, '}') {
            depth -= 1;
        } else if depth == 0 && lx.punct(j, ',') {
            args.push((start, j));
            start = j + 1;
        }
    }
    if start < close {
        args.push((start, close));
    }
    args
}

/// Does the argument token range `lo..hi` derive from a rank bucket —
/// mention `rank_bucket(..)` (or any `*bucket*` ident) inline, or name
/// a local whose `let` initializer does?
fn bucket_derived(ctx: &Ctx, lo: usize, hi: usize) -> bool {
    let lx = &ctx.model.lexed;
    for j in lo..hi {
        if lx.ident(j).is_some_and(|id| id.contains("bucket")) {
            return true;
        }
    }
    if hi == lo + 1 {
        if let Some(v) = lx.ident(lo) {
            return let_init_mentions_bucket(ctx, v);
        }
    }
    false
}

/// Is there a `let [mut] <v> = …;` in the file whose initializer
/// mentions a `*bucket*` ident?
fn let_init_mentions_bucket(ctx: &Ctx, v: &str) -> bool {
    let lx = &ctx.model.lexed;
    let n = lx.tokens.len();
    for i in 0..n {
        if lx.ident(i) != Some("let") {
            continue;
        }
        let mut j = i + 1;
        if lx.ident(j) == Some("mut") {
            j += 1;
        }
        if lx.ident(j) != Some(v) || !lx.punct(j + 1, '=') {
            continue;
        }
        let mut k = j + 2;
        while k < n && !lx.punct(k, ';') {
            if lx.ident(k).is_some_and(|id| id.contains("bucket")) {
                return true;
            }
            k += 1;
        }
    }
    false
}

/// R9 — FLOPs charge widths must derive from `rank_bucket(..)`.
/// Scoped to the serving stack (`coordinator/`, `runtime/`,
/// `conformance/`): the definitions in `flops.rs` and the RL reward
/// estimators legitimately take raw ranks.
fn r9_charge_at_bucket(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if ctx.kind != FileKind::Src
        || !(ctx.in_module("coordinator")
            || ctx.in_module("runtime")
            || ctx.in_module("conformance"))
    {
        return;
    }
    let m = &ctx.model;
    let lx = &m.lexed;
    for i in 0..lx.tokens.len() {
        if ctx.masked(i) {
            continue;
        }
        let Some(name) = lx.ident(i) else { continue };
        let Some(&(_, watched)) = CHARGE_FNS.iter().find(|(f, _)| *f == name) else {
            continue;
        };
        if !lx.punct(i + 1, '(') || (i >= 1 && lx.ident(i - 1) == Some("fn")) {
            continue;
        }
        let Some(close) = matching_paren(m, i + 1) else { continue };
        let args = split_args(m, i + 1, close);
        for &ai in watched {
            let Some(&(lo, hi)) = args.get(ai) else { continue };
            if !bucket_derived(ctx, lo, hi) {
                let text = format!(
                    "width argument {} of {name}(..) does not derive from rank_bucket(..)",
                    ai + 1
                );
                ctx.flag(out, i, close, "charge-at-bucket", &[], ctx.base_level(), Some(text), None);
                break;
            }
        }
    }
}

/// Methods that explicitly resolve a reply handle.
const RESOLVE_METHODS: [&str; 3] = ["post", "fulfill", "abandon"];

/// R10 — a fn that binds a reply handle (`GenReply` / `AttnReply` in a
/// `let` initializer) must resolve it — `.post(..)`/`.fulfill(..)`/
/// `.abandon(..)`, `drop(..)`, or a move (argument position, struct
/// field, return) — before any `?` or `return` early exit. The handles'
/// `Drop` backstop keeps tickets from hanging even on the flagged
/// paths, but an implicit abandon on an error path is exactly the kind
/// of outcome this rule wants stated in the source. Path-insensitive:
/// the first resolution or early exit in token order wins.
fn r10_ticket_resolve(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    if ctx.kind != FileKind::Src {
        return;
    }
    let m = &ctx.model;
    let lx = &m.lexed;
    for f in &m.fns {
        if f.is_test {
            continue;
        }
        let mut i = f.open;
        while i < f.close {
            if lx.ident(i) != Some("let") || m.in_test(i) {
                i += 1;
                continue;
            }
            let mut j = i + 1;
            if lx.ident(j) == Some("mut") {
                j += 1;
            }
            let (Some(v), true) = (lx.ident(j), lx.punct(j + 1, '=')) else {
                i += 1;
                continue;
            };
            // Find the statement end and look for a handle type in the
            // initializer.
            let mut k = j + 2;
            let mut depth = 0i64;
            let mut has_handle = false;
            while k < f.close {
                if lx.punct(k, '(') || lx.punct(k, '{') || lx.punct(k, '[') {
                    depth += 1;
                } else if lx.punct(k, ')') || lx.punct(k, '}') || lx.punct(k, ']') {
                    depth -= 1;
                } else if depth <= 0 && lx.punct(k, ';') {
                    break;
                }
                if matches!(lx.ident(k), Some("GenReply") | Some("AttnReply")) {
                    has_handle = true;
                }
                k += 1;
            }
            if has_handle && !ctx.allowed(lx.tokens[i].line, "ticket-resolve", &[]) {
                scan_handle_paths(ctx, v, k + 1, f.close, out);
            }
            i = k + 1;
        }
    }
}

/// Scan tokens `from..to` for the first resolution of handle `v` or the
/// first `?`/`return` early exit, flagging the exit if it comes first.
fn scan_handle_paths(
    ctx: &Ctx,
    v: &str,
    from: usize,
    to: usize,
    out: &mut Vec<LintViolation>,
) {
    let lx = &ctx.model.lexed;
    let mut r = from;
    while r < to {
        if lx.ident(r) == Some(v) && !(r >= 1 && lx.punct(r - 1, '.')) {
            // `v.post(..)` / `v.fulfill(..)` / `v.abandon(..)`.
            if lx.punct(r + 1, '.')
                && lx.ident(r + 2).is_some_and(|mth| RESOLVE_METHODS.contains(&mth))
                && lx.punct(r + 3, '(')
            {
                return;
            }
            // `drop(v)`.
            if r >= 2
                && lx.ident(r - 2) == Some("drop")
                && lx.punct(r - 1, '(')
                && lx.punct(r + 1, ')')
            {
                return;
            }
            // Moved out: argument position, struct field, reassignment,
            // or returned.
            let prev_ok = r >= 1
                && (lx.punct(r - 1, '(')
                    || lx.punct(r - 1, ',')
                    || lx.punct(r - 1, ':')
                    || lx.punct(r - 1, '='));
            let next_ok = lx.punct(r + 1, ')')
                || lx.punct(r + 1, ',')
                || lx.punct(r + 1, ';')
                || lx.punct(r + 1, '}');
            if prev_ok && next_ok {
                return;
            }
        }
        if lx.punct(r, '?') || lx.ident(r) == Some("return") {
            let text = format!(
                "early exit while reply handle `{v}` is unresolved — resolve, move, or \
                 drop(..) it first so the ticket outcome is explicit on this path"
            );
            ctx.flag(out, r, r, "ticket-resolve", &[], ctx.base_level(), Some(text), None);
            return;
        }
        r += 1;
    }
}

/// Strip every `lint:allow(<rule>)` marker from a comment group's text,
/// leaving whatever rationale surrounds them.
fn strip_allow_markers(text: &str) -> String {
    let mut s = text.to_string();
    while let Some(p) = s.find("lint:allow(") {
        let close = s[p..].find(')').map(|q| p + q + 1).unwrap_or(s.len());
        s.replace_range(p..close, "");
    }
    s
}

/// R11 — every `lint:allow(<rule>)` marker must carry a rationale:
/// after stripping the markers themselves, the contiguous comment block
/// they live in must still say something (≥ 10 alphanumeric chars).
fn r11_allow_rationale(ctx: &Ctx, out: &mut Vec<LintViolation>) {
    let comments = &ctx.model.lexed.comments;
    if comments.is_empty() {
        return;
    }
    // Line ranges of test-masked tokens: a marker inside Src test code
    // is gated with the rest of the test code.
    let mut masked_ranges: Vec<(usize, usize)> = Vec::new();
    if ctx.kind == FileKind::Src {
        let lx = &ctx.model.lexed;
        let mut run: Option<(usize, usize)> = None;
        for i in 0..lx.tokens.len() {
            if ctx.model.in_test(i) {
                let l = lx.tokens[i].line;
                run = Some(match run {
                    Some((a, _)) => (a, l),
                    None => (l, l),
                });
            } else if let Some(rg) = run.take() {
                masked_ranges.push(rg);
            }
        }
        if let Some(rg) = run {
            masked_ranges.push(rg);
        }
    }
    let mut gi = 0;
    while gi < comments.len() {
        // Contiguous comment group: each next comment starts no later
        // than the line after the previous one ends.
        let mut ge = gi;
        while ge + 1 < comments.len() && comments[ge + 1].line <= comments[ge].end_line + 1 {
            ge += 1;
        }
        let group_text: String = comments[gi..=ge]
            .iter()
            .map(|c| c.text.as_str())
            .collect::<Vec<_>>()
            .join("\n");
        if let Some(marker) =
            comments[gi..=ge].iter().find(|c| c.text.contains("lint:allow("))
        {
            let masked =
                masked_ranges.iter().any(|&(a, b)| a <= marker.line && marker.line <= b);
            let stripped = strip_allow_markers(&group_text);
            let said = stripped.chars().filter(|c| c.is_alphanumeric()).count();
            if !masked && said < 10 {
                let lt = ctx.line_text(marker.line);
                let sidx = lt.find("lint:allow(").unwrap_or(0);
                let eidx = lt[sidx..].find(')').map(|p| sidx + p + 1).unwrap_or(lt.len());
                let base = ctx.line_start_byte(marker.line);
                out.push(LintViolation {
                    file: ctx.path.clone(),
                    line: marker.line,
                    col: sidx + 1,
                    byte_start: base + sidx,
                    byte_end: base + eidx,
                    snippet: lt.get(sidx..eidx).unwrap_or("").to_string(),
                    rule: "allow-rationale",
                    level: ctx.base_level(),
                    text: "suppression without a rationale — say in the marker's comment \
                           block why it is sound"
                        .to_string(),
                    suggestion: None,
                });
            }
        }
        gi = ge + 1;
    }
}

/// R12 — verify the span invariant of every diagnostic against the
/// scanned sources: the snippet must equal the byte range, and line/col
/// must agree with the newlines before it. The engine calls this on its
/// own output (a clean run emits nothing); tests corrupt violations and
/// feed them back to prove the check bites.
pub fn verify_spans(
    files: &[(PathBuf, String)],
    violations: &[LintViolation],
) -> Vec<LintViolation> {
    let by_path: BTreeMap<&Path, &str> =
        files.iter().map(|(p, s)| (p.as_path(), s.as_str())).collect();
    let mut out = Vec::new();
    for v in violations {
        if v.rule == "span-fidelity" {
            continue;
        }
        let Some(&src) = by_path.get(v.file.as_path()) else { continue };
        let bytes = src.as_bytes();
        let mut problems: Vec<String> = Vec::new();
        if v.byte_start > v.byte_end || v.byte_end > bytes.len() {
            problems.push(format!("byte range {}..{} out of bounds", v.byte_start, v.byte_end));
        } else {
            if src.get(v.byte_start..v.byte_end) != Some(v.snippet.as_str()) {
                problems.push("snippet does not match the byte range".to_string());
            }
            let line = 1 + bytes[..v.byte_start].iter().filter(|&&b| b == b'\n').count();
            if line != v.line {
                problems.push(format!("line says {} but the span starts on line {line}", v.line));
            }
            let line_start =
                bytes[..v.byte_start].iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1);
            let col = v.byte_start - line_start + 1;
            if col != v.col {
                problems.push(format!("col says {} but the span starts at col {col}", v.col));
            }
        }
        if !problems.is_empty() {
            out.push(LintViolation {
                file: v.file.clone(),
                line: 1,
                col: 1,
                byte_start: 0,
                byte_end: 0,
                snippet: String::new(),
                rule: "span-fidelity",
                level: Level::Error,
                text: format!(
                    "diagnostic [{}] at line {} carries an unfaithful span: {}",
                    v.rule,
                    v.line,
                    problems.join("; ")
                ),
                suggestion: None,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(file: &str, src: &str) -> Vec<LintViolation> {
        analyze_source(Path::new(file), src)
    }

    // ---- R1 (migrated from the line scanner, now token-exact) ----

    #[test]
    fn r1_flags_poisoning_lock_unwraps() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let v = scan("rust/src/coordinator/engine.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "lock-unwrap");
        assert_eq!(v[0].line, 2);
        assert_eq!(v[0].level, Level::Error);

        let ok = "fn f() {\n    let g = state.lock_unpoisoned();\n}\n";
        assert!(scan("rust/src/coordinator/engine.rs", ok).is_empty());
    }

    #[test]
    fn r1_spans_and_suggestions_are_mechanical() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let v = scan("rust/src/coordinator/engine.rs", src);
        assert_eq!(v[0].snippet, "lock().unwrap()");
        assert_eq!(v[0].suggestion.as_deref(), Some("lock_unpoisoned()"));
        assert_eq!(&src[v[0].byte_start..v[0].byte_end], v[0].snippet);

        let cv = "fn f() { let g = cv.wait(guard).unwrap(); }\n";
        let v = scan("rust/src/coordinator/engine.rs", cv);
        assert_eq!(v[0].suggestion.as_deref(), Some("wait_unpoisoned(guard)"));

        // expect(..) carries a message the fix can't keep — no
        // suggestion, just the finding.
        let ex = "fn f() { let g = state.lock().expect(\"poisoned\"); }\n";
        let v = scan("rust/src/coordinator/engine.rs", ex);
        assert_eq!(v.len(), 1);
        assert!(v[0].suggestion.is_none());
    }

    #[test]
    fn r1_flags_condvar_unwraps_but_not_ticket_waits() {
        let bad = "fn f() { let g = cv.wait(guard).unwrap(); }\n";
        assert_eq!(scan("rust/src/coordinator/engine.rs", bad).len(), 1);
        // Ticket::wait returns a plain result the caller may handle.
        let ok = "fn f() { let r = ticket.wait(); r.ok(); }\n";
        assert!(scan("rust/src/coordinator/engine.rs", ok).is_empty());
    }

    #[test]
    fn r1_is_not_fooled_by_strings_or_comments() {
        // The cases the old line-oriented scanner could not distinguish.
        let src = concat!(
            "fn f() {\n",
            "    // state.lock().unwrap() — do not resurrect\n",
            "    let msg = \"state.lock().unwrap()\";\n",
            "    let raw = r#\"cv.wait(g).unwrap()\"#;\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/engine.rs", src).is_empty());
    }

    #[test]
    fn r1_skips_test_code() {
        let src = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() { let g = m.lock().unwrap(); }\n",
            "}\n",
        );
        assert!(scan("rust/src/util/threadpool.rs", src).is_empty());
    }

    // ---- R2 ----

    #[test]
    fn r2_flags_instant_now_anywhere_in_rank_controller() {
        let src = "fn decide() {\n    let t = Instant::now();\n}\n";
        let v = scan("rust/src/coordinator/rank_controller.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "instant-in-decide");
        assert_eq!(v[0].snippet, "Instant::now");
        // Same text outside any decide-critical scope is fine.
        assert!(scan("rust/src/coordinator/batcher.rs", src).is_empty());
    }

    #[test]
    fn r2_tracks_shard_guard_regions_anywhere() {
        let bad = concat!(
            "fn decide_stage() {\n",
            "    {\n",
            "        let mut shard = shared.shards[layer].lock_unpoisoned();\n",
            "        let t = Instant::now();\n",
            "    }\n",
            "    let after = Instant::now();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", bad);
        assert_eq!(v.len(), 1, "only the in-guard read is critical: {v:?}");
        assert_eq!(v[0].line, 4);
        // The guard-region rule is crate-wide now, not pipeline-only.
        let v2 = scan("rust/src/runtime/host.rs", bad);
        assert_eq!(v2.len(), 1);
    }

    // ---- R3 ----

    #[test]
    fn r3_flags_raw_mpsc_unless_annotated() {
        let bad = "use std::sync::mpsc;\n";
        let v = scan("rust/src/runtime/worker.rs", bad);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "raw-mpsc");

        let allowed = concat!(
            "// PJRT literals are not Send; a thread-local channel is the\n",
            "// sanctioned escape hatch here. lint:allow(mpsc)\n",
            "use std::sync::mpsc;\n",
        );
        assert!(scan("rust/src/runtime/worker.rs", allowed).is_empty());

        // A blank line breaks the annotation's contiguous block (the
        // stranded bare marker is R11's finding, not R3's).
        let broken = "// lint:allow(mpsc)\n\nuse std::sync::mpsc;\n";
        let v = scan("rust/src/runtime/worker.rs", broken);
        assert_eq!(v.iter().filter(|v| v.rule == "raw-mpsc").count(), 1);

        // completion.rs owns the channel surface.
        assert!(scan("rust/src/coordinator/completion.rs", bad).is_empty());
    }

    #[test]
    fn r3_accepts_rule_scoped_allow_spelling() {
        let allowed = "// internal queue only. lint:allow(raw-mpsc)\nuse std::sync::mpsc;\n";
        assert!(scan("rust/src/util/threadpool.rs", allowed).is_empty());
    }

    // ---- R4 ----

    #[test]
    fn r4_detects_two_lock_order_inversion() {
        let src = concat!(
            "fn forward(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn backward(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        let cycles: Vec<_> = v.iter().filter(|v| v.rule == "lock-order").collect();
        assert_eq!(cycles.len(), 1, "{v:?}");
        assert!(cycles[0].text.contains("alpha"));
        assert!(cycles[0].text.contains("beta"));
    }

    #[test]
    fn r4_consistent_order_is_clean() {
        let src = concat!(
            "fn one(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn two(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/sched.rs", src).is_empty());
    }

    #[test]
    fn r4_propagates_one_call_level() {
        let src = concat!(
            "fn outer(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    helper(s);\n",
            "}\n",
            "fn helper(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn inverted(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        let cycles: Vec<_> = v.iter().filter(|v| v.rule == "lock-order").collect();
        assert_eq!(cycles.len(), 1, "{v:?}");
        assert!(cycles[0].text.contains("helper"), "{}", cycles[0].text);
        assert!(cycles[0].text.contains("beta acquired at sched.rs:6"), "{}", cycles[0].text);
    }

    #[test]
    fn r4_self_relock_is_a_cycle() {
        let src = concat!(
            "fn f(s: &S) {\n",
            "    let a = s.table.lock_unpoisoned();\n",
            "    let b = s.table.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        assert_eq!(v.iter().filter(|v| v.rule == "lock-order").count(), 1);
    }

    #[test]
    fn r4_detached_closures_do_not_edge() {
        // The guard is NOT held inside an execute() closure — no edge.
        let src = concat!(
            "fn f(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    pool.execute(move || {\n",
            "        let b = s.beta.lock_unpoisoned();\n",
            "    });\n",
            "}\n",
            "fn g(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        assert!(v.iter().all(|v| v.rule != "lock-order"), "{v:?}");
    }

    #[test]
    fn r4_allow_suppresses_the_edge() {
        let src = concat!(
            "fn forward(s: &S) {\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "}\n",
            "fn backward(s: &S) {\n",
            "    let b = s.beta.lock_unpoisoned();\n",
            "    // audited: disjoint shard index sets. lint:allow(lock-order)\n",
            "    let a = s.alpha.lock_unpoisoned();\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/sched.rs", src).is_empty());
    }

    // ---- R5 ----

    #[test]
    fn r5_flags_hashmap_iteration_in_critical_modules() {
        let src = concat!(
            "use std::collections::HashMap;\n",
            "fn reduce(xs: &[f64]) -> f64 {\n",
            "    let mut acc: HashMap<usize, f64> = HashMap::new();\n",
            "    for (i, x) in xs.iter().enumerate() { *acc.entry(i % 4).or_insert(0.0) += x; }\n",
            "    let mut total = 0.0;\n",
            "    for (_, v) in &acc { total += v; }\n",
            "    total\n",
            "}\n",
        );
        let v = scan("rust/src/linalg/reduce.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "nondet-iter");
        assert_eq!(v[0].line, 6);
        // Outside the critical modules the same code is fine.
        assert!(scan("rust/src/rl/replay.rs", src).is_empty());
    }

    #[test]
    fn r5_flags_iter_method_chains() {
        let src = concat!(
            "fn f() {\n",
            "    let seen = HashSet::new();\n",
            "    let total: usize = seen.iter().count();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/track.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn r5_btreemap_is_fine() {
        let src = concat!(
            "fn f() {\n",
            "    let mut m: BTreeMap<usize, f64> = BTreeMap::new();\n",
            "    for (k, v) in &m { use_it(k, v); }\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/track.rs", src).is_empty());
    }

    #[test]
    fn r5_lookup_without_iteration_is_fine() {
        let src = concat!(
            "fn f() {\n",
            "    let m: HashMap<usize, f64> = HashMap::new();\n",
            "    let x = m.get(&3).copied().unwrap_or(0.0);\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/track.rs", src).is_empty());
    }

    // ---- R6 ----

    #[test]
    fn r6_flags_unwrap_in_pool_closures() {
        let src = concat!(
            "fn submit(pool: &ThreadPool, rx: Receiver<J>) {\n",
            "    pool.execute(move || {\n",
            "        let job = rx.recv().unwrap();\n",
            "        job.run();\n",
            "    });\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/jobs.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "panic-in-worker");
        assert_eq!(v[0].line, 3);
        assert_eq!(v[0].level, Level::Error);
    }

    #[test]
    fn r6_flags_panic_in_worker_loop_fns() {
        let src = concat!(
            "fn device_worker_loop(rx: &R) {\n",
            "    loop {\n",
            "        let Some(cmd) = rx.next() else { panic!(\"torn queue\") };\n",
            "        cmd.run().expect(\"cmd\");\n",
            "    }\n",
            "}\n",
        );
        let v = scan("rust/src/runtime/dev.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|v| v.rule == "panic-in-worker"));
    }

    #[test]
    fn r6_ignores_unwrap_outside_worker_contexts() {
        let src = "fn setup() { let cfg = load().unwrap(); }\n";
        assert!(scan("rust/src/coordinator/jobs.rs", src).is_empty());
    }

    #[test]
    fn r6_allow_annotation() {
        let src = concat!(
            "fn submit(pool: &ThreadPool) {\n",
            "    pool.execute(move || {\n",
            "        // invariant: slot filled by construction. lint:allow(panic-in-worker)\n",
            "        let v = slot.take().unwrap();\n",
            "    });\n",
            "}\n",
        );
        assert!(scan("rust/src/coordinator/jobs.rs", src).is_empty());
    }

    #[test]
    fn r6_is_advisory_in_test_code() {
        let src = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        pool.execute(move || { let v = slot.take().unwrap(); });\n",
            "    }\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/jobs.rs", src);
        let r6: Vec<_> = v.iter().filter(|v| v.rule == "panic-in-worker").collect();
        assert_eq!(r6.len(), 1, "{v:?}");
        assert_eq!(r6[0].level, Level::Advisory);
    }

    // ---- R7 ----

    #[test]
    fn r7_flags_pool_size_reads_in_linalg() {
        let src = concat!(
            "fn partition(total: usize, pool: &ThreadPool) -> usize {\n",
            "    let n_chunks = (total / 64).max(pool.size());\n",
            "    n_chunks\n",
            "}\n",
        );
        let v = scan("rust/src/linalg/split.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "pool-shape-partition");
        // The same read outside linalg/ is not this rule's business.
        assert!(scan("rust/src/util/threadpool.rs", src).is_empty());
    }

    #[test]
    fn r7_flags_available_parallelism() {
        let src = "fn chunks() -> usize { std::thread::available_parallelism().unwrap().get() }\n";
        let v = scan("rust/src/linalg/split.rs", src);
        assert!(v.iter().any(|v| v.rule == "pool-shape-partition"), "{v:?}");
    }

    #[test]
    fn r7_shape_derived_partition_is_clean() {
        let src = concat!(
            "const K_CHUNK: usize = 64;\n",
            "fn partition(k: usize) -> usize { k.div_ceil(K_CHUNK) }\n",
        );
        assert!(scan("rust/src/linalg/split.rs", src).is_empty());
    }
}

#[cfg(test)]
mod interprocedural_tests {
    use super::*;

    fn scan(file: &str, src: &str) -> Vec<LintViolation> {
        analyze_source(Path::new(file), src)
    }

    fn scan_with(file: &str, src: &str, opts: AnalysisOptions) -> Vec<LintViolation> {
        analyze_crate_with(&[(PathBuf::from(file), src.to_string())], opts)
    }

    fn rule<'a>(v: &'a [LintViolation], r: &str) -> Vec<&'a LintViolation> {
        v.iter().filter(|x| x.rule == r).collect()
    }

    // ---- R4, fixed point vs the PR 8 one-level analyzer ----

    const THREE_DEEP: &str = concat!(
        "fn outer(s: &S) {\n",          // 1
        "    let a = s.alpha.lock_unpoisoned();\n", // 2
        "    h1(s);\n",                 // 3
        "}\n",
        "fn h1(s: &S) { h2(s); }\n",    // 5
        "fn h2(s: &S) { h3(s); }\n",    // 6
        "fn h3(s: &S) {\n",             // 7
        "    let b = s.beta.lock_unpoisoned();\n",  // 8
        "}\n",
        "fn inverted(s: &S) {\n",       // 10
        "    let b = s.beta.lock_unpoisoned();\n",  // 11
        "    let a = s.alpha.lock_unpoisoned();\n", // 12
        "}\n",
    );

    #[test]
    fn r4_one_level_misses_the_three_deep_cycle() {
        let v = scan_with(
            "rust/src/coordinator/sched.rs",
            THREE_DEEP,
            AnalysisOptions { lock_depth: Some(1), ..AnalysisOptions::default() },
        );
        assert!(rule(&v, "lock-order").is_empty(), "one-level must miss it: {v:?}");
    }

    #[test]
    fn r4_fixed_point_catches_it_and_prints_the_chain() {
        let v = scan("rust/src/coordinator/sched.rs", THREE_DEEP);
        let cycles = rule(&v, "lock-order");
        assert_eq!(cycles.len(), 1, "{v:?}");
        let text = &cycles[0].text;
        assert!(text.contains("h1()"), "{text}");
        assert!(text.contains("h2() at sched.rs:5"), "{text}");
        assert!(text.contains("h3() at sched.rs:6"), "{text}");
        assert!(text.contains("beta acquired at sched.rs:8"), "{text}");
    }

    // ---- R8 ----

    #[test]
    fn r8_flags_blocking_directly_under_shard_guard() {
        let src = concat!(
            "fn drain_stage(s: &S, rx: &Receiver<C>) {\n",
            "    let shard = s.shards.lock_unpoisoned();\n",
            "    let cmd = rx.recv();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", src);
        let r8 = rule(&v, "blocking-under-lock");
        assert_eq!(r8.len(), 1, "{v:?}");
        assert_eq!(r8[0].line, 3);
        assert_eq!(r8[0].level, Level::Error);
        assert!(r8[0].text.contains("recv"), "{}", r8[0].text);
    }

    #[test]
    fn r8_clean_once_the_guard_is_dropped() {
        let src = concat!(
            "fn drain_stage(s: &S, rx: &Receiver<C>) {\n",
            "    {\n",
            "        let shard = s.shards.lock_unpoisoned();\n",
            "    }\n",
            "    let cmd = rx.recv();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", src);
        assert!(rule(&v, "blocking-under-lock").is_empty(), "{v:?}");
    }

    #[test]
    fn r8_reaches_blocking_through_two_calls() {
        let src = concat!(
            "fn stage(s: &S) {\n",                        // 1
            "    let shard = s.shard.lock_unpoisoned();\n", // 2
            "    helper();\n",                            // 3
            "}\n",
            "fn helper() { waiter(); }\n",                // 5
            "fn waiter() { std::thread::sleep(d); }\n",   // 6
        );
        let v = scan("rust/src/coordinator/sched.rs", src);
        let r8 = rule(&v, "blocking-under-lock");
        assert_eq!(r8.len(), 1, "{v:?}");
        assert_eq!(r8[0].line, 3, "flag the call site under the guard");
        let text = &r8[0].text;
        assert!(text.contains("sleep"), "{text}");
        assert!(text.contains("waiter() at sched.rs:5"), "{text}");
        assert!(text.contains("sleep blocks at sched.rs:6"), "{text}");

        // The one-level analyzer's view: helper() has no *direct*
        // blocking fact, so the same tree scans clean.
        let legacy = scan_with(
            "rust/src/coordinator/sched.rs",
            src,
            AnalysisOptions { lock_depth: Some(1), ..AnalysisOptions::default() },
        );
        assert!(rule(&legacy, "blocking-under-lock").is_empty(), "{legacy:?}");
    }

    #[test]
    fn r8_flags_pool_dispatch_under_shard_guard() {
        let src = concat!(
            "fn fanout(s: &S, pool: &ThreadPool) {\n",
            "    let shard = s.shard.lock_unpoisoned();\n",
            "    pool.execute(move || { heavy(); });\n",
            "}\n",
            "fn heavy() {}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", src);
        let r8 = rule(&v, "blocking-under-lock");
        assert_eq!(r8.len(), 1, "{v:?}");
        assert!(r8[0].text.contains("execute"), "{}", r8[0].text);
    }

    #[test]
    fn r8_allow_suppresses_with_rationale() {
        let src = concat!(
            "fn drain_stage(s: &S, rx: &Receiver<C>) {\n",
            "    let shard = s.shards.lock_unpoisoned();\n",
            "    // bounded: sender is the same thread pool, queue depth 1.\n",
            "    // lint:allow(blocking-under-lock)\n",
            "    let cmd = rx.recv();\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/pipeline.rs", src);
        assert!(rule(&v, "blocking-under-lock").is_empty(), "{v:?}");
        assert!(rule(&v, "allow-rationale").is_empty(), "{v:?}");
    }

    // ---- R9 ----

    #[test]
    fn r9_flags_raw_rank_at_charge_site() {
        let src = concat!(
            "fn charge(&self, r: usize) {\n",
            "    self.ledger.add(lowrank_attention_flops(self.seq, self.dim, r));\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/ledger.rs", src);
        let r9 = rule(&v, "charge-at-bucket");
        assert_eq!(r9.len(), 1, "{v:?}");
        assert!(r9[0].text.contains("argument 3"), "{}", r9[0].text);
        assert!(r9[0].text.contains("rank_bucket"), "{}", r9[0].text);
    }

    #[test]
    fn r9_bucket_derived_widths_are_clean() {
        let direct = concat!(
            "fn charge(&self, r: usize) {\n",
            "    self.ledger.add(lowrank_attention_flops(self.seq, self.dim, self.ladder.rank_bucket(r)));\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/ledger.rs", direct), "charge-at-bucket")
            .is_empty());

        // A local whose initializer mentions a bucket also counts.
        let via_let = concat!(
            "fn charge(&self, r: usize) {\n",
            "    let width = self.ladder.rank_bucket(r);\n",
            "    self.ledger.add(lowrank_attention_flops(self.seq, self.dim, width));\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/ledger.rs", via_let), "charge-at-bucket")
            .is_empty());
    }

    #[test]
    fn r9_checks_each_watched_argument() {
        let src = concat!(
            "fn charge(&self, r_old: usize, next_bucket: usize) {\n",
            "    self.ledger.add(incremental_svd_flops(self.seq, self.dim, r_old, next_bucket));\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/ledger.rs", src);
        let r9 = rule(&v, "charge-at-bucket");
        assert_eq!(r9.len(), 1, "only the raw arg flags: {v:?}");
        assert!(r9[0].text.contains("argument 3"), "{}", r9[0].text);
    }

    #[test]
    fn r9_is_scoped_to_charge_call_sites_not_the_flops_module() {
        // flops.rs internals pass raw ranks between the charge helpers
        // by design; the rule watches the *call* surface.
        let src = concat!(
            "pub fn lowrank_attention_flops(s: usize, d: usize, r: usize) -> u64 {\n",
            "    partial_svd_flops(s, d, r)\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/flops.rs", src), "charge-at-bucket").is_empty());
    }

    // ---- R10 ----

    #[test]
    fn r10_flags_early_exit_before_handle_resolution() {
        let src = concat!(
            "fn submit(&self, req: Req) -> Result<(), E> {\n",
            "    let reply = GenReply { slot: self.slot(), stream: None };\n",
            "    self.preflight()?;\n",
            "    self.send(Work::Generate(req, reply))\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/engine.rs", src);
        let r10 = rule(&v, "ticket-resolve");
        assert_eq!(r10.len(), 1, "{v:?}");
        assert_eq!(r10[0].line, 3);
        assert!(r10[0].text.contains("`reply`"), "{}", r10[0].text);
    }

    #[test]
    fn r10_move_before_the_exit_is_clean() {
        let src = concat!(
            "fn submit(&self, req: Req) -> Result<(), E> {\n",
            "    self.preflight()?;\n",
            "    let reply = GenReply { slot: self.slot(), stream: None };\n",
            "    self.send(Work::Generate(req, reply))\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/engine.rs", src), "ticket-resolve")
            .is_empty());
    }

    #[test]
    fn r10_explicit_drop_and_resolve_methods_are_clean() {
        let dropped = concat!(
            "fn cancel(&self) -> Result<(), E> {\n",
            "    let reply = GenReply { slot: self.slot(), stream: None };\n",
            "    if self.closed() { drop(reply); return Err(E::Closed); }\n",
            "    Ok(())\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/engine.rs", dropped), "ticket-resolve")
            .is_empty());

        let abandoned = concat!(
            "fn cancel(&self) -> Result<(), E> {\n",
            "    let reply = AttnReply { slot: self.slot() };\n",
            "    reply.abandon();\n",
            "    return Err(E::Closed);\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/engine.rs", abandoned), "ticket-resolve")
            .is_empty());
    }

    // ---- R11 ----

    #[test]
    fn r11_flags_bare_allow_markers() {
        let src = concat!(
            "fn f(pool: &P, x: &Slot) {\n",
            "    pool.execute(move || {\n",
            "        // lint:allow(panic-in-worker)\n",
            "        let v = x.take().unwrap();\n",
            "    });\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/jobs.rs", src);
        let r11 = rule(&v, "allow-rationale");
        assert_eq!(r11.len(), 1, "{v:?}");
        assert_eq!(r11[0].line, 3);
        assert_eq!(r11[0].level, Level::Error);
        // The marker still suppresses its target rule.
        assert!(rule(&v, "panic-in-worker").is_empty(), "{v:?}");
    }

    #[test]
    fn r11_accepts_rationale_in_the_same_comment_block() {
        let inline = concat!(
            "fn f(pool: &P, x: &Slot) {\n",
            "    pool.execute(move || {\n",
            "        // invariant: slot filled by construction. lint:allow(panic-in-worker)\n",
            "        let v = x.take().unwrap();\n",
            "    });\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/jobs.rs", inline), "allow-rationale")
            .is_empty());

        let above = concat!(
            "fn f(pool: &P, x: &Slot) {\n",
            "    pool.execute(move || {\n",
            "        // Slot is filled by construction before dispatch.\n",
            "        // lint:allow(panic-in-worker)\n",
            "        let v = x.take().unwrap();\n",
            "    });\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/jobs.rs", above), "allow-rationale")
            .is_empty());
    }

    #[test]
    fn r11_ignores_markers_in_test_code() {
        let src = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        // lint:allow(nondet-iter)\n",
            "        for (k, v) in &map { use_it(k, v); }\n",
            "    }\n",
            "}\n",
        );
        assert!(rule(&scan("rust/src/coordinator/jobs.rs", src), "allow-rationale")
            .is_empty());
    }

    // ---- R12 ----

    #[test]
    fn r12_clean_run_carries_faithful_spans() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let files = vec![(
            PathBuf::from("rust/src/coordinator/engine.rs"),
            src.to_string(),
        )];
        let v = analyze_crate_with(&files, AnalysisOptions::default());
        assert!(!v.is_empty());
        assert!(rule(&v, "span-fidelity").is_empty(), "{v:?}");
    }

    #[test]
    fn r12_catches_a_corrupted_span() {
        let src = "fn f() {\n    let g = state.lock().unwrap();\n}\n";
        let files = vec![(
            PathBuf::from("rust/src/coordinator/engine.rs"),
            src.to_string(),
        )];
        let mut v = analyze_crate_with(&files, AnalysisOptions::default());
        v[0].byte_start += 1;
        let bad = verify_spans(&files, &v);
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert_eq!(bad[0].rule, "span-fidelity");
        assert_eq!(bad[0].level, Level::Error);
        assert!(bad[0].text.contains("unfaithful span"), "{}", bad[0].text);
    }

    // ---- severity by file kind ----

    #[test]
    fn findings_outside_src_are_advisory() {
        let src = "fn f() { let g = state.lock().unwrap(); }\n";
        for file in
            ["rust/tests/conformance.rs", "rust/benches/decode.rs", "examples/demo.rs"]
        {
            let v = scan(file, src);
            let r1 = rule(&v, "lock-unwrap");
            assert_eq!(r1.len(), 1, "{file}: {v:?}");
            assert_eq!(r1[0].level, Level::Advisory, "{file}");
        }
    }

    #[test]
    fn test_mask_is_ignored_outside_src() {
        // In rust/tests/ everything is test code; the in-file test mask
        // must not blank the whole file.
        let src = concat!(
            "#[test]\n",
            "fn t() { let g = state.lock().unwrap(); }\n",
        );
        let v = scan("rust/tests/conformance.rs", src);
        assert_eq!(rule(&v, "lock-unwrap").len(), 1, "{v:?}");
    }

    #[test]
    fn rule_table_matches_the_rule_set() {
        assert_eq!(RULES.len(), 14);
        let ids: BTreeSet<&str> = RULES.iter().map(|r| r.name).collect();
        assert_eq!(ids.len(), 14);
        assert_eq!(RULES[7].name, "blocking-under-lock");
        assert_eq!(RULES[11].name, "span-fidelity");
        assert_eq!(RULES[12].name, "nondet-partition");
        assert_eq!(RULES[13].name, "nondet-decide");
        for r in &RULES {
            assert!(!r.contract.is_empty(), "{} has no contract", r.name);
            assert!(!r.example.is_empty(), "{} has no example", r.name);
            assert!(!r.suppression.is_empty(), "{} has no suppression text", r.name);
        }
    }

    // ---- R13/R14 determinism taint ----

    #[test]
    fn r13_flags_pool_sized_partitions() {
        let src = concat!(
            "fn plan(pool: &P, work: &[J]) {\n",
            "    let lanes = pool.size();\n",
            "    for w in work.chunks(lanes) { run(w); }\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/plan.rs", src);
        let r13 = rule(&v, "nondet-partition");
        assert_eq!(r13.len(), 1, "{v:?}");
        assert_eq!(r13[0].line, 3);
        assert_eq!(r13[0].level, Level::Error);
        assert!(r13[0].text.contains("`lanes`"), "{}", r13[0].text);
        assert!(r13[0].text.contains("pool-shape"), "{}", r13[0].text);
        assert!(r13[0].snippet.starts_with("chunks(lanes)"), "{}", r13[0].snippet);
    }

    #[test]
    fn r13_taint_flows_through_let_chains() {
        let src = concat!(
            "fn plan(cfg: &C, xs: &[f32]) {\n",
            "    let n_workers = cfg.n_workers.max(1);\n",
            "    let lanes = n_workers * 2;\n",
            "    let step = xs.len().div_ceil(lanes);\n",
            "    consume(step);\n",
            "}\n",
        );
        let v = scan("rust/src/linalg/tile.rs", src);
        let r13 = rule(&v, "nondet-partition");
        assert_eq!(r13.len(), 1, "{v:?}");
        assert_eq!(r13[0].line, 4);
        assert!(r13[0].text.contains("n_workers"), "{}", r13[0].text);
    }

    #[test]
    fn r13_shape_pure_partitions_stay_clean() {
        let src = concat!(
            "fn plan(xs: &[f32], tile: usize) {\n",
            "    let step = xs.len().div_ceil(tile);\n",
            "    for w in xs.chunks(step) { run(w); }\n",
            "}\n",
        );
        let v = scan("rust/src/linalg/tile.rs", src);
        assert!(rule(&v, "nondet-partition").is_empty(), "{v:?}");
    }

    #[test]
    fn r13_is_scoped_to_bit_identity_modules() {
        let src = concat!(
            "fn plan(pool: &P, work: &[J]) {\n",
            "    let lanes = pool.size();\n",
            "    for w in work.chunks(lanes) { run(w); }\n",
            "}\n",
        );
        let v = scan("rust/src/util/report.rs", src);
        assert!(rule(&v, "nondet-partition").is_empty(), "{v:?}");
    }

    #[test]
    fn r13_unordered_iteration_taints_the_partition() {
        let src = concat!(
            "fn plan(index: HashMap<u64, usize>, xs: &[f32]) {\n",
            "    let order: Vec<usize> = index.values().copied().collect();\n",
            "    xs.split_at(order[0]);\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/plan.rs", src);
        let r13 = rule(&v, "nondet-partition");
        assert_eq!(r13.len(), 1, "{v:?}");
        assert!(r13[0].text.contains("unordered-iter"), "{}", r13[0].text);
    }

    #[test]
    fn r13_allow_marker_with_rationale_suppresses() {
        let src = concat!(
            "fn plan(pool: &P, work: &[J]) {\n",
            "    let lanes = pool.size();\n",
            "    // Display-only batching; results are merged by job id.\n",
            "    // lint:allow(nondet-partition)\n",
            "    for w in work.chunks(lanes) { run(w); }\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/plan.rs", src);
        assert!(rule(&v, "nondet-partition").is_empty(), "{v:?}");
        assert!(rule(&v, "allow-rationale").is_empty(), "{v:?}");
    }

    #[test]
    fn r14_flags_wall_clock_into_decide_step() {
        let src = concat!(
            "fn budget_ms() -> u64 {\n",
            "    let t0 = Instant::now();\n",
            "    t0.elapsed().as_millis() as u64\n",
            "}\n",
            "fn drive(ctl: &C) {\n",
            "    let budget = budget_ms();\n",
            "    ctl.decide_step(budget);\n",
            "}\n",
        );
        let v = scan("rust/src/coordinator/driver.rs", src);
        let r14 = rule(&v, "nondet-decide");
        assert_eq!(r14.len(), 1, "{v:?}");
        assert_eq!(r14[0].line, 7);
        assert_eq!(r14[0].level, Level::Error);
        assert!(r14[0].text.contains("wall-clock"), "{}", r14[0].text);
        assert!(r14[0].text.contains("budget_ms()"), "{}", r14[0].text);
    }

    #[test]
    fn r14_racing_recv_taints_the_decision() {
        let src = concat!(
            "fn drive(ctl: &C, rx: &R) {\n",
            "    let hint = rx.try_recv().ok();\n",
            "    ctl.decide_step(hint);\n",
            "}\n",
        );
        let v = scan("rust/src/policy/driver.rs", src);
        let r14 = rule(&v, "nondet-decide");
        assert_eq!(r14.len(), 1, "{v:?}");
        assert!(r14[0].text.contains("channel-race"), "{}", r14[0].text);
    }

    #[test]
    fn r14_plain_recv_is_ordered_and_clean() {
        let src = concat!(
            "fn drive(ctl: &C, rx: &R) {\n",
            "    let cmd = rx.recv();\n",
            "    ctl.decide_step(cmd);\n",
            "}\n",
        );
        let v = scan("rust/src/policy/driver.rs", src);
        assert!(rule(&v, "nondet-decide").is_empty(), "{v:?}");
    }

    #[test]
    fn r14_one_level_misses_the_two_hop_taint() {
        // budget_ms() -> jitter() -> Instant::now(): at depth 1 a call
        // site only sees direct facts, so the taint never reaches drive.
        let src = concat!(
            "fn jitter() -> u64 {\n",
            "    let t0 = Instant::now();\n",
            "    t0.elapsed().as_nanos() as u64\n",
            "}\n",
            "fn budget_ms() -> u64 { jitter() / 1_000_000 }\n",
            "fn drive(ctl: &C) {\n",
            "    let budget = budget_ms();\n",
            "    ctl.decide_step(budget);\n",
            "}\n",
        );
        let legacy = scan_with(
            "rust/src/coordinator/driver.rs",
            src,
            AnalysisOptions { lock_depth: Some(1), ..AnalysisOptions::default() },
        );
        assert!(rule(&legacy, "nondet-decide").is_empty(), "{legacy:?}");
        let v = scan("rust/src/coordinator/driver.rs", src);
        let r14 = rule(&v, "nondet-decide");
        assert_eq!(r14.len(), 1, "{v:?}");
        assert!(r14[0].text.contains("budget_ms()"), "{}", r14[0].text);
    }
}
