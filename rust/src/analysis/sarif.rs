//! SARIF 2.1.0 emission for the lint findings.
//!
//! SARIF (Static Analysis Results Interchange Format) is the standard
//! CI-ingestible report shape: one `run` by one `tool.driver`, a rule
//! catalogue, and one `result` per finding with a physical location.
//! We emit the minimal profile that code-scanning UIs consume —
//! `ruleId`, `level`, `message.text`, and a `physicalLocation` with
//! both line/column and byte-offset regions (R12 guarantees the two
//! agree) — plus a `fix` when the finding carries a mechanical
//! suggestion.
//!
//! The serializer is the crate's own [`crate::util::json`]; there is no
//! external SARIF dependency to drift against, so `validate_sarif`
//! pins the shape the tests (and CI uploaders) rely on.

use crate::util::json::{obj, Json};

use super::rules::{Level, LintViolation, RULES};

/// SARIF version emitted and accepted by [`validate_sarif`].
pub const SARIF_VERSION: &str = "2.1.0";
const SARIF_SCHEMA: &str =
    "https://docs.oasis-open.org/sarif/sarif/v2.1.0/errata01/os/schemas/sarif-schema-2.1.0.json";

fn level_str(l: Level) -> &'static str {
    match l {
        Level::Error => "error",
        // SARIF has no "advisory"; "note" is its non-failing severity.
        Level::Advisory => "note",
    }
}

fn location(v: &LintViolation) -> Json {
    obj(vec![(
        "physicalLocation",
        obj(vec![
            (
                "artifactLocation",
                obj(vec![(
                    "uri",
                    Json::Str(v.file.to_string_lossy().replace('\\', "/")),
                )]),
            ),
            (
                "region",
                obj(vec![
                    ("startLine", Json::Num(v.line as f64)),
                    ("startColumn", Json::Num(v.col as f64)),
                    ("byteOffset", Json::Num(v.byte_start as f64)),
                    ("byteLength", Json::Num((v.byte_end - v.byte_start) as f64)),
                    ("snippet", obj(vec![("text", Json::Str(v.snippet.clone()))])),
                ]),
            ),
        ]),
    )])
}

fn result(v: &LintViolation) -> Json {
    let mut pairs = vec![
        ("ruleId", Json::Str(v.rule.to_string())),
        ("level", Json::Str(level_str(v.level).to_string())),
        ("message", obj(vec![("text", Json::Str(v.text.clone()))])),
        ("locations", Json::Arr(vec![location(v)])),
    ];
    if let Some(s) = &v.suggestion {
        pairs.push((
            "fixes",
            Json::Arr(vec![obj(vec![
                (
                    "description",
                    obj(vec![("text", Json::Str(format!("replace with `{s}`")))]),
                ),
                (
                    "artifactChanges",
                    Json::Arr(vec![obj(vec![
                        (
                            "artifactLocation",
                            obj(vec![(
                                "uri",
                                Json::Str(v.file.to_string_lossy().replace('\\', "/")),
                            )]),
                        ),
                        (
                            "replacements",
                            Json::Arr(vec![obj(vec![
                                (
                                    "deletedRegion",
                                    obj(vec![
                                        ("byteOffset", Json::Num(v.byte_start as f64)),
                                        (
                                            "byteLength",
                                            Json::Num((v.byte_end - v.byte_start) as f64),
                                        ),
                                    ]),
                                ),
                                (
                                    "insertedContent",
                                    obj(vec![("text", Json::Str(s.clone()))]),
                                ),
                            ])]),
                        ),
                    ])]),
                ),
            ])]),
        ));
    }
    obj(pairs)
}

/// Render `violations` as a single-run SARIF 2.1.0 log.
pub fn to_sarif(violations: &[LintViolation]) -> Json {
    let rules: Vec<Json> = RULES
        .iter()
        .map(|r| {
            obj(vec![
                ("id", Json::Str(r.name.to_string())),
                (
                    "shortDescription",
                    obj(vec![("text", Json::Str(r.contract.to_string()))]),
                ),
                (
                    "fullDescription",
                    obj(vec![("text", Json::Str(r.example.to_string()))]),
                ),
                ("help", obj(vec![("text", Json::Str(r.suppression.to_string()))])),
            ])
        })
        .collect();
    let results: Vec<Json> = violations.iter().map(result).collect();
    obj(vec![
        ("$schema", Json::Str(SARIF_SCHEMA.to_string())),
        ("version", Json::Str(SARIF_VERSION.to_string())),
        (
            "runs",
            Json::Arr(vec![obj(vec![
                (
                    "tool",
                    obj(vec![(
                        "driver",
                        obj(vec![
                            ("name", Json::Str("drrl-lint".to_string())),
                            ("informationUri", Json::Str("CONFORMANCE.md".to_string())),
                            ("rules", Json::Arr(rules)),
                        ]),
                    )]),
                ),
                ("results", Json::Arr(results)),
            ])]),
        ),
    ])
}

/// Shape-check a SARIF log: the invariants CI uploaders and the tests
/// depend on. Returns the list of problems (empty = valid).
pub fn validate_sarif(doc: &Json) -> Vec<String> {
    let mut errs = Vec::new();
    if doc.get("version").and_then(|v| v.as_str()) != Some(SARIF_VERSION) {
        errs.push(format!("version must be \"{SARIF_VERSION}\""));
    }
    let Some(runs) = doc.get("runs").and_then(|r| r.as_arr()) else {
        errs.push("runs must be an array".to_string());
        return errs;
    };
    if runs.len() != 1 {
        errs.push(format!("expected exactly 1 run, got {}", runs.len()));
        return errs;
    }
    let run = &runs[0];
    let driver = run.get("tool").and_then(|t| t.get("driver"));
    match driver.and_then(|d| d.get("name")).and_then(|n| n.as_str()) {
        Some("drrl-lint") => {}
        other => errs.push(format!("tool.driver.name must be \"drrl-lint\", got {other:?}")),
    }
    let rule_entries: &[Json] = driver
        .and_then(|d| d.get("rules"))
        .and_then(|r| r.as_arr())
        .map(|r| r.as_slice())
        .unwrap_or(&[]);
    if rule_entries.len() != RULES.len() {
        errs.push(format!(
            "tool.driver.rules must list all {} rules, got {}",
            RULES.len(),
            rule_entries.len()
        ));
    } else {
        // The catalogue is THE rule table ([`RULES`]), not a copy: ids
        // and the three metadata texts must match it entry for entry.
        for (i, (entry, ri)) in rule_entries.iter().zip(RULES.iter()).enumerate() {
            if entry.get("id").and_then(|x| x.as_str()) != Some(ri.name) {
                errs.push(format!("rules[{i}].id must be {:?}", ri.name));
            }
            let texts = [
                ("shortDescription", ri.contract),
                ("fullDescription", ri.example),
                ("help", ri.suppression),
            ];
            for (field, want) in texts {
                let got =
                    entry.get(field).and_then(|d| d.get("text")).and_then(|t| t.as_str());
                if got != Some(want) {
                    errs.push(format!("rules[{i}].{field}.text diverges from RULES"));
                }
            }
        }
    }
    let Some(results) = run.get("results").and_then(|r| r.as_arr()) else {
        errs.push("runs[0].results must be an array".to_string());
        return errs;
    };
    for (i, r) in results.iter().enumerate() {
        let rule_id = r.get("ruleId").and_then(|x| x.as_str());
        if !rule_id.is_some_and(|id| RULES.iter().any(|ri| ri.name == id)) {
            errs.push(format!("results[{i}].ruleId {rule_id:?} is not a known rule"));
        }
        match r.get("level").and_then(|x| x.as_str()) {
            Some("error") | Some("note") => {}
            other => errs.push(format!("results[{i}].level must be error|note, got {other:?}")),
        }
        if r.get("message")
            .and_then(|m| m.get("text"))
            .and_then(|t| t.as_str())
            .map_or(true, str::is_empty)
        {
            errs.push(format!("results[{i}].message.text missing or empty"));
        }
        let region = r
            .get("locations")
            .and_then(|l| l.as_arr())
            .and_then(|l| l.first())
            .and_then(|l| l.get("physicalLocation"))
            .and_then(|p| p.get("region"));
        let Some(region) = region else {
            errs.push(format!("results[{i}] lacks a physicalLocation.region"));
            continue;
        };
        for field in ["startLine", "startColumn", "byteOffset", "byteLength"] {
            if region.get(field).and_then(|x| x.as_usize()).is_none() {
                errs.push(format!("results[{i}].region.{field} missing"));
            }
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::analyze_source;
    use std::path::Path;

    fn findings() -> Vec<LintViolation> {
        analyze_source(
            Path::new("rust/src/coordinator/engine.rs"),
            "fn f() {\n    let g = state.lock().unwrap();\n}\n",
        )
    }

    #[test]
    fn emitted_sarif_validates_and_roundtrips() {
        let v = findings();
        assert!(!v.is_empty());
        let doc = to_sarif(&v);
        assert_eq!(validate_sarif(&doc), Vec::<String>::new());
        let re = Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(validate_sarif(&re), Vec::<String>::new());
        let results = re.get("runs").unwrap().as_arr().unwrap()[0]
            .get("results")
            .unwrap()
            .as_arr()
            .unwrap()
            .to_vec();
        assert_eq!(results.len(), v.len());
        assert_eq!(
            results[0].get("ruleId").unwrap().as_str(),
            Some("lock-unwrap")
        );
        // The mechanical fix rides along.
        assert!(results[0].get("fixes").is_some());
    }

    #[test]
    fn empty_run_is_valid() {
        let doc = to_sarif(&[]);
        assert!(validate_sarif(&doc).is_empty());
    }

    #[test]
    fn rule_catalogue_mirrors_the_rule_table() {
        let doc = to_sarif(&[]);
        let rules = doc.get("runs").unwrap().as_arr().unwrap()[0]
            .get("tool")
            .unwrap()
            .get("driver")
            .unwrap()
            .get("rules")
            .unwrap()
            .as_arr()
            .unwrap()
            .to_vec();
        assert_eq!(rules.len(), RULES.len());
        for (entry, ri) in rules.iter().zip(RULES.iter()) {
            assert_eq!(entry.get("id").unwrap().as_str(), Some(ri.name));
            let text = |field: &str| {
                entry.get(field).unwrap().get("text").unwrap().as_str().unwrap().to_string()
            };
            assert_eq!(text("shortDescription"), ri.contract);
            assert_eq!(text("fullDescription"), ri.example);
            assert_eq!(text("help"), ri.suppression);
        }
    }

    #[test]
    fn validator_rejects_unknown_rules_and_levels() {
        let mut v = findings();
        v[0].rule = "not-a-rule";
        let doc = to_sarif(&v);
        assert!(!validate_sarif(&doc).is_empty());

        let bad = Json::parse(r#"{"version":"2.0.0","runs":[]}"#).unwrap();
        assert!(!validate_sarif(&bad).is_empty());
    }
}
